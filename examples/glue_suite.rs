//! GLUE-suite example: the paper's Table 2 comparison (classifier probe vs
//! Hadamard adapter vs full fine-tuning) across all eight synthetic-GLUE
//! tasks on one backbone, printed as a markdown table.
//!
//! ```bash
//! cargo run --release --example glue_suite            # full budgets
//! cargo run --release --example glue_suite -- quick   # smoke budgets
//! ```

use hadapt::config::Config;
use hadapt::coordinator::{index_records, Coordinator};
use hadapt::report::Table;
use hadapt::Result;

const TASKS: [&str; 8] = ["mrpc", "cola", "mnli", "qnli", "qqp", "rte", "sst2", "stsb"];
const METHODS: [&str; 3] = ["classifier", "hadamard", "full"];

fn main() -> Result<()> {
    let quick = std::env::args().any(|a| a == "quick");
    let mut cfg = Config::default();
    cfg.models = vec!["base".into()];
    cfg.quick = quick;

    let mut coord = Coordinator::new(cfg)?;
    let models = coord.config.models.clone();
    let recs = coord.run_grid(&models, &TASKS, &METHODS)?;
    let idx = index_records(&recs);

    let mut header = vec!["method"];
    header.extend(TASKS);
    header.push("avg");
    let mut t = Table::new("GLUE suite: base backbone", &header);
    let mut avgs = Vec::new();
    for m in METHODS {
        let mut cells = vec![m.to_string()];
        let mut sum = 0.0;
        for task in TASKS {
            let r = idx[&("base".to_string(), task.to_string(), m.to_string())];
            cells.push(format!("{:.1}", r.score));
            sum += r.score;
        }
        let avg = sum / TASKS.len() as f64;
        avgs.push(avg);
        cells.push(format!("{avg:.1}"));
        t.row(cells);
    }
    println!("{}", t.render());
    println!(
        "classifier reaches {:.1}% of full FT; hadamard reaches {:.1}% \
         (paper: 77.5% / 99.4%)",
        100.0 * avgs[0] / avgs[2].max(1e-9),
        100.0 * avgs[1] / avgs[2].max(1e-9)
    );
    Ok(())
}
