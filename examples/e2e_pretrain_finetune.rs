//! End-to-end driver (the EXPERIMENTS.md §E2E run): proves all three layers
//! compose on a real small workload.
//!
//! 1. MLM-pretrains the `base` transformer from scratch on the synthetic
//!    corpus for several hundred steps, logging the loss curve — every step
//!    executes the Pallas-kernel-bearing HLO artifact from Rust via PJRT.
//! 2. Runs the paper's two-stage Hadamard tuning on an SST-2-like task,
//!    logging both stage loss curves.
//! 3. Evaluates and reports score, parameter accounting, and engine stats.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pretrain_finetune
//! ```

use std::time::Instant;

use hadapt::data::{generate, task_info};
use hadapt::methods::Method;
use hadapt::runtime::Engine;
use hadapt::train::{pretrain, tune, PretrainOpts, TuneOpts};
use hadapt::report::pct;
use hadapt::Result;

fn print_curve(name: &str, losses: &[f32], every: usize) {
    println!("  {name} loss curve:");
    for (i, l) in losses.iter().enumerate() {
        if i % every == 0 || i + 1 == losses.len() {
            println!("    step {i:>5}  loss {l:.4}");
        }
    }
}

fn main() -> Result<()> {
    let t0 = Instant::now();
    let engine = Engine::new("artifacts")?;
    let model = "base";
    let info = engine.manifest().model(model)?.clone();
    println!(
        "== e2e: {model} ({} layers, hidden {}, {} backbone params) ==\n",
        info.layers, info.hidden, info.backbone_params()
    );

    // ---- 1) pre-train ----
    println!("[1/3] MLM pre-training (from scratch, synthetic corpus)");
    // base diverges above ~1e-3 (see EXPERIMENTS.md §E2E); 600 steps is
    // enough to drop visibly below the 6.22 unigram floor on one core
    let popts = PretrainOpts { steps: 600, lr: 1e-3, warmup: 50, seed: 42, log_every: 0 };
    let pre = pretrain(&engine, model, &popts)?;
    print_curve("mlm", &pre.losses, 50);
    let first = pre.losses[0];
    let last = pre.losses[pre.losses.len() - 10..].iter().sum::<f32>() / 10.0;
    println!("  mlm loss {first:.3} -> {last:.3} (uniform floor ~6.22, band floor ~4.1)\n");

    // ---- 2) two-stage Hadamard tuning ----
    println!("[2/3] two-stage Hadamard adapter tuning on sst2-like");
    let train_ds = generate(task_info("sst2").unwrap(), 42, "train", 4096);
    let dev_ds = generate(task_info("sst2").unwrap(), 42, "dev", 512);
    let method = Method::hadamard();
    let opts = TuneOpts {
        stage1_steps: 120,
        main_steps: 240,
        verbose: false,
        ..Default::default()
    };
    let result = tune(&engine, model, &pre.store, &train_ds, &dev_ds, &method, &opts)?;
    print_curve("stage1 (classifier)", &result.stage1_losses, 30);
    print_curve("stage2 (adapter+norm)", &result.main_losses, 60);

    // ---- 3) report ----
    println!("\n[3/3] results");
    println!("  dev accuracy: {:.1}", result.score);
    println!(
        "  trainable in stage 2: {} scalars; adapter-only {} = {} of backbone",
        result.trainable_scalars,
        result.adapter_scalars,
        pct(result.param_fraction)
    );
    let stats = engine.stats();
    println!(
        "  engine: {} artifact compiles ({:.1}s), {} executions ({:.1}s, {:.1} exec/s)",
        stats.compiles,
        stats.compile_secs,
        stats.executions,
        stats.execute_secs,
        stats.executions as f64 / stats.execute_secs.max(1e-9)
    );
    println!("  total wall time: {:.1}s", t0.elapsed().as_secs_f64());

    // hard assertions: this binary doubles as a smoke gate
    assert!(last < first - 0.15, "pre-training failed to learn");
    assert!(result.score > 60.0, "adapter tuning failed to beat chance");
    println!("\nE2E OK");
    Ok(())
}
