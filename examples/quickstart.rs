//! Quickstart: tune a pre-trained backbone on one task with the Hadamard
//! adapter and print the paper-style summary.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use hadapt::config::Config;
use hadapt::coordinator::{Coordinator, RunSpec};
use hadapt::report::pct;
use hadapt::Result;

fn main() -> Result<()> {
    let mut cfg = Config::default();
    // keep the quickstart snappy: small model, reduced budgets
    cfg.models = vec!["base".into()];
    cfg.pretrain_steps = 400;
    cfg.stage1_steps = 80;
    cfg.main_steps = 200;

    let mut coord = Coordinator::new(cfg)?;
    println!("== hadapt quickstart: Hadamard adapter on SST-2-like ==\n");

    // 1) the "pre-trained PLM" (MLM-pretrained in-harness, cached on disk)
    coord.backbone("base")?;

    // 2) two-stage adapter tuning (paper Sec 3.2): classifier first, then
    //    adapter + norm with everything else frozen
    let seed = coord.config.seed;
    let hadamard = coord.run(&RunSpec {
        model: "base".into(),
        task: "sst2".into(),
        method: "hadamard".into(),
        seed,
    })?;

    // 3) the two reference points from the paper's Table 2
    let classifier = coord.run(&RunSpec {
        model: "base".into(),
        task: "sst2".into(),
        method: "classifier".into(),
        seed,
    })?;
    let full = coord.run(&RunSpec {
        model: "base".into(),
        task: "sst2".into(),
        method: "full".into(),
        seed,
    })?;

    println!("\n  {:<12} {:>8} {:>14} {:>12}", "method", "score", "trainable", "% backbone");
    for r in [&classifier, &hadamard, &full] {
        println!(
            "  {:<12} {:>8.1} {:>14} {:>12}",
            r.spec.method,
            r.score,
            r.trainable_scalars,
            pct(r.param_fraction)
        );
    }
    println!(
        "\nHadamard adapter reaches {:.1}% of full fine-tuning with {} of its parameters.",
        100.0 * hadamard.score / full.score.max(1e-9),
        pct(hadamard.param_fraction)
    );
    Ok(())
}
