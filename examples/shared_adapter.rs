//! Shared-adapter example: the paper's Sec. 5 future-work proposal, made
//! concrete. Trains Hadamard adapters on several tasks, shows that the
//! *weight* vectors are nearly identical across tasks while the *bias*
//! vectors diverge (Fig 5 c1/c2), then demonstrates adapter transfer:
//! reuse task A's trained weight vectors on task B, retraining only B's
//! biases + norm — halving the already-tiny parameter budget.
//!
//! ```bash
//! cargo run --release --example shared_adapter
//! ```

use hadapt::analysis::similarity::{extract, similarity_avg};
use hadapt::config::Config;
use hadapt::coordinator::{Coordinator, RunSpec};
use hadapt::methods::Method;
use hadapt::train::tune;
use hadapt::Result;

const TASKS: [&str; 3] = ["sst2", "rte", "qnli"];

fn main() -> Result<()> {
    let mut cfg = Config::default();
    cfg.models = vec!["base".into()];
    cfg.stage1_steps = 80;
    cfg.main_steps = 240;
    let mut coord = Coordinator::new(cfg)?;
    let info = coord.engine.manifest().model("base")?.clone();
    let layers = info.layers;
    let opts = coord.config.tune_opts();

    // 1) train adapters per task, keep the tuned stores
    println!("[1/3] training Hadamard adapters on {TASKS:?}");
    let mut adapters = Vec::new();
    let mut tuned = Vec::new();
    for task in TASKS {
        let spec = RunSpec {
            model: "base".into(),
            task: task.into(),
            method: "hadamard".into(),
            seed: coord.config.seed,
        };
        let (rec, result) = coord.run_uncached(&spec, &opts)?;
        println!("  {task}: {:.1}", rec.score);
        adapters.push(extract(task, &result.store, layers)?);
        tuned.push((task, result));
    }

    // 2) the Fig 5 observation
    println!("\n[2/3] cross-task adapter similarity (layer-averaged cosine)");
    let w = similarity_avg(&adapters, |a| &a.weights);
    let b = similarity_avg(&adapters, |a| &a.biases);
    println!(
        "  weights: off-diagonal mean {:.3} (paper ~1.0 => reusable)",
        w.off_diagonal_mean()
    );
    println!(
        "  biases:  off-diagonal mean {:.3} (paper <=0.3 => task-specific)",
        b.off_diagonal_mean()
    );

    // 3) adapter transfer: take task 0's trained weight vectors, implant
    //    into the backbone, and tune only B+N (+head stage) on task 1.
    let (donor_task, donor) = (&tuned[0].0, &tuned[0].1);
    let target = TASKS[1];
    println!("\n[3/3] transferring '{donor_task}' adapter weights to '{target}', training B+N only");
    coord.backbone("base")?;
    let mut shared = coord.backbones_get("base").unwrap().clone();
    let weight_names: Vec<String> = (0..layers)
        .map(|l| format!("encoder.layer.{l}.hadamard.weight"))
        .collect();
    shared.copy_from(&donor.store, &weight_names)?;

    let train_ds = coord.dataset(target, "train")?.clone();
    let dev_ds = coord.dataset(target, "dev")?.clone();
    let bn_only = Method::hadamard_ablation("B+N");
    let transferred = tune(
        &coord.engine, "base", &shared, &train_ds, &dev_ds, &bn_only, &opts,
    )?;

    // baseline: B+N from identity weights
    coord.backbone("base")?;
    let plain = coord.backbones_get("base").unwrap().clone();
    let scratch = tune(
        &coord.engine, "base", &plain, &train_ds, &dev_ds, &bn_only, &opts,
    )?;

    let full_method = tuned
        .iter()
        .find(|(t, _)| *t == target)
        .map(|(_, r)| r.score)
        .unwrap_or(0.0);
    println!("\n  {target} results:");
    println!("    full hadamard (W+B+N):        {full_method:.1}");
    println!("    B+N with transferred W:        {:.1}", transferred.score);
    println!("    B+N from identity W:           {:.1}", scratch.score);
    println!(
        "    trainable scalars (B+N only):  {} ({:.3}% of backbone)",
        transferred.trainable_scalars,
        100.0 * transferred.adapter_scalars as f64 / info.backbone_params() as f64
    );
    println!("\nShared-adapter transfer keeps the task performance while halving the adapter budget.");
    Ok(())
}
