#!/usr/bin/env python3
"""Crash-loop smoke for the durable bank lifecycle.

CI's "bank crash-loop smoke" step points this script at the release
binary and fails the build unless the bank's two durability invariants
hold under repeated SIGKILL and injected corruption:

1.  **The previous generation is always loadable.** The script runs
    `bank-build` / `bank-churn` / `bank-compact` in a loop, killing the
    process with SIGKILL at a random point inside each op's measured
    runtime. After every kill, `bank-scrub` must exit 0 on the bank
    path: same tenant count as the seed build, zero quarantined damage
    (a torn tail from a killed churn append is a benign crash artifact
    and scrubs clean). A kill landing after the op completed is fine —
    the round still has to scrub clean.
2.  **Quarantine is bounded by injected damage.** The script then flips
    K single bytes inside the tenant log (located from the file's own
    header: the centroid-region length is the u64 at byte offset 32, so
    the log starts at 48 + region_len; flips land in the first half of
    the log so at least one sits mid-log). `bank-scrub` must now exit
    nonzero with quarantined in [1, K] and at most K tenants lost —
    one flipped byte never costs more than one tenant. A final
    `bank-compact` must drop exactly the quarantined regions, bump the
    generation, and scrub clean.

Stdlib only. Exit code 0 on success, 1 with a diagnostic on any failure.

Usage:
  python3 tools/bank_crash_loop.py --binary ./target/release/hadapt \
      --tenants 1000 --rounds 12
"""

import argparse
import os
import random
import signal
import struct
import subprocess
import sys
import tempfile
import time


def fail(msg: str) -> None:
    print(f"bank_crash_loop: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def run(cmd, **kw):
    """Run to completion, returning (exit_code, stdout+stderr)."""
    p = subprocess.run(
        cmd, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True, **kw
    )
    return p.returncode, p.stdout


def run_killed(cmd, delay: float) -> bool:
    """Start `cmd`, SIGKILL it after `delay` seconds. Returns True if the
    kill landed while the process was still running."""
    p = subprocess.Popen(cmd, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    time.sleep(delay)
    landed = p.poll() is None
    if landed:
        os.kill(p.pid, signal.SIGKILL)
    p.wait()
    return landed


def scrub(binary: str, bank: str):
    """Run bank-scrub; return (exit_code, dict of the report key=values)."""
    code, out = run([binary, "bank-scrub", "--bank", bank])
    report = {}
    for line in out.splitlines():
        if line.startswith("bank-scrub:") and "=" in line:
            for tok in line.split()[1:]:
                k, _, v = tok.partition("=")
                report[k] = v
    if not report:
        fail(f"bank-scrub printed no report (exit {code}):\n{out}")
    return code, report


def require_clean(binary: str, bank: str, tenants: int, context: str):
    code, rep = scrub(binary, bank)
    if code != 0:
        fail(f"{context}: scrub must exit 0, got {code}: {rep}")
    if int(rep["tenants"]) != tenants:
        fail(f"{context}: expected {tenants} tenants, scrub saw {rep['tenants']}")
    if int(rep["quarantined"]) != 0:
        fail(f"{context}: kill-induced state must never quarantine: {rep}")
    return rep


def tenant_log_extent(bank: str):
    """(log_start, file_len) read from the bank's own header."""
    with open(bank, "rb") as f:
        header = f.read(48)
        file_len = os.fstat(f.fileno()).st_size
    if len(header) < 48 or header[:8] != b"HADBANK1":
        fail(f"{bank} does not start with a bank header")
    region_len = struct.unpack_from("<Q", header, 32)[0]
    return 48 + region_len, file_len


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--binary", default="./target/release/hadapt")
    ap.add_argument("--tenants", type=int, default=1000)
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--flips", type=int, default=3)
    ap.add_argument("--seed", type=int, default=20260808)
    args = ap.parse_args()
    rng = random.Random(args.seed)
    bank = os.path.join(tempfile.mkdtemp(prefix="hadapt_crash_loop_"), "fleet.bank")

    # ---- seed build + baseline op timings --------------------------------
    ops = {
        "bank-build": [
            args.binary, "bank-build", "--model", "tiny",
            "--tenants", str(args.tenants), "--out", bank,
        ],
        "bank-churn": [args.binary, "bank-churn", "--bank", bank, "--upserts", "200"],
        "bank-compact": [args.binary, "bank-compact", "--bank", bank],
    }
    base = {}
    for name, cmd in ops.items():
        t0 = time.monotonic()
        code, out = run(cmd)
        base[name] = max(time.monotonic() - t0, 0.02)
        if code != 0:
            fail(f"baseline {name} failed:\n{out}")
    require_clean(args.binary, bank, args.tenants, "baseline")
    print(
        "bank_crash_loop: baseline ok — "
        + " ".join(f"{k}={v * 1e3:.0f}ms" for k, v in base.items())
    )

    # ---- phase 1: SIGKILL each op at random points -----------------------
    names = list(ops)
    kills = 0
    for i in range(args.rounds):
        name = names[i % len(names)]
        delay = rng.uniform(0.0, base[name] * 1.1)
        landed = run_killed(ops[name], delay)
        kills += landed
        rep = require_clean(
            args.binary, bank, args.tenants,
            f"round {i} ({name}, killed at {delay * 1e3:.0f}ms, landed={landed})",
        )
        print(
            f"bank_crash_loop: round {i}: {name} kill@{delay * 1e3:.0f}ms "
            f"landed={landed} -> gen={rep['generation']} "
            f"tenants={rep['tenants']} torn_bytes={rep['torn_bytes']}"
        )
    if kills == 0:
        fail(f"no kill landed in {args.rounds} rounds — delays are mis-scaled")

    # ---- phase 2: injected corruption stays bounded ----------------------
    log_start, file_len = tenant_log_extent(bank)
    if log_start + 64 >= file_len:
        fail(f"tenant log too small to flip ({log_start}..{file_len})")
    span = (file_len - log_start) // 2  # first half: guaranteed mid-log
    offsets = rng.sample(range(log_start, log_start + span), args.flips)
    with open(bank, "r+b") as f:
        for off in offsets:
            f.seek(off)
            byte = f.read(1)[0]
            f.seek(off)
            f.write(bytes([byte ^ 0xFF]))
    code, rep = scrub(args.binary, bank)
    if code == 0:
        fail(f"scrub must flag injected mid-log corruption: {rep}")
    quarantined = int(rep["quarantined"])
    lost = args.tenants - int(rep["tenants"])
    if not 1 <= quarantined <= args.flips:
        fail(f"quarantine must be bounded by the {args.flips} flips: {rep}")
    if not 0 <= lost <= args.flips:
        fail(f"{args.flips} flipped bytes may cost at most {args.flips} tenants: {rep}")
    print(
        f"bank_crash_loop: {args.flips} flips -> quarantined={quarantined} "
        f"tenants_lost={lost} (blast radius bounded)"
    )

    # ---- phase 3: compact drops the quarantine and scrubs clean ----------
    code, out = run(ops["bank-compact"])
    if code != 0:
        fail(f"bank-compact must recover a quarantined bank:\n{out}")
    code, rep = scrub(args.binary, bank)
    if code != 0:
        fail(f"post-compact scrub must be clean: {rep}")
    if int(rep["quarantined"]) != 0 or int(rep["generation"]) < 1:
        fail(f"compact must drop the quarantine and bump the generation: {rep}")
    if int(rep["tenants"]) != args.tenants - lost:
        fail(f"compact must keep every surviving tenant: {rep}")
    print(
        f"bank_crash_loop: PASS — {kills}/{args.rounds} kills landed, "
        f"final gen={rep['generation']} tenants={rep['tenants']}"
    )


if __name__ == "__main__":
    main()
