#!/usr/bin/env python3
"""Prune orphaned run-cache files that predate the PR 2 injective id scheme.

The coordinator persists every completed run under `results/runs/` as
`<id>.json` (plus an optional `<id>.ckpt` checkpoint). PR 2 made run ids
injective in the method string by appending a 16-hex-digit FNV-1a tag to
the readable slug:

    <model>_<task>_<slug>-<16 hex>_s<seed>_t<stage1>x<main>

Files written by the pre-PR 2 scheme (no hash tag) can never be resumed
again — the coordinator computes only new-style ids — so they sit in the
cache as dead weight, and worse, they are exactly the files whose slugs
could collide (`had+ln` vs `had^ln`). This tool deletes them.

Default is a dry run: it lists what would be removed and exits non-zero
if orphans exist (useful as a CI hygiene check). Pass `--delete` to
actually remove the files.

Usage:
    python3 tools/prune_orphaned_runs.py [--runs-dir results/runs] [--delete]
"""

import argparse
import re
import sys
from pathlib import Path

# The PR 2 injective id: readable slug, '-', 16 hex digits of FNV-1a over
# the raw method string, then seed and step budgets.
MODERN_ID = re.compile(r"^.+-[0-9a-f]{16}_s\d+_t\d+x\d+$")

# Files the coordinator writes per run id.
RUN_SUFFIXES = (".json", ".ckpt")


def classify(path: Path):
    """Return (run_id, is_orphan) for a runs-dir file, or None to skip."""
    if path.suffix not in RUN_SUFFIXES or not path.is_file():
        return None
    run_id = path.name[: -len(path.suffix)]
    return run_id, MODERN_ID.match(run_id) is None


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--runs-dir",
        default="results/runs",
        help="run-cache directory (default: results/runs)",
    )
    ap.add_argument(
        "--delete",
        action="store_true",
        help="actually delete orphaned files (default: dry run)",
    )
    args = ap.parse_args()

    runs = Path(args.runs_dir)
    if not runs.is_dir():
        print(f"{runs}: no run cache (nothing to prune)")
        return 0

    orphans, kept = [], 0
    for path in sorted(runs.iterdir()):
        entry = classify(path)
        if entry is None:
            continue
        run_id, is_orphan = entry
        if is_orphan:
            orphans.append(path)
        else:
            kept += 1

    if not orphans:
        print(f"{runs}: {kept} cache file(s), all carry the injective id scheme")
        return 0

    verb = "deleting" if args.delete else "would delete"
    for path in orphans:
        print(f"{verb} {path} (pre-PR 2 run id: {path.stem!r})")
        if args.delete:
            path.unlink()
    print(
        f"{runs}: {len(orphans)} orphaned file(s) {'removed' if args.delete else 'found'}, "
        f"{kept} kept"
    )
    if not args.delete:
        print("dry run — pass --delete to remove them")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
