#!/usr/bin/env python3
"""Drive a running `hadapt serve-http` server and verify the wire contract.

CI's "wire ingress smoke" step starts the release binary, points this
script at it, and fails the build unless every assertion below holds:

1.  The server becomes ready (retried connects, ~10 s budget).
2.  Every fixture in the adversarial corpus (rust/tests/fixtures/wire/,
    named `<expected_code>__<desc>.raw`) replayed over its own
    connection is answered with the expected typed error code (or 200
    with logits for `ok` fixtures), and the server survives all of them.
3.  A pipelined happy-path burst (--requests requests in waves of
    --batch on one connection) is answered in order with 200s and
    parseable logits.
4.  /stats before vs after shows the steady-state zero-contracts hold
    *through the socket*: zero new arena misses, thread spawns,
    frozen-weight repacks and bank cold faults across the whole burst,
    and the reject counters account for exactly the non-ok fixtures.
5.  With --cold-tenants (a server started with --bank): each named
    tenant's first request faults and promotes it exactly once, and a
    second request serves it from the hot tier with no new fault.
6.  POST /shutdown answers 200 and the server exits (the caller waits
    on the process).

With --overload the steady-state phases are replaced by an overload
drill against a server started with --queue-cap/--tenant-rps/--window-us:
sustained Zipf-skewed bursts far past admitted capacity, asserting that
every request gets a *typed* outcome (200/429/503, zero unclassified),
that both throttling and shedding actually fired, that equally-offered
tenants keep fair goodput, and that the admitted path's zero-contracts
survive the abuse; SLO-honest results (admitted-only percentiles,
goodput vs offered) can be merged into BENCH_kernels.json via
--bench-out.

With --connections N (N > 1) a multi-connection phase runs first: N
persistent connections each send one timestamped request per round and
read their own reply, so per-request latency is honest (send-to-reply
per socket, not a shared-pipeline RTT) and the server sees N
simultaneous frames per batching window. Asserts zero unclassified
outcomes, no cross-connection reply bleed (each 200 carries its own
connection's task), /stats counter agreement including conns_accepted
and — on a batching server — cross_conn_waves, and the zero-contracts
through connection concurrency; admitted-only percentiles can be
merged into BENCH_kernels.json's `ingress_mc` section via --bench-out.

Stdlib only. Exit code 0 on success, 1 with a diagnostic on any failure.

Usage:
  python3 tools/wire_load.py --addr 127.0.0.1:8471 \
      --fixtures rust/tests/fixtures/wire --requests 64 --batch 8 \
      [--cold-tenants t000500,t000731]
  python3 tools/wire_load.py --addr 127.0.0.1:8473 --overload \
      --connections 8 --overload-duration 3 \
      [--bench-out BENCH_kernels.json]
"""

import argparse
import json
import os
import socket
import sys
import time

TASKS = ["sst2", "mrpc", "rte"]


def fail(msg):
    print(f"wire_load: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def connect(addr, timeout=5.0):
    s = socket.create_connection(addr, timeout=timeout)
    s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    return s


def wait_ready(addr, budget=10.0):
    """Bounded readiness probe. A bare connect() is not proof of life —
    the kernel accepts onto the listen backlog before the server thread
    serves, and an early request can then die with ConnectionResetError.
    Probe /healthz until a 200 comes back, retrying refused/reset/timeout
    (each on a fresh connection) within the budget."""
    deadline = time.monotonic() + budget
    while True:
        try:
            s = connect(addr, timeout=1.0)
            try:
                s.sendall(b"GET /healthz HTTP/1.1\r\n\r\n")
                s.settimeout(1.0)
                data = b""
                while b"\r\n\r\n" not in data:
                    chunk = s.recv(4096)
                    if not chunk:
                        raise ConnectionResetError("closed before /healthz answered")
                    data += chunk
                if b" 200 " not in data.split(b"\r\n", 1)[0]:
                    raise ConnectionResetError(f"healthz: {data[:64]!r}")
                return
            finally:
                s.close()
        except OSError:
            if time.monotonic() > deadline:
                fail(f"server at {addr[0]}:{addr[1]} never became ready")
            time.sleep(0.1)


def read_responses(sock, n):
    """Read exactly n Content-Length-framed responses: [(status, body)]."""
    buf = b""
    out = []
    while len(out) < n:
        while True:
            head_end = buf.find(b"\r\n\r\n")
            if head_end < 0:
                break
            head = buf[:head_end].decode("utf-8", "replace")
            cl = 0
            for line in head.split("\r\n")[1:]:
                k, _, v = line.partition(":")
                if k.strip().lower() == "content-length":
                    cl = int(v.strip())
            total = head_end + 4 + cl
            if len(buf) < total:
                break
            status = int(head.split(" ", 2)[1])
            out.append((status, buf[head_end + 4 : total].decode("utf-8", "replace")))
            buf = buf[total:]
            if len(out) == n:
                return out
        chunk = sock.recv(65536)
        if not chunk:
            fail(f"server closed after {len(out)} of {n} responses")
        buf += chunk
    return out


def roundtrip(addr, payload, half_close=False):
    s = connect(addr)
    s.sendall(payload)
    if half_close:
        s.shutdown(socket.SHUT_WR)
    resp = read_responses(s, 1)[0]
    s.close()
    return resp


def post(path, body=b""):
    head = f"POST {path} HTTP/1.1\r\nContent-Length: {len(body)}\r\n\r\n"
    return head.encode() + body


def infer(task, ids):
    body = json.dumps(
        {"task": task, "text_a": ids}, separators=(",", ":")
    ).encode()
    return post("/infer", body)


def get_stats(addr):
    status, body = roundtrip(addr, b"GET /stats HTTP/1.1\r\n\r\n")
    if status != 200:
        fail(f"/stats answered {status}: {body}")
    return json.loads(body)


def replay_corpus(addr, fixtures_dir):
    names = sorted(f for f in os.listdir(fixtures_dir) if f.endswith(".raw"))
    if len(names) < 30:
        fail(f"fixture corpus shrank: only {len(names)} fixtures in {fixtures_dir}")
    ok = err = 0
    for name in names:
        code = name.split("__")[0]
        with open(os.path.join(fixtures_dir, name), "rb") as f:
            raw = f.read()
        status, body = roundtrip(addr, raw, half_close=code.startswith("truncated"))
        if code == "ok":
            ok += 1
            if status != 200 or '"logits":[' not in body:
                fail(f"fixture {name}: expected 200 with logits, got {status}: {body}")
        else:
            err += 1
            if status == 200 or f'"error":"{code}"' not in body:
                fail(f"fixture {name}: expected code {code}, got {status}: {body}")
    print(f"wire_load: corpus OK ({ok} ok / {err} rejected, server survived)")
    return ok, err


def happy_burst(addr, requests, batch):
    s = connect(addr)
    served = 0
    wave_idx = 0
    while served < requests:
        n = min(batch, requests - served)
        payload = b"".join(
            infer(TASKS[(served + i) % len(TASKS)], [(served + i) * 7 % 512, 3, 11])
            for i in range(n)
        )
        s.sendall(payload)
        for status, body in read_responses(s, n):
            if status != 200:
                fail(f"burst wave {wave_idx}: status {status}: {body}")
            logits = json.loads(body).get("logits")
            if not isinstance(logits, list) or not logits:
                fail(f"burst wave {wave_idx}: unparseable logits: {body}")
        served += n
        wave_idx += 1
    s.close()
    print(f"wire_load: burst OK ({served} requests in {wave_idx} waves of {batch})")


def cold_tenant_phase(addr, cold):
    """First touch of each cold tenant faults+promotes exactly once; the
    second touch is a hot hit with no new fault."""
    s_before = get_stats(addr)
    for i, task in enumerate(cold):
        status, body = roundtrip(addr, infer(task, [7 + i, 3, 11]))
        if status != 200 or '"logits":[' not in body:
            fail(f"cold tenant {task}: expected 200 with logits, got {status}: {body}")
    s_mid = get_stats(addr)
    faults = s_mid["bank_cold_faults"] - s_before["bank_cold_faults"]
    promos = s_mid["bank_promotions"] - s_before["bank_promotions"]
    if faults != len(cold) or promos != len(cold):
        fail(
            f"first touch of {len(cold)} cold tenants should fault+promote each "
            f"exactly once, got faults +{faults}, promotions +{promos}"
        )
    for i, task in enumerate(cold):
        status, body = roundtrip(addr, infer(task, [9 + i, 5, 13]))
        if status != 200:
            fail(f"hot re-touch of {task}: status {status}: {body}")
    s_after = get_stats(addr)
    if s_after["bank_cold_faults"] != s_mid["bank_cold_faults"]:
        fail("re-touching promoted tenants must not fault again")
    if s_after["bank_hot_hits"] <= s_mid["bank_hot_hits"]:
        fail("re-touching promoted tenants must register hot hits")
    if s_after["bank_resident_bytes"] != s_mid["bank_resident_bytes"]:
        fail("hot re-touches must not change resident bytes")
    print(
        f"wire_load: bank OK ({len(cold)} cold tenants faulted+promoted once, "
        "then served hot)"
    )


def multi_conn_phase(addr, connections, requests, bench_out):
    """Drive N persistent connections concurrently: each round sends one
    timestamped request on every connection, then reads every
    connection's single reply, so the server holds N open sockets with
    simultaneous in-flight frames. Asserts the multi-connection
    contract:

    * every reply is typed — 200 with logits, or (when pointed at an
      overload-configured server) 429 tenant-throttled / 503
      queue-full; zero unclassified outcomes;
    * no cross-connection reply bleed: each 200 carries the task its
      own connection asked for;
    * /stats accounts for the traffic — the reply/reject deltas match
      the observed outcomes, `conns_accepted` covers all N
      connections, nothing was refused at the accept tier, and on a
      batching server (window_us > 0) at least one wave mixed rows
      from different connections (`cross_conn_waves` advanced);
    * the admitted path's zero-contracts (arena misses, thread spawns,
      repacks, bank cold faults) survive connection concurrency.

    Admitted-only latency percentiles (timestamped per request, not
    per wave) can be merged into `bench_out`'s `ingress_mc` section
    when given."""
    socks = [connect(addr) for _ in range(connections)]
    # warm every connection's slot and the engine before snapshotting
    for i, s in enumerate(socks):
        s.sendall(infer(TASKS[i % len(TASKS)], [5 + i, 6, 7]))
    for s in socks:
        read_responses(s, 1)
    s0 = get_stats(addr)

    rounds = max(1, (requests + connections - 1) // connections)
    ok = throttled = shed = other = 0
    bled = 0
    lats = []
    t0 = time.monotonic()
    for r in range(rounds):
        sent_at = []
        for i, s in enumerate(socks):
            task = TASKS[(r + i) % len(TASKS)]
            sent_at.append(time.monotonic())
            s.sendall(infer(task, [3 + (r * 7 + i) % 500, 11, 13]))
        for i, s in enumerate(socks):
            task = TASKS[(r + i) % len(TASKS)]
            status, body = read_responses(s, 1)[0]
            lat = time.monotonic() - sent_at[i]
            if status == 200:
                ok += 1
                lats.append(lat)
                if f'"task":"{task}"' not in body:
                    bled += 1
            elif status == 429 and '"error":"tenant-throttled"' in body:
                throttled += 1
            elif status == 503 and '"error":"queue-full"' in body:
                shed += 1
            else:
                other += 1
    wall = max(time.monotonic() - t0, 1e-9)
    s1 = get_stats(addr)
    for s in socks:
        s.close()

    offered = rounds * connections
    if other:
        fail(f"{other} of {offered} multi-conn requests got an untyped outcome")
    if bled:
        fail(f"reply bleed: {bled} replies carried another connection's task")
    if ok < connections:
        fail(f"multi-conn phase starved: only {ok} of {offered} admitted")
    dr = s1["replies"] - s0["replies"]
    if dr != ok:
        fail(f"reply counter drifted: server saw +{dr} for {ok} observed 200s")
    drej = (s1["rejects_throttle"] - s0["rejects_throttle"]) + (
        s1["rejects_shed"] - s0["rejects_shed"]
    )
    if drej != throttled + shed:
        fail(
            f"reject counters drifted: +{drej} on the server for "
            f"{throttled + shed} observed 429/503s"
        )
    if s1["conns_accepted"] < connections:
        fail(
            f"conns_accepted {s1['conns_accepted']} cannot cover "
            f"{connections} live connections"
        )
    if s1["conns_rejected"] != s0["conns_rejected"]:
        fail("the accept tier refused a connection under its own limit")
    if s1["conns_open"] < connections:
        fail(
            f"conns_open {s1['conns_open']} while {connections} "
            "connections are still held open"
        )
    waves = s1["cross_conn_waves"] - s0["cross_conn_waves"]
    if connections > 1 and s1["window_us"] > 0 and waves < 1:
        fail(
            f"{connections} connections against a {s1['window_us']} us "
            "batching window never produced a cross-connection wave"
        )
    for key in ("arena_misses", "pool_threads_spawned", "repacks", "bank_cold_faults"):
        delta = s1[key] - s0[key]
        if delta != 0:
            fail(f"multi-conn broke a steady-state contract: {key} grew by {delta}")

    lats.sort()
    pct = lambda q: lats[min(int(len(lats) * q), len(lats) - 1)] * 1e3
    rows = {
        "provenance": "measured",
        "connections": connections,
        "req_per_s": round(ok / wall),
        "p50_ms": round(pct(0.50), 3),
        "p99_ms": round(pct(0.99), 3),
        "p999_ms": round(pct(0.999), 3),
        "conns_accepted": s1["conns_accepted"],
        "conns_rejected": s1["conns_rejected"],
        "cross_conn_waves": waves,
        # the allocator contract is pinned by the in-tree test
        # (tests/workspace_alloc.rs::steady_multi_conn_loop); this
        # driver only re-asserts its observable proxies above
        "mc_steady_allocs": 0,
    }
    print(
        f"wire_load: multi-conn OK ({connections} conns, {offered} offered, "
        f"{ok} admitted at {rows['req_per_s']}/s, 429s {throttled}, "
        f"503s {shed}, cross_conn_waves +{waves}, p99 {rows['p99_ms']}ms)"
    )
    if bench_out:
        try:
            with open(bench_out) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            doc = {}
        doc["ingress_mc"] = rows
        with open(bench_out, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"wire_load: ingress_mc rows merged into {bench_out}")


def overload_phase(addr, duration, bench_out):
    """Offer the front door several times its admitted capacity — deep
    Zipf-skewed pipelined bursts (36 heavy-tenant + 6 + 6 light per 48)
    against the bounded queue and per-tenant buckets — and assert the
    overload contract:

    * every request gets a *typed* outcome (200 / 429 tenant-throttled /
      503 queue-full); zero unclassified errors;
    * both degradation modes actually fired (>=1 throttle, >=1 shed);
    * the server's throttle/shed counters account for each observed one;
    * the two equally-offered light tenants end within 20% of each
      other's goodput (weighted fairness, not luck);
    * the admitted steady path stayed on its zero-contracts (no arena
      misses, thread spawns, repacks or cold faults) through the abuse.

    Reports SLO-honest numbers — percentiles over admitted replies only,
    goodput next to offered load — and merges them into `bench_out`'s
    `overload` section when given."""
    # warm the engine (arena, workers, packs) with one in-budget wave per
    # tenant before snapshotting the zero-contract counters
    s = connect(addr)
    s.sendall(b"".join(infer(t, [5, 6, 7]) for t in TASKS))
    read_responses(s, len(TASKS))
    s.close()
    s0 = get_stats(addr)

    burst_tasks = [
        "mrpc" if i % 8 == 6 else "rte" if i % 8 == 7 else "sst2" for i in range(48)
    ]
    payload = b"".join(
        infer(t, [3 + i % 29, 7, 11]) for i, t in enumerate(burst_tasks)
    )
    ok = throttled = shed = other = 0
    goodput = {t: 0 for t in TASKS}
    lats = []
    rounds = 0
    s = connect(addr)
    t0 = time.monotonic()
    while time.monotonic() - t0 < duration:
        tw = time.monotonic()
        s.sendall(payload)
        resp = read_responses(s, len(burst_tasks))
        rtt = time.monotonic() - tw
        rounds += 1
        for (status, body), task in zip(resp, burst_tasks):
            if status == 200:
                ok += 1
                goodput[task] += 1
                lats.append(rtt)
            elif status == 429:
                throttled += 1
                if '"error":"tenant-throttled"' not in body or '"retry_after_ms":' not in body:
                    fail(f"429 without typed throttle body: {body}")
            elif status == 503:
                shed += 1
                if '"error":"queue-full"' not in body:
                    fail(f"503 without typed queue-full body: {body}")
            else:
                other += 1
    wall = max(time.monotonic() - t0, 1e-9)
    s.close()
    s1 = get_stats(addr)

    offered = rounds * len(burst_tasks)
    if other:
        fail(f"{other} of {offered} overload requests got an untyped outcome")
    if throttled < 1 or shed < 1:
        fail(f"overload never tripped both modes: 429s={throttled} 503s={shed}")
    if ok < 1:
        fail("overload starved every request; goodput should survive")
    dt = s1["rejects_throttle"] - s0["rejects_throttle"]
    ds = s1["rejects_shed"] - s0["rejects_shed"]
    if dt != throttled or ds != shed:
        fail(
            f"reject counters drifted: server saw +{dt} throttles/+{ds} sheds "
            f"for {throttled}/{shed} observed"
        )
    for key in ("arena_misses", "pool_threads_spawned", "repacks", "bank_cold_faults"):
        delta = s1[key] - s0[key]
        if delta != 0:
            fail(f"overload broke a steady-state contract: {key} grew by {delta}")
    gm, gr = goodput["mrpc"], goodput["rte"]
    fair_dev = abs(gm - gr) / max((gm + gr) / 2.0, 1.0)
    if fair_dev > 0.2:
        fail(f"equal-weight tenants diverged: mrpc {gm} vs rte {gr} ({fair_dev:.2f})")

    lats.sort()
    pct = lambda q: lats[min(int(len(lats) * q), len(lats) - 1)] * 1e3
    rows = {
        "provenance": "measured",
        "offered_rps": round(offered / wall),
        "goodput_rps": round(ok / wall),
        "p50_ms": round(pct(0.50), 3),
        "p99_ms": round(pct(0.99), 3),
        "p999_ms": round(pct(0.999), 3),
        "throttled_429": throttled,
        "shed_503": shed,
        "unclassified_errors": other,
        "fair_dev": round(fair_dev, 3),
        "window_us": s1["window_us"],
        "queue_cap": s1["queue_cap"],
        "tenant_rps": s1["tenant_rps"],
    }
    print(
        f"wire_load: overload OK ({offered} offered at {rows['offered_rps']}/s, "
        f"goodput {rows['goodput_rps']}/s, 429s {throttled}, 503s {shed}, "
        f"p99 {rows['p99_ms']}ms, fair_dev {rows['fair_dev']})"
    )
    if bench_out:
        try:
            with open(bench_out) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            doc = {}
        doc["overload"] = rows
        with open(bench_out, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"wire_load: overload rows merged into {bench_out}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--addr", default="127.0.0.1:8471")
    ap.add_argument("--fixtures", default="rust/tests/fixtures/wire")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument(
        "--connections",
        type=int,
        default=1,
        help="with N > 1, run the multi-connection phase first: N "
        "persistent connections sending timestamped concurrent waves, "
        "asserting typed outcomes, no reply bleed, conns_accepted/"
        "cross_conn_waves accounting and the zero-contracts",
    )
    ap.add_argument(
        "--cold-tenants",
        default="",
        help="comma-separated tenant names expected to be cold in the server's "
        "bank file: each must fault in exactly once, then serve hot",
    )
    ap.add_argument(
        "--overload",
        action="store_true",
        help="run the overload phase instead of the steady-state phases: "
        "point this at a server started with --queue-cap/--tenant-rps/"
        "--window-us and assert typed 429/503 degradation",
    )
    ap.add_argument(
        "--overload-duration",
        type=float,
        default=3.0,
        help="seconds of sustained overload bursts",
    )
    ap.add_argument(
        "--bench-out",
        default="",
        help="merge the overload rows into this BENCH_kernels.json",
    )
    args = ap.parse_args()
    host, _, port = args.addr.rpartition(":")
    addr = (host, int(port))

    wait_ready(addr)

    if args.connections > 1:
        multi_conn_phase(addr, args.connections, args.requests, args.bench_out)

    if args.overload:
        overload_phase(addr, args.overload_duration, args.bench_out)
        status, body = roundtrip(addr, post("/shutdown"))
        if status != 200 or '"shutting_down":true' not in body:
            fail(f"/shutdown answered {status}: {body}")
        print("wire_load: PASS — overload degraded typed, server drained cleanly")
        return
    # warm everything (arena, workers, packs, connection buffers) before
    # snapshotting the zero-contract counters
    happy_burst(addr, args.batch, args.batch)
    s0 = get_stats(addr)

    ok_n, err_n = replay_corpus(addr, args.fixtures)
    happy_burst(addr, args.requests, args.batch)
    s1 = get_stats(addr)

    for key in ("arena_misses", "pool_threads_spawned", "repacks", "bank_cold_faults"):
        delta = s1[key] - s0[key]
        if delta != 0:
            fail(f"steady-state contract broken over the wire: {key} grew by {delta}")
    rejects = sum(
        s1[k] - s0[k] for k in ("rejects_http", "rejects_parse", "rejects_submit")
    )
    if rejects != err_n:
        fail(f"reject counters drifted: {rejects} new rejects for {err_n} bad fixtures")
    replies = s1["replies"] - s0["replies"]
    if replies < args.requests + ok_n:
        fail(f"reply counter drifted: {replies} < {args.requests + ok_n}")

    cold = [t for t in args.cold_tenants.split(",") if t]
    if cold:
        cold_tenant_phase(addr, cold)

    status, body = roundtrip(addr, post("/shutdown"))
    if status != 200 or '"shutting_down":true' not in body:
        fail(f"/shutdown answered {status}: {body}")
    print(
        "wire_load: PASS — zero-contracts held over the wire "
        f"(replies +{replies}, rejects +{rejects}, arena/spawn/repack deltas 0)"
    )


if __name__ == "__main__":
    main()
