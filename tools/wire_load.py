#!/usr/bin/env python3
"""Drive a running `hadapt serve-http` server and verify the wire contract.

CI's "wire ingress smoke" step starts the release binary, points this
script at it, and fails the build unless every assertion below holds:

1.  The server becomes ready (retried connects, ~10 s budget).
2.  Every fixture in the adversarial corpus (rust/tests/fixtures/wire/,
    named `<expected_code>__<desc>.raw`) replayed over its own
    connection is answered with the expected typed error code (or 200
    with logits for `ok` fixtures), and the server survives all of them.
3.  A pipelined happy-path burst (--requests requests in waves of
    --batch on one connection) is answered in order with 200s and
    parseable logits.
4.  /stats before vs after shows the steady-state zero-contracts hold
    *through the socket*: zero new arena misses, thread spawns,
    frozen-weight repacks and bank cold faults across the whole burst,
    and the reject counters account for exactly the non-ok fixtures.
5.  With --cold-tenants (a server started with --bank): each named
    tenant's first request faults and promotes it exactly once, and a
    second request serves it from the hot tier with no new fault.
6.  POST /shutdown answers 200 and the server exits (the caller waits
    on the process).

Stdlib only. Exit code 0 on success, 1 with a diagnostic on any failure.

Usage:
  python3 tools/wire_load.py --addr 127.0.0.1:8471 \
      --fixtures rust/tests/fixtures/wire --requests 64 --batch 8 \
      [--cold-tenants t000500,t000731]
"""

import argparse
import json
import os
import socket
import sys
import time

TASKS = ["sst2", "mrpc", "rte"]


def fail(msg):
    print(f"wire_load: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def connect(addr, timeout=5.0):
    s = socket.create_connection(addr, timeout=timeout)
    s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    return s


def wait_ready(addr, budget=10.0):
    deadline = time.monotonic() + budget
    while True:
        try:
            connect(addr, timeout=1.0).close()
            return
        except OSError:
            if time.monotonic() > deadline:
                fail(f"server at {addr[0]}:{addr[1]} never became ready")
            time.sleep(0.1)


def read_responses(sock, n):
    """Read exactly n Content-Length-framed responses: [(status, body)]."""
    buf = b""
    out = []
    while len(out) < n:
        while True:
            head_end = buf.find(b"\r\n\r\n")
            if head_end < 0:
                break
            head = buf[:head_end].decode("utf-8", "replace")
            cl = 0
            for line in head.split("\r\n")[1:]:
                k, _, v = line.partition(":")
                if k.strip().lower() == "content-length":
                    cl = int(v.strip())
            total = head_end + 4 + cl
            if len(buf) < total:
                break
            status = int(head.split(" ", 2)[1])
            out.append((status, buf[head_end + 4 : total].decode("utf-8", "replace")))
            buf = buf[total:]
            if len(out) == n:
                return out
        chunk = sock.recv(65536)
        if not chunk:
            fail(f"server closed after {len(out)} of {n} responses")
        buf += chunk
    return out


def roundtrip(addr, payload, half_close=False):
    s = connect(addr)
    s.sendall(payload)
    if half_close:
        s.shutdown(socket.SHUT_WR)
    resp = read_responses(s, 1)[0]
    s.close()
    return resp


def post(path, body=b""):
    head = f"POST {path} HTTP/1.1\r\nContent-Length: {len(body)}\r\n\r\n"
    return head.encode() + body


def infer(task, ids):
    body = json.dumps(
        {"task": task, "text_a": ids}, separators=(",", ":")
    ).encode()
    return post("/infer", body)


def get_stats(addr):
    status, body = roundtrip(addr, b"GET /stats HTTP/1.1\r\n\r\n")
    if status != 200:
        fail(f"/stats answered {status}: {body}")
    return json.loads(body)


def replay_corpus(addr, fixtures_dir):
    names = sorted(f for f in os.listdir(fixtures_dir) if f.endswith(".raw"))
    if len(names) < 30:
        fail(f"fixture corpus shrank: only {len(names)} fixtures in {fixtures_dir}")
    ok = err = 0
    for name in names:
        code = name.split("__")[0]
        with open(os.path.join(fixtures_dir, name), "rb") as f:
            raw = f.read()
        status, body = roundtrip(addr, raw, half_close=code.startswith("truncated"))
        if code == "ok":
            ok += 1
            if status != 200 or '"logits":[' not in body:
                fail(f"fixture {name}: expected 200 with logits, got {status}: {body}")
        else:
            err += 1
            if status == 200 or f'"error":"{code}"' not in body:
                fail(f"fixture {name}: expected code {code}, got {status}: {body}")
    print(f"wire_load: corpus OK ({ok} ok / {err} rejected, server survived)")
    return ok, err


def happy_burst(addr, requests, batch):
    s = connect(addr)
    served = 0
    wave_idx = 0
    while served < requests:
        n = min(batch, requests - served)
        payload = b"".join(
            infer(TASKS[(served + i) % len(TASKS)], [(served + i) * 7 % 512, 3, 11])
            for i in range(n)
        )
        s.sendall(payload)
        for status, body in read_responses(s, n):
            if status != 200:
                fail(f"burst wave {wave_idx}: status {status}: {body}")
            logits = json.loads(body).get("logits")
            if not isinstance(logits, list) or not logits:
                fail(f"burst wave {wave_idx}: unparseable logits: {body}")
        served += n
        wave_idx += 1
    s.close()
    print(f"wire_load: burst OK ({served} requests in {wave_idx} waves of {batch})")


def cold_tenant_phase(addr, cold):
    """First touch of each cold tenant faults+promotes exactly once; the
    second touch is a hot hit with no new fault."""
    s_before = get_stats(addr)
    for i, task in enumerate(cold):
        status, body = roundtrip(addr, infer(task, [7 + i, 3, 11]))
        if status != 200 or '"logits":[' not in body:
            fail(f"cold tenant {task}: expected 200 with logits, got {status}: {body}")
    s_mid = get_stats(addr)
    faults = s_mid["bank_cold_faults"] - s_before["bank_cold_faults"]
    promos = s_mid["bank_promotions"] - s_before["bank_promotions"]
    if faults != len(cold) or promos != len(cold):
        fail(
            f"first touch of {len(cold)} cold tenants should fault+promote each "
            f"exactly once, got faults +{faults}, promotions +{promos}"
        )
    for i, task in enumerate(cold):
        status, body = roundtrip(addr, infer(task, [9 + i, 5, 13]))
        if status != 200:
            fail(f"hot re-touch of {task}: status {status}: {body}")
    s_after = get_stats(addr)
    if s_after["bank_cold_faults"] != s_mid["bank_cold_faults"]:
        fail("re-touching promoted tenants must not fault again")
    if s_after["bank_hot_hits"] <= s_mid["bank_hot_hits"]:
        fail("re-touching promoted tenants must register hot hits")
    if s_after["bank_resident_bytes"] != s_mid["bank_resident_bytes"]:
        fail("hot re-touches must not change resident bytes")
    print(
        f"wire_load: bank OK ({len(cold)} cold tenants faulted+promoted once, "
        "then served hot)"
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--addr", default="127.0.0.1:8471")
    ap.add_argument("--fixtures", default="rust/tests/fixtures/wire")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument(
        "--cold-tenants",
        default="",
        help="comma-separated tenant names expected to be cold in the server's "
        "bank file: each must fault in exactly once, then serve hot",
    )
    args = ap.parse_args()
    host, _, port = args.addr.rpartition(":")
    addr = (host, int(port))

    wait_ready(addr)
    # warm everything (arena, workers, packs, connection buffers) before
    # snapshotting the zero-contract counters
    happy_burst(addr, args.batch, args.batch)
    s0 = get_stats(addr)

    ok_n, err_n = replay_corpus(addr, args.fixtures)
    happy_burst(addr, args.requests, args.batch)
    s1 = get_stats(addr)

    for key in ("arena_misses", "pool_threads_spawned", "repacks", "bank_cold_faults"):
        delta = s1[key] - s0[key]
        if delta != 0:
            fail(f"steady-state contract broken over the wire: {key} grew by {delta}")
    rejects = sum(
        s1[k] - s0[k] for k in ("rejects_http", "rejects_parse", "rejects_submit")
    )
    if rejects != err_n:
        fail(f"reject counters drifted: {rejects} new rejects for {err_n} bad fixtures")
    replies = s1["replies"] - s0["replies"]
    if replies < args.requests + ok_n:
        fail(f"reply counter drifted: {replies} < {args.requests + ok_n}")

    cold = [t for t in args.cold_tenants.split(",") if t]
    if cold:
        cold_tenant_phase(addr, cold)

    status, body = roundtrip(addr, post("/shutdown"))
    if status != 200 or '"shutting_down":true' not in body:
        fail(f"/shutdown answered {status}: {body}")
    print(
        "wire_load: PASS — zero-contracts held over the wire "
        f"(replies +{replies}, rejects +{rejects}, arena/spawn/repack deltas 0)"
    )


if __name__ == "__main__":
    main()
