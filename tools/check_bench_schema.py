#!/usr/bin/env python3
"""Validate the schema of BENCH_kernels.json (committed or bench-written).

The file is a contract between `cargo bench --bench bench_runtime` (the
writer), the README's "how to read this" section, and anyone tracking the
kernel-perf trajectory in-tree. Rows may be populated from a real run
(provenance=measured) or projected (see the file's provenance note), but
the shape must always match what the bench writes.

Since PR 4 the file also carries the persistent-pool dispatch rows: a
top-level "pool" section (empty-job round trips, per-step spawn/job
counters, its own provenance label), `scoped_ms`/`persistent_ms`/
`dispatch_speedup` columns in every matmul row, and
`pool_steady_spawns`/`pool_steady_jobs` in every train_step row.

Since PR 5 it also carries a top-level "serve" section: the multi-tenant
serve path's throughput/latency rows at micro-batch sizes 1/8/32 plus the
adapter-swap economics and its own steady-state counters.

Since PR 6 it also carries a top-level "ingress" section: the wire front
door — pull-parser nanoseconds per request body, socket-to-logits
throughput/latency rows at wave sizes 1/8/32 through a real WireServer,
and the serve zero-contracts re-asserted over the wire via /stats.

Since PR 7 it also carries a top-level "bank" section: the tiered
adapter bank — fleet size, on-disk compression ratio vs dense per-tenant
storage, cold-fault p99 and the hot-hit rate of a Zipf replay, plus the
hot-resident steady allocation counter.

Since PR 9 it also carries a top-level "bank_lifecycle" section: the
durable-bank maintenance path — clean-open vs salvage-open (one flipped
mid-log byte) milliseconds, scrub throughput in MB/s, online-compaction
milliseconds and reclaimed bytes after a churn at fleet scale, and the
steady allocation counter across the generation swap.

Since PR 8 it also carries a top-level "overload" section: the front
door offered several times its admitted capacity — SLO-honest latency
percentiles over admitted replies only, goodput vs offered load, typed
429/503 counts, fairness deviation between equally-offered tenants, and
the policy knobs (queue_cap/window_us/tenant_rps) the run used. Written
by the bench, overwritten by `tools/wire_load.py --overload --bench-out`.

Since PR 10 it also carries a top-level "ingress_mc" section: the
multi-connection front door — N concurrent persistent connections
multiplexed into the single serve thread, per-request (timestamped,
admitted-only) latency percentiles, accept-tier counters, the number of
waves that mixed rows from different connections, and the steady
allocation counter across four-connection concurrent traffic. Written
by the bench, overwritten by `tools/wire_load.py --connections N
--bench-out`.

Zero-contracts enforced (all counters, not measurements): steady-state
arena misses, steady-state pool spawns, the serve and ingress paths'
steady-state arena misses / pool spawns / repacks, and the bank's
hot-resident steady allocations must all be 0. The bank's compression
ratio must be at least 10 (the tiered format's acceptance floor). The
overload section's unclassified_errors must be 0 (every overloaded
request gets a typed outcome) and fair_dev at most 0.2. The
bank_lifecycle section's compact_steady_allocs must be 0 (serving across
an online generation swap allocates nothing) and its generation at
least 1 (the compact actually committed a new image). The ingress_mc
section's mc_steady_allocs must be 0 (the multi-connection steady path
never touches the heap — pinned in-tree by
tests/workspace_alloc.rs::steady_multi_conn_loop), its connections at
least 2 (otherwise it measured nothing multi), and its
cross_conn_waves at least 1 (waves actually mixed connections).

Every section and key is documented in docs/BENCH_SCHEMA.md.

Usage: python3 tools/check_bench_schema.py BENCH_kernels.json
"""

import json
import sys

FWD_KEYS = {
    "scalar_ms",
    "blocked_ms",
    "parallel_ms",
    "packed_ms",
    "speedup_blocked",
    "speedup_parallel",
    "speedup_packed",
    "packed_vs_parallel",
}
STEP_KEYS = {
    "scalar_ms",
    "parallel_ms",
    "packed_ms",
    "speedup_parallel",
    "speedup_packed",
    "packed_vs_parallel",
    "arena_steady_hits",
    "arena_steady_misses",
    "packed_weights",
    "pool_steady_spawns",
    "pool_steady_jobs",
}
MM_KEYS = {
    "scalar_ms",
    "blocked_ms",
    "parallel_ms",
    "scoped_ms",
    "persistent_ms",
    "packed_ms",
    "pack_once_ms",
    "bias_gelu_separate_ms",
    "bias_gelu_fused_ms",
    "speedup_blocked",
    "speedup_parallel",
    "speedup_packed",
    "fused_vs_separate",
    "dispatch_speedup",
}
SERVE_KEYS = {
    "tasks",
    "adapter_scalars_per_task",
    "adapter_swap_us",
    "full_reupload_ms",
    "swap_vs_reupload",
    "steady_arena_misses",
    "steady_pool_spawns",
    "steady_repacks",
}
SERVE_ROW_KEYS = {
    "batch",
    "p50_ms",
    "p99_ms",
    "req_per_s",
}
INGRESS_KEYS = {
    "tasks",
    "parse_ns_per_request",
    "steady_arena_misses",
    "steady_pool_spawns",
    "steady_repacks",
}
INGRESS_ROW_KEYS = {
    "batch",
    "p50_ms",
    "p99_ms",
    "req_per_s",
}
BANK_KEYS = {
    "tenants",
    "compression_ratio",
    "cold_fault_us_p99",
    "hot_hit_rate",
    "steady_hot_allocs",
}
BANK_LIFECYCLE_KEYS = {
    "tenants",
    "clean_open_ms",
    "salvage_open_ms",
    "scrub_mb_per_s",
    "compact_ms",
    "compact_upserts",
    "reclaimed_bytes",
    "generation",
    "compact_steady_allocs",
}
OVERLOAD_KEYS = {
    "offered_rps",
    "goodput_rps",
    "p50_ms",
    "p99_ms",
    "p999_ms",
    "throttled_429",
    "shed_503",
    "unclassified_errors",
    "fair_dev",
    "window_us",
    "queue_cap",
    "tenant_rps",
}
INGRESS_MC_KEYS = {
    "connections",
    "req_per_s",
    "p50_ms",
    "p99_ms",
    "p999_ms",
    "conns_accepted",
    "conns_rejected",
    "cross_conn_waves",
    "mc_steady_allocs",
}
POOL_KEYS = {
    "threads",
    "empty_job_persistent_ns",
    "empty_job_scoped_ns",
    "dispatch_ns",
    "dispatch_speedup",
    "jobs_per_step",
    "wakeups_per_step",
    "spawns_steady_per_step",
    "scoped_spawns_per_step_est",
    "pool_spawns",
}


def fail(msg):
    print(
        f"BENCH_kernels.json schema error: {msg} "
        "(see docs/BENCH_SCHEMA.md for the full schema)",
        file=sys.stderr,
    )
    sys.exit(1)


def check_rows(section, rows, required):
    if not isinstance(rows, dict):
        fail(f"'{section}' must be an object")
    for name, row in rows.items():
        if not isinstance(row, dict):
            fail(f"{section}.{name} must be an object")
        missing = required - set(row)
        if missing:
            fail(f"{section}.{name} missing keys: {sorted(missing)}")
        for key in required:
            if not isinstance(row[key], (int, float)):
                fail(f"{section}.{name}.{key} must be a number")
            if key.endswith("_ms") and row[key] < 0:
                fail(f"{section}.{name}.{key} must be non-negative")


def check_pool(pool):
    if not isinstance(pool, dict):
        fail("'pool' must be an object")
    if not isinstance(pool.get("provenance"), str) or not pool["provenance"]:
        fail("pool.provenance must be a non-empty string label")
    missing = POOL_KEYS - set(pool)
    if missing:
        fail(f"pool missing keys: {sorted(missing)}")
    for key in POOL_KEYS:
        if not isinstance(pool[key], (int, float)):
            fail(f"pool.{key} must be a number")
        if pool[key] < 0:
            fail(f"pool.{key} must be non-negative")
    # the zero-spawn steady state is a contract, not a measurement
    if pool["spawns_steady_per_step"] != 0:
        fail("pool.spawns_steady_per_step must be 0 (zero-spawn steady state)")


def check_serve(serve):
    if not isinstance(serve, dict):
        fail("'serve' must be an object")
    if not isinstance(serve.get("provenance"), str) or not serve["provenance"]:
        fail("serve.provenance must be a non-empty string label")
    if not isinstance(serve.get("model"), str) or not serve["model"]:
        fail("serve.model must name the benchmarked model")
    missing = SERVE_KEYS - set(serve)
    if missing:
        fail(f"serve missing keys: {sorted(missing)}")
    for key in SERVE_KEYS:
        if not isinstance(serve[key], (int, float)):
            fail(f"serve.{key} must be a number")
        if serve[key] < 0:
            fail(f"serve.{key} must be non-negative")
    rows = serve.get("rows")
    if not isinstance(rows, dict) or not rows:
        fail("serve.rows must be a non-empty object of per-batch-size rows")
    check_rows("serve.rows", rows, SERVE_ROW_KEYS)
    # the serve path inherits every steady-state zero-contract
    for key in ("steady_arena_misses", "steady_pool_spawns", "steady_repacks"):
        if serve[key] != 0:
            fail(f"serve.{key} must be 0 (serve-path steady-state contract)")


def check_ingress(ingress):
    if not isinstance(ingress, dict):
        fail("'ingress' must be an object")
    if not isinstance(ingress.get("provenance"), str) or not ingress["provenance"]:
        fail("ingress.provenance must be a non-empty string label")
    if not isinstance(ingress.get("model"), str) or not ingress["model"]:
        fail("ingress.model must name the benchmarked model")
    missing = INGRESS_KEYS - set(ingress)
    if missing:
        fail(f"ingress missing keys: {sorted(missing)}")
    for key in INGRESS_KEYS:
        if not isinstance(ingress[key], (int, float)):
            fail(f"ingress.{key} must be a number")
        if ingress[key] < 0:
            fail(f"ingress.{key} must be non-negative")
    rows = ingress.get("rows")
    if not isinstance(rows, dict) or not rows:
        fail("ingress.rows must be a non-empty object of per-wave-size rows")
    check_rows("ingress.rows", rows, INGRESS_ROW_KEYS)
    # the wire front door inherits the serve path's steady-state contracts
    for key in ("steady_arena_misses", "steady_pool_spawns", "steady_repacks"):
        if ingress[key] != 0:
            fail(f"ingress.{key} must be 0 (wire-ingress steady-state contract)")


def check_bank(bank):
    if not isinstance(bank, dict):
        fail("'bank' must be an object")
    if not isinstance(bank.get("provenance"), str) or not bank["provenance"]:
        fail("bank.provenance must be a non-empty string label")
    if not isinstance(bank.get("model"), str) or not bank["model"]:
        fail("bank.model must name the benchmarked model")
    missing = BANK_KEYS - set(bank)
    if missing:
        fail(f"bank missing keys: {sorted(missing)}")
    for key in BANK_KEYS:
        if not isinstance(bank[key], (int, float)):
            fail(f"bank.{key} must be a number")
        if bank[key] < 0:
            fail(f"bank.{key} must be non-negative")
    if not 0 <= bank["hot_hit_rate"] <= 1:
        fail("bank.hot_hit_rate must be a fraction in [0, 1]")
    # contracts, not measurements: the hot-resident steady state is
    # allocation-free, and the tiered format must beat dense 10x
    if bank["steady_hot_allocs"] != 0:
        fail("bank.steady_hot_allocs must be 0 (hot-resident zero-alloc contract)")
    if bank["compression_ratio"] < 10:
        fail("bank.compression_ratio must be >= 10 (tiered-format acceptance floor)")


def check_bank_lifecycle(life):
    if not isinstance(life, dict):
        fail("'bank_lifecycle' must be an object")
    if not isinstance(life.get("provenance"), str) or not life["provenance"]:
        fail("bank_lifecycle.provenance must be a non-empty string label")
    if not isinstance(life.get("model"), str) or not life["model"]:
        fail("bank_lifecycle.model must name the benchmarked model")
    missing = BANK_LIFECYCLE_KEYS - set(life)
    if missing:
        fail(f"bank_lifecycle missing keys: {sorted(missing)}")
    for key in BANK_LIFECYCLE_KEYS:
        if not isinstance(life[key], (int, float)):
            fail(f"bank_lifecycle.{key} must be a number")
        if life[key] < 0:
            fail(f"bank_lifecycle.{key} must be non-negative")
    # contracts, not measurements: the generation swap is invisible to
    # the serve path, and the compact must have actually committed
    if life["compact_steady_allocs"] != 0:
        fail(
            "bank_lifecycle.compact_steady_allocs must be 0 "
            "(zero-alloc serving across the online generation swap)"
        )
    if life["generation"] < 1:
        fail("bank_lifecycle.generation must be >= 1 (the compact committed)")


def check_overload(overload):
    if not isinstance(overload, dict):
        fail("'overload' must be an object")
    if not isinstance(overload.get("provenance"), str) or not overload["provenance"]:
        fail("overload.provenance must be a non-empty string label")
    missing = OVERLOAD_KEYS - set(overload)
    if missing:
        fail(f"overload missing keys: {sorted(missing)}")
    for key in OVERLOAD_KEYS:
        if not isinstance(overload[key], (int, float)):
            fail(f"overload.{key} must be a number")
        if overload[key] < 0:
            fail(f"overload.{key} must be non-negative")
    # contracts, not measurements: overload degrades typed and fair
    if overload["unclassified_errors"] != 0:
        fail("overload.unclassified_errors must be 0 (typed-degradation contract)")
    if overload["fair_dev"] > 0.2:
        fail("overload.fair_dev must be <= 0.2 (equal-weight fairness contract)")
    if overload["throttled_429"] < 1 or overload["shed_503"] < 1:
        fail("overload must exercise both degradation modes (>=1 429 and >=1 503)")
    if overload["goodput_rps"] > overload["offered_rps"]:
        fail("overload.goodput_rps cannot exceed offered_rps")


def check_ingress_mc(mc):
    if not isinstance(mc, dict):
        fail("'ingress_mc' must be an object")
    if not isinstance(mc.get("provenance"), str) or not mc["provenance"]:
        fail("ingress_mc.provenance must be a non-empty string label")
    missing = INGRESS_MC_KEYS - set(mc)
    if missing:
        fail(f"ingress_mc missing keys: {sorted(missing)}")
    for key in INGRESS_MC_KEYS:
        if not isinstance(mc[key], (int, float)):
            fail(f"ingress_mc.{key} must be a number")
        if mc[key] < 0:
            fail(f"ingress_mc.{key} must be non-negative")
    # contracts, not measurements: the multi-connection steady path is
    # allocation-free, and the run must actually have been multi
    if mc["mc_steady_allocs"] != 0:
        fail(
            "ingress_mc.mc_steady_allocs must be 0 (multi-connection "
            "zero-alloc contract, pinned by steady_multi_conn_loop)"
        )
    if mc["connections"] < 2:
        fail("ingress_mc.connections must be >= 2 (single-conn runs prove nothing)")
    if mc["cross_conn_waves"] < 1:
        fail(
            "ingress_mc.cross_conn_waves must be >= 1 "
            "(waves never mixed rows from different connections)"
        )


def main(path):
    with open(path) as f:
        data = json.load(f)
    for key in (
        "note",
        "provenance",
        "batch",
        "seq_len",
        "forward",
        "train_step",
        "matmul",
        "pool",
        "serve",
        "ingress",
        "bank",
        "bank_lifecycle",
        "overload",
        "ingress_mc",
    ):
        if key not in data:
            fail(f"missing top-level key '{key}'")
    check_rows("forward", data["forward"], FWD_KEYS)
    check_rows("train_step", data["train_step"], STEP_KEYS)
    check_rows("matmul", data["matmul"], MM_KEYS)
    check_pool(data["pool"])
    check_serve(data["serve"])
    check_ingress(data["ingress"])
    check_bank(data["bank"])
    check_bank_lifecycle(data["bank_lifecycle"])
    check_overload(data["overload"])
    check_ingress_mc(data["ingress_mc"])
    # steady-state misses/spawns are the zero-overhead contracts
    for name, row in data["train_step"].items():
        if row["arena_steady_misses"] != 0:
            fail(f"train_step.{name}.arena_steady_misses must be 0 (zero-alloc steady state)")
        if row["pool_steady_spawns"] != 0:
            fail(f"train_step.{name}.pool_steady_spawns must be 0 (zero-spawn steady state)")
    n_rows = (
        sum(len(data[s]) for s in ("forward", "train_step", "matmul"))
        + len(data["serve"]["rows"])
        + len(data["ingress"]["rows"])
        + 5  # pool, bank, bank_lifecycle, overload and ingress_mc: one row each
    )
    print(
        f"BENCH_kernels.json schema OK ({n_rows} rows, "
        f"provenance: {str(data['provenance'])[:40]}..., "
        f"pool provenance: {data['pool']['provenance'][:40]})"
    )


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "BENCH_kernels.json")
