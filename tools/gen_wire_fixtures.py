#!/usr/bin/env python3
"""Generate the adversarial wire fixture corpus (rust/tests/fixtures/wire).

Each fixture is a full raw HTTP/1.1 request byte string named
``<expected_code>__<description>.raw`` — the part before the first ``__``
is the exact error code (``WireError::code()`` / ``JsonError::code()``)
the server must answer with, or ``ok`` for requests that must serve.
``rust/tests/wire_parser.rs`` asserts the code twice: once against a
unit-level classifier mirroring the server's routing, once end-to-end
over a real socket.

Conventions the tests rely on:

* ``Content-Length`` is byte-exact unless the fixture name says
  otherwise (the two ``bad-content-length`` fixtures and the declared
  over-length ones).
* fixtures whose code starts with ``truncated`` are *incomplete by
  design*: the client sends the bytes, half-closes the write side, and
  the server must answer the truncation error instead of hanging.
* happy fixtures only use token ids < 64 so they stay inside every
  manifest model's vocabulary, and only the tasks the test harness
  registers (sst2, rte).

Deterministic: running it twice produces identical bytes. Stdlib only.
"""

import json
import os


def jbody(obj):
    return json.dumps(obj, separators=(",", ":")).encode()


def req(body, method=b"POST", target=b"/infer", version=b"HTTP/1.1", headers=None, cl=None):
    """Build a raw request. cl: None = exact, False = omit, else literal."""
    head = [method + b" " + target + b" " + version]
    if cl is None:
        head.append(b"Content-Length: " + str(len(body)).encode())
    elif cl is not False:
        head.append(b"Content-Length: " + str(cl).encode())
    for h in headers or []:
        head.append(h)
    return b"\r\n".join(head) + b"\r\n\r\n" + body


FIXTURES = {
    # -- happy path ---------------------------------------------------------
    "ok__minimal": req(jbody({"task": "sst2", "text_a": [5, 6, 7]})),
    # 2 is '2': the unescape scratch path must still admit to "sst2"
    "ok__escaped_task_pair": req(
        b'{"task":"sst\\u0032","text_a":[4,5],"text_b":[6]}'
    ),
    "ok__null_text_b": req(jbody({"task": "rte", "text_a": [9], "text_b": None})),
    # -- framing ------------------------------------------------------------
    "bad-request-line__garbage": b"garbage\r\n\r\n",
    "bad-version__http09": req(
        jbody({"task": "sst2", "text_a": [1]}), version=b"HTTP/0.9"
    ),
    "bad-header__missing_colon": req(b"", headers=[b"X-Weird"]),
    "bad-content-length__negative": req(b"", cl=b"-5".decode()),
    "bad-content-length__alpha": req(b"", cl="12abc"),
    "unsupported-transfer-encoding__chunked": req(
        b"", headers=[b"Transfer-Encoding: chunked"], cl=False
    ),
    "head-too-large__5k_header": req(b"", headers=[b"X-Pad: " + b"a" * 5000]),
    "body-too-large__giant_content_length": req(b"", cl=10_000_000),
    "truncated-head__half_closed_mid_head": b"POST /infer HTTP/1.1\r\nContent-",
    "truncated-body__half_closed_short_body": req(b'{"task":"s', cl=500),
    # -- routing ------------------------------------------------------------
    "unknown-route__post_predict": req(
        jbody({"task": "sst2", "text_a": [1]}), target=b"/predict"
    ),
    "method-not-allowed__get_infer": req(b"", method=b"GET"),
    # -- JSON grammar -------------------------------------------------------
    "json-eof__truncated_object": req(b'{"task":"sst2","text_a":[5'),
    "json-byte__nan_literal": req(b'{"task":"sst2","text_a":[NaN]}'),
    "json-nonfinite__exp_overflow": req(b'{"task":"sst2","text_a":[1e999]}'),
    "json-escape__unknown_escape": req(b'{"task":"a\\q","text_a":[1]}'),
    "json-utf8__raw_ff_in_task": req(b'{"task":"\xff","text_a":[1]}'),
    "json-trailing__second_document": req(b'{"task":"sst2","text_a":[1]}{}'),
    # -- request shape ------------------------------------------------------
    "not-an-object__deep_array_nesting": req(b"[" * 100),
    "bad-field-type__nested_text_a": req(jbody({"task": "sst2", "text_a": [[1]]})),
    "bad-field-type__task_number": req(jbody({"task": 7, "text_a": [1]})),
    "duplicate-field__task_twice": req(b'{"task":"a","task":"b","text_a":[1]}'),
    "unknown-field__extra_key": req(
        jbody({"task": "sst2", "text_a": [1], "mode": "fast"})
    ),
    "missing-task__only_text": req(jbody({"text_a": [1]})),
    "missing-text__only_task": req(jbody({"task": "sst2"})),
    "token-not-integer__fractional": req(jbody({"task": "sst2", "text_a": [1.5]})),
    "token-out-of-range__huge_number": req(
        jbody({"task": "sst2", "text_a": [3000000000]})
    ),
    "too-many-tokens__flood": req(jbody({"task": "sst2", "text_a": [1] * 5000})),
    # -- admission ----------------------------------------------------------
    "unknown-task__unregistered_tenant": req(
        jbody({"task": "not-a-task", "text_a": [1]})
    ),
    "token-out-of-vocab__negative_id": req(jbody({"task": "sst2", "text_a": [-4]})),
}


def main():
    out = os.path.normpath(
        os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "rust", "tests", "fixtures", "wire")
    )
    os.makedirs(out, exist_ok=True)
    for name, data in sorted(FIXTURES.items()):
        path = os.path.join(out, name + ".raw")
        with open(path, "wb") as f:
            f.write(data)
        print(f"{len(data):>6} bytes  {name}.raw")
    print(f"{len(FIXTURES)} fixtures -> {out}")


if __name__ == "__main__":
    main()
