//! Integration: the Rust runtime on the native backend (tiny model).
//!
//! Hermetic: the builtin manifest supplies the model inventory and the
//! `NativeBackend` evaluates every artifact in pure Rust — no Python, no
//! `make artifacts`, no network. (With `--features xla` the same suite
//! semantics hold on the PJRT path via `Engine::xla`.)

use hadapt::data::{class_mask, generate, make_batch, task_info};
use hadapt::model::{FreezeMask, ParamStore};
use hadapt::optim::LrSchedule;
use hadapt::runtime::{Engine, Manifest};
use hadapt::train::{evaluate, Session};

fn engine() -> Engine {
    Engine::native().expect("native engine")
}

#[test]
fn manifest_loads_and_is_consistent() {
    let e = engine();
    let m = e.manifest();
    assert_eq!(m.batch, 16);
    assert_eq!(m.seq_len, 32);
    let tiny = m.model("tiny").unwrap();
    assert!(tiny.total_params() > 0);
    // every artifact's grad params exist in its model
    for a in m.artifacts.values() {
        let model = m.model(&a.model).unwrap();
        for g in a.grad_params() {
            assert!(model.param_index(g).is_ok(), "{g} in {}", a.name);
        }
    }
    // groups cover what they claim
    let full = tiny.group("full").unwrap();
    assert!(full.iter().all(|n| !n.contains(".hadamard.")));
    let had = tiny.group("hadamard").unwrap();
    assert!(had.iter().any(|n| n.ends_with(".hadamard.weight")));
}

#[test]
fn forward_artifact_runs_and_probes_shape() {
    let e = engine();
    let info = e.manifest().model("tiny").unwrap().clone();
    let store = ParamStore::init(&info, 42);
    let ds = generate(task_info("sst2").unwrap(), 7, "dev", 48);
    let r = evaluate(&e, "tiny", &store, &ds).unwrap();
    assert_eq!(r.examples, 48);
    assert_eq!(r.preds.len(), 48);
    assert_eq!(r.attn_norms.len(), info.layers);
    assert_eq!(r.attn_norms[0].len(), 48);
    // untrained model should be near chance but must produce a valid score
    assert!(r.score >= 0.0 && r.score <= 100.0);
    // attention norms are positive
    assert!(r.attn_norms[0].iter().all(|&x| x > 0.0));
}

#[test]
fn identity_adapters_do_not_change_logits() {
    // Perturbing LoRA-A (B=0) and Houlsby-down (up=0) must leave the
    // forward output bit-identical; perturbing hadamard.bias must change it.
    let e = engine();
    let info = e.manifest().model("tiny").unwrap().clone();
    let store = ParamStore::init(&info, 42);
    let ds = generate(task_info("rte").unwrap(), 3, "dev", 16);
    let base = evaluate(&e, "tiny", &store, &ds).unwrap();

    let mut s2 = store.clone();
    for t in s2.get_mut("encoder.layer.0.lora.query.a").unwrap().data.iter_mut() {
        *t += 1.0;
    }
    for t in s2
        .get_mut("encoder.layer.0.houlsby.attn.down.weight")
        .unwrap()
        .data
        .iter_mut()
    {
        *t += 1.0;
    }
    let same = evaluate(&e, "tiny", &s2, &ds).unwrap();
    assert_eq!(base.preds, same.preds);
    assert_eq!(base.attn_means, same.attn_means);

    let mut s3 = store.clone();
    for t in s3.get_mut("encoder.layer.0.hadamard.bias").unwrap().data.iter_mut() {
        *t += 0.5;
    }
    let diff = evaluate(&e, "tiny", &s3, &ds).unwrap();
    assert_ne!(base.attn_means, diff.attn_means);
}

#[test]
fn train_step_decreases_loss_and_respects_mask() {
    let e = engine();
    let info = e.manifest().model("tiny").unwrap().clone();
    let store = ParamStore::init(&info, 1);
    let frozen_snapshot = store.clone();

    let ds = generate(task_info("sst2").unwrap(), 5, "train", 64);
    let cm = class_mask(2);
    let mask = FreezeMask::from_names(
        &info,
        &info.group("hadamard").unwrap().to_vec(),
    );
    let artifact = Manifest::train_name("cls", "hadamard", "tiny");
    let mut session = Session::new(
        &e,
        &artifact,
        store,
        mask,
        LrSchedule::constant(5e-3),
    )
    .unwrap();

    let idx: Vec<usize> = (0..16).collect();
    let b = make_batch(&ds, &idx, 16, 32);
    let first = session.step_cls(&b, &cm).unwrap();
    let mut last = first;
    for _ in 0..15 {
        last = session.step_cls(&b, &cm).unwrap();
    }
    assert!(
        last < first,
        "loss should decrease on a fixed batch: {first} -> {last}"
    );

    let tuned = session.into_store();
    // frozen params identical
    for (name, (a, b)) in tuned
        .names
        .iter()
        .zip(tuned.tensors.iter().zip(&frozen_snapshot.tensors))
    {
        let in_group = info.group("hadamard").unwrap().contains(name);
        if !in_group {
            assert_eq!(a, b, "frozen param '{name}' changed");
        }
    }
    // hadamard params moved
    let moved = tuned
        .get("encoder.layer.0.hadamard.bias")
        .unwrap()
        .data
        .iter()
        .any(|&x| x != 0.0);
    assert!(moved, "hadamard bias never updated");
}

#[test]
fn regression_artifact_runs() {
    let e = engine();
    let info = e.manifest().model("tiny").unwrap().clone();
    let store = ParamStore::init(&info, 2);
    let ds = generate(task_info("stsb").unwrap(), 9, "train", 32);
    let mask = FreezeMask::from_names(&info, &info.group("head").unwrap().to_vec());
    let artifact = Manifest::train_name("reg", "head", "tiny");
    let mut session =
        Session::new(&e, &artifact, store, mask, LrSchedule::constant(3e-3)).unwrap();
    let idx: Vec<usize> = (0..16).collect();
    let b = make_batch(&ds, &idx, 16, 32);
    let first = session.step_reg(&b).unwrap();
    let mut last = first;
    for _ in 0..10 {
        last = session.step_reg(&b).unwrap();
    }
    assert!(last < first, "reg loss: {first} -> {last}");
}

#[test]
fn mlm_pretraining_reduces_loss() {
    let e = engine();
    let opts = hadapt::train::PretrainOpts {
        steps: 80,
        lr: 5e-3,
        warmup: 10,
        seed: 77,
        log_every: 0,
    };
    let r = hadapt::train::pretrain(&e, "tiny", &opts).unwrap();
    let first = r.losses[0];
    // average the tail to smooth batch noise
    let tail: f32 =
        r.losses[60..].iter().sum::<f32>() / (r.losses.len() - 60) as f32;
    // ln(512) ~ 6.24 at init. 80 steps is far from convergence (the full
    // pre-training runs 600-1500 steps); the meaningful bound here is the
    // marginal-unigram floor ~6.22 — dropping below it requires using
    // context, which proves gradients flow through the whole stack
    // (Pallas custom VJPs included).
    assert!(first > 5.0, "first {first}");
    assert!(tail < 6.21, "mlm loss {first} -> tail {tail} (unigram floor not crossed)");
    assert!(tail < first - 0.02, "mlm loss {first} -> tail {tail}");
}
