//! Integration: the full two-stage tuning pipeline on the tiny model,
//! exercising coordinator, methods, masks, sessions and eval end-to-end —
//! hermetically, on the native backend (no `make artifacts` needed).

use hadapt::config::Config;
use hadapt::coordinator::{Coordinator, RunSpec};
use hadapt::methods::Method;
use hadapt::runtime::Engine;
use hadapt::train::{tune, PretrainOpts, TuneOpts};

fn test_config(tag: &str) -> Config {
    let mut cfg = Config::default();
    cfg.artifacts_dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").into();
    cfg.checkpoints_dir =
        std::env::temp_dir().join(format!("hadapt_it_{tag}_ckpt"));
    cfg.results_dir = std::env::temp_dir().join(format!("hadapt_it_{tag}_res"));
    cfg.models = vec!["tiny".into()];
    cfg.quick = true;
    cfg.pretrain_steps = 80;
    cfg.pretrain_lr = 5e-3;
    cfg
}

#[test]
fn two_stage_hadamard_beats_frozen_backbone() {
    let cfg = test_config("two_stage");
    let _ = std::fs::remove_dir_all(&cfg.checkpoints_dir);
    let _ = std::fs::remove_dir_all(&cfg.results_dir);
    let mut coord = Coordinator::new(cfg).unwrap();

    let spec = RunSpec {
        model: "tiny".into(),
        task: "sst2".into(),
        method: "hadamard".into(),
        seed: coord.config.seed,
    };
    let rec = coord.run(&spec).unwrap();
    // quick budgets: just verify the pipeline trains and scores validly
    assert!(rec.score >= 0.0 && rec.score <= 100.0);
    assert!(rec.trainable_scalars > 0);
    // the paper's efficiency claim holds structurally even at tiny scale:
    // adapter params are a small fraction of the backbone
    assert!(
        rec.param_fraction < 0.05,
        "adapter fraction {}",
        rec.param_fraction
    );
    // second call hits the cache (same id)
    let rec2 = coord.run(&spec).unwrap();
    assert_eq!(rec.score, rec2.score);
}

#[test]
fn methods_have_ordered_param_budgets() {
    let engine =
        Engine::native().unwrap();
    let info = engine.manifest().model("tiny").unwrap();
    let frac = |m: Method| m.param_fraction(info).unwrap();
    let hadamard = frac(Method::hadamard());
    let bitfit = frac(Method::bitfit());
    let houlsby = frac(Method::houlsby());
    let full = 1.0;
    // paper Table 3 ordering: hadamard < bitfit-ish < houlsby << full.
    assert!(hadamard < houlsby, "hadamard {hadamard} houlsby {houlsby}");
    assert!(hadamard < full);
    assert!(bitfit < houlsby);
    // headline magnitude: hadamard trains < 2% even on the tiny model
    // (0.033% at BERT scale; fraction grows as models shrink)
    assert!(hadamard < 0.02, "hadamard fraction {hadamard}");
}

#[test]
fn layer_ablation_trains_fewer_params() {
    let engine =
        Engine::native().unwrap();
    let info = engine.manifest().model("tiny").unwrap();
    let k1 = Method::by_name("hadamard@1L").unwrap();
    let full = Method::hadamard();
    let a = k1.adapter_params(info).unwrap();
    let b = full.adapter_params(info).unwrap();
    assert!(a < b, "{a} !< {b}");
    // exactly layers-proportional for the adapter+norm vectors
    assert_eq!(a * info.layers, b);
}

#[test]
fn single_stage_baselines_run() {
    let cfg = test_config("baselines");
    let _ = std::fs::remove_dir_all(&cfg.checkpoints_dir);
    let _ = std::fs::remove_dir_all(&cfg.results_dir);
    let mut coord = Coordinator::new(cfg).unwrap();
    for method in ["bitfit", "lora", "ia3"] {
        let rec = coord
            .run(&RunSpec {
                model: "tiny".into(),
                task: "rte".into(),
                method: method.into(),
                seed: coord.config.seed,
            })
            .unwrap();
        assert!(rec.score >= 0.0 && rec.score <= 100.0, "{method}");
    }
}

#[test]
fn tune_directly_with_quick_opts() {
    let engine =
        Engine::native().unwrap();
    let opts = PretrainOpts { steps: 40, lr: 5e-3, warmup: 5, seed: 3, log_every: 0 };
    let backbone = hadapt::train::pretrain(&engine, "tiny", &opts).unwrap().store;
    let train_ds = hadapt::data::generate(
        hadapt::data::task_info("stsb").unwrap(), 3, "train", 128);
    let dev_ds = hadapt::data::generate(
        hadapt::data::task_info("stsb").unwrap(), 3, "dev", 64);
    let r = tune(
        &engine,
        "tiny",
        &backbone,
        &train_ds,
        &dev_ds,
        &Method::hadamard(),
        &TuneOpts::quick(),
    )
    .unwrap();
    // regression path end-to-end: Pearson in [-100, 100], losses recorded
    assert!(r.score.abs() <= 100.0);
    assert_eq!(r.stage1_losses.len(), 20);
    assert_eq!(r.main_losses.len(), 40);
    // stage-2 must not have trained the head (paper: reload + freeze)
    assert!(r.trainable_scalars < backbone.total_scalars() / 10);
}
