//! Tiered-bank persistence contracts, end to end:
//!
//! 1. **Tier transparency**: a session paging tenants through a small
//!    LRU hot tier over the on-disk bank returns **bitwise** the logits
//!    of a flat in-memory bank holding every tenant — reconstruction
//!    (centroid + delta rows) is exact for the ε=0 encoding, and the
//!    fault/evict machinery never leaks into the math.
//! 2. **Compression**: a Zipf-clustered synthetic fleet (duplicates,
//!    single-layer deviations, full tunes — the shape the paper's
//!    redundant-layer finding predicts) stores at ≥10x below the naive
//!    per-tenant scalar total, and cold reads reconstruct tenants
//!    bitwise.
//! 3. **Crash safety**: truncating an upsert at *every* byte boundary
//!    still reloads, and always yields the last committed state; a
//!    corrupt byte anywhere in the appended record is caught by its
//!    checksum and falls back the same way.
//! 4. **Determinism**: promotion/eviction order, slot assignment and the
//!    tier counters are identical across repeated runs, and eviction
//!    provably skips pinned slots.

use std::fs;
use std::path::PathBuf;

use hadapt::model::ParamStore;
use hadapt::runtime::{
    synthetic_adapters, synthetic_tenant, AdapterBank, BankBuilder, BankGeometry, BankReader,
    DamageKind, Engine, ServeRequest, ServeSession, TaskAdapter,
};

fn engine2() -> Engine {
    Engine::new_with_threads("/definitely/not/a/dir", 2).unwrap()
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("hadapt_bankp_{}_{tag}.bank", std::process::id()))
}

/// Every float of every family as raw bits, in a fixed family order —
/// one flat value to compare two adapters bitwise (`-0.0` vs `0.0` and
/// exact payloads included).
fn adapter_bits(a: &TaskAdapter) -> Vec<u32> {
    let mut out = Vec::new();
    for fam in [&a.had_w, &a.had_b, &a.norm_w, &a.norm_b] {
        for row in fam.iter() {
            out.extend(row.iter().map(|x| x.to_bits()));
        }
    }
    for flat in [&a.pooler_w, &a.pooler_b, &a.cls_w, &a.cls_b] {
        out.extend(flat.iter().map(|x| x.to_bits()));
    }
    out
}

fn tiny_geom(engine: &Engine) -> BankGeometry {
    let info = engine.manifest().model("tiny").unwrap();
    let classes = info.params[info.param_index("classifier.bias").unwrap()].shape[0];
    BankGeometry { layers: info.layers, hidden: info.hidden, classes }
}

/// A hand-shaped adapter at an arbitrary mini geometry (no engine
/// involved) for the byte-level crash-safety test.
fn mini(g: &BankGeometry, name: &str, fill: f32) -> TaskAdapter {
    TaskAdapter {
        task: name.to_string(),
        classes: g.classes,
        had_w: vec![vec![fill; g.hidden]; g.layers],
        had_b: vec![vec![fill * 0.5; g.hidden]; g.layers],
        norm_w: vec![vec![1.0; g.hidden]; g.layers],
        norm_b: vec![vec![0.0; g.hidden]; g.layers],
        pooler_w: vec![fill; g.hidden * g.hidden],
        pooler_b: vec![0.0; g.hidden],
        cls_w: vec![fill; g.hidden * g.classes],
        cls_b: vec![0.0; g.classes],
    }
}

#[test]
fn tiered_serve_is_bitwise_identical_to_a_flat_bank() {
    let engine = engine2();
    let seed = 71;
    let info = engine.manifest().model("tiny").unwrap().clone();
    let store = ParamStore::init(&info, seed);
    let base_tasks = vec!["sst2".to_string(), "mrpc".to_string(), "rte".to_string()];
    let bases = synthetic_adapters(&info, &store, &base_tasks, seed).unwrap();
    let fleet: Vec<TaskAdapter> =
        (0..12).map(|i| synthetic_tenant(&bases, i, seed)).collect();

    let path = tmp("roundtrip");
    let mut builder = BankBuilder::new(tiny_geom(&engine), bases.clone(), 0.0).unwrap();
    for t in &fleet {
        builder.add_tenant(t).unwrap();
    }
    let summary = builder.write(&path).unwrap();
    assert_eq!(summary.tenants, fleet.len());

    // 12 tenants through a 4-slot hot tier (= the wave size) vs all 12
    // resident in a flat bank
    let mut tiered = ServeSession::new(&engine, "tiny", &store, 4).unwrap();
    tiered.attach_store(BankReader::open(&path).unwrap(), 4).unwrap();
    let mut flat = ServeSession::new(&engine, "tiny", &store, 4).unwrap();
    for t in &fleet {
        flat.register_task(t.clone()).unwrap();
    }

    // three rounds over the whole fleet in wave-sized chunks (submission
    // resolves tenants immediately, so a wave can pin at most the hot
    // tier's 4 slots): every round churns the LRU, so the stream
    // constantly mixes hot hits, faults and evictions
    for round in 0..3usize {
        for chunk in fleet.iter().enumerate().collect::<Vec<_>>().chunks(4) {
            for &(i, t) in chunk {
                let req = ServeRequest {
                    task: t.task.clone(),
                    seq_a: (0..5 + (i + round) % 4)
                        .map(|j| 3 + ((i * 31 + round * 7 + j * 11) % 500) as i32)
                        .collect(),
                    seq_b: (i % 2 == 0).then(|| vec![9 + i as i32, 17, 23]),
                };
                tiered.submit(req.clone()).unwrap();
                flat.submit(req).unwrap();
            }
            let got = tiered.run_pending().unwrap();
            let want = flat.run_pending().unwrap();
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.task, w.task, "round {round}");
                assert_eq!(g.label, w.label, "round {round} task {}", g.task);
                let gb: Vec<u32> = g.logits.iter().map(|x| x.to_bits()).collect();
                let wb: Vec<u32> = w.logits.iter().map(|x| x.to_bits()).collect();
                assert_eq!(
                    gb, wb,
                    "round {round} task {}: paged reconstruction must be bitwise",
                    g.task
                );
            }
        }
    }

    let stats = tiered.bank().bank_stats();
    assert!(stats.cold_faults > 0, "a 4-slot tier over 12 tenants must fault");
    assert!(stats.evictions > 0, "and recycle slots");
    assert_eq!(stats.promotions, stats.cold_faults, "every fault promotes");
    assert!(tiered.bank().len() <= 4, "hot tier stays capped");
    assert_eq!(tiered.bank().tenant_count(), 12, "both tiers together serve the fleet");
    assert!(
        tiered.bank().resident_bytes() < flat.bank().resident_bytes(),
        "the tiered bank must hold fewer bytes resident than the flat bank"
    );
    let flat_stats = flat.bank().bank_stats();
    assert_eq!((flat_stats.cold_faults, flat_stats.evictions), (0, 0));
    fs::remove_file(&path).ok();
}

/// Regression: the owned `submit` path must resolve (and fault in) the
/// tenant at submit time, exactly like `submit_borrowed` — an unknown
/// task rejects immediately instead of poisoning the whole wave at
/// `run_pending`, and a queued row pins a *slot*, not a name.
#[test]
fn owned_submit_resolves_and_rejects_at_submit_time() {
    let engine = engine2();
    let seed = 83;
    let info = engine.manifest().model("tiny").unwrap().clone();
    let store = ParamStore::init(&info, seed);
    let base_tasks = vec!["sst2".to_string(), "mrpc".to_string(), "rte".to_string()];
    let bases = synthetic_adapters(&info, &store, &base_tasks, seed).unwrap();
    let path = tmp("submit_time");
    let mut builder = BankBuilder::new(tiny_geom(&engine), bases.clone(), 0.0).unwrap();
    for i in 0..6 {
        builder.add_tenant(&synthetic_tenant(&bases, i, seed)).unwrap();
    }
    builder.write(&path).unwrap();

    let mut session = ServeSession::new(&engine, "tiny", &store, 4).unwrap();
    session.attach_store(BankReader::open(&path).unwrap(), 4).unwrap();
    let req = |task: &str| ServeRequest {
        task: task.to_string(),
        seq_a: vec![4, 5, 6],
        seq_b: None,
    };

    // an unknown task fails at submit — the error arrives before any
    // neighbor row is dragged into a failing wave
    let err = session.submit(req("not-a-tenant")).unwrap_err();
    assert!(err.to_string().contains("no adapter in either tier"), "{err}");

    // a cold tenant faults in *at submit*: the queue holds a resolved,
    // pinned slot from that point on
    let before = session.bank().bank_stats().cold_faults;
    session.submit(req("t000004")).unwrap();
    assert_eq!(
        session.bank().bank_stats().cold_faults,
        before + 1,
        "resolution (and the cold fault) happens at submit time"
    );
    session.submit(req("t000005")).unwrap();

    // the earlier rejection cost nothing: both admitted rows serve
    let replies = session.run_pending().unwrap();
    assert_eq!(replies.len(), 2);
    assert_eq!(replies[0].task, "t000004");
    assert_eq!(replies[1].task, "t000005");
    fs::remove_file(&path).ok();
}

#[test]
fn zipf_fleet_bank_compresses_at_least_10x_and_reads_back_bitwise() {
    let engine = engine2();
    let seed = 1234;
    let info = engine.manifest().model("tiny").unwrap().clone();
    let store = ParamStore::init(&info, seed);
    let base_tasks = vec!["sst2".to_string(), "mrpc".to_string(), "rte".to_string()];
    let bases = synthetic_adapters(&info, &store, &base_tasks, seed).unwrap();

    let n = 1000usize;
    let path = tmp("zipf");
    let mut builder = BankBuilder::new(tiny_geom(&engine), bases.clone(), 0.0).unwrap();
    for i in 0..n {
        builder.add_tenant(&synthetic_tenant(&bases, i, seed)).unwrap();
    }
    let summary = builder.write(&path).unwrap();
    assert_eq!(summary.tenants, n);
    assert_eq!(
        summary.naive_scalars,
        (n * bases[0].scalars()) as u64,
        "naive accounting is logical scalars × tenants"
    );
    assert!(
        summary.compression_ratio >= 10.0,
        "fleet must store <10% of the dense total, got {:.2}x over {} bytes",
        summary.compression_ratio,
        summary.file_bytes
    );

    // cold reads reconstruct exactly what the generator produced
    let mut reader = BankReader::open(&path).unwrap();
    assert_eq!(reader.len(), n);
    for idx in [0usize, 2, 17, 500, n - 1] {
        let want = synthetic_tenant(&bases, idx, seed);
        let mut got = reader.blank_adapter();
        reader.read_into(&want.task, &mut got).unwrap();
        assert_eq!(got.task, want.task);
        assert_eq!(got.classes, want.classes);
        assert_eq!(adapter_bits(&got), adapter_bits(&want), "tenant {idx}");
    }
    fs::remove_file(&path).ok();
}

#[test]
fn torn_upsert_always_reloads_the_last_committed_state() {
    let g = BankGeometry { layers: 2, hidden: 4, classes: 2 };
    let base = mini(&g, "base", 1.0);
    let mut old = mini(&g, "t1", 1.0);
    old.had_b[1][2] = -0.75; // deviates, so the record carries delta rows
    let path = tmp("torn_src");
    let mut builder = BankBuilder::new(g, vec![base], 0.0).unwrap();
    builder.add_tenant(&old).unwrap();
    builder.write(&path).unwrap();

    // shadow t1 through the reader's append path
    let mut new = old.clone();
    new.had_w[0][0] = 2.5;
    new.had_b[1][2] = -0.5;
    let len0 = fs::metadata(&path).unwrap().len() as usize;
    {
        let mut r = BankReader::open(&path).unwrap();
        r.upsert(&new).unwrap();
    }
    let bytes = fs::read(&path).unwrap();
    let len1 = bytes.len();
    assert!(len1 > len0, "the upsert must append a shadowing record");

    // truncate the file at every byte boundary of the appended record:
    // reload must always succeed and always yield the last state whose
    // record is fully on disk
    let cut_path = tmp("torn_cut");
    for cut in len0..=len1 {
        fs::write(&cut_path, &bytes[..cut]).unwrap();
        let mut r = BankReader::open(&cut_path).unwrap_or_else(|e| {
            panic!("cut at {cut}/{len1}: reload must survive a torn tail: {e}")
        });
        let mut got = r.blank_adapter();
        r.read_into("t1", &mut got).unwrap_or_else(|e| panic!("cut at {cut}: {e}"));
        let want = if cut == len1 { &new } else { &old };
        assert_eq!(got.task, "t1");
        assert_eq!(adapter_bits(&got), adapter_bits(want), "cut at {cut}/{len1}");
    }

    // a flipped byte anywhere in the appended record (magic, payload or
    // trailing checksum) is detected and the reload falls back the same
    // way a torn tail does
    for i in [len0 + 2, len0 + (len1 - len0) / 2, len1 - 1] {
        let mut corrupt = bytes.clone();
        corrupt[i] ^= 0x40;
        fs::write(&cut_path, &corrupt).unwrap();
        let mut r = BankReader::open(&cut_path).unwrap();
        let mut got = r.blank_adapter();
        r.read_into("t1", &mut got).unwrap();
        assert_eq!(adapter_bits(&got), adapter_bits(&old), "corrupt byte at {i}");
    }
    fs::remove_file(&path).ok();
    fs::remove_file(&cut_path).ok();
}

/// The same FNV-1a the bank uses for its checksums, reimplemented here
/// so tests can forge a valid checksum over a doctored payload.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// First byte of the tenant log, read from the file's own header
/// (`centroid_region_len` is the u64 at offset 32).
fn tenant_start_of(bytes: &[u8]) -> usize {
    48 + u64::from_le_bytes(bytes[32..40].try_into().unwrap()) as usize
}

/// Byte extents of every tenant record: (record offset, total bytes).
fn record_extents(bytes: &[u8]) -> Vec<(usize, usize)> {
    let mut off = tenant_start_of(bytes);
    let mut out = Vec::new();
    while off + 8 <= bytes.len() {
        assert_eq!(&bytes[off..off + 4], b"TENT", "extent walk out of sync at {off}");
        let rec_len = u32::from_le_bytes(bytes[off + 4..off + 8].try_into().unwrap()) as usize;
        out.push((off, rec_len + 16));
        off += rec_len + 16;
    }
    assert_eq!(off, bytes.len(), "trailing bytes after the last record");
    out
}

/// The corruption blast-radius proof, exhaustively: flip every single
/// byte of a multi-tenant bank, one at a time, and assert the typed
/// outcome per region. Header and centroid-table flips are fatal (the
/// shared tier must be intact); a tenant-log flip costs **exactly one
/// tenant** — quarantined with a typed [`DamageKind`] mid-log, a torn
/// tail at the end — and every other tenant reads back bitwise.
#[test]
fn byte_flip_matrix_loses_at_most_one_tenant_per_flip() {
    let g = BankGeometry { layers: 1, hidden: 2, classes: 2 };
    let names = ["alpha", "beta", "gamma", "delta", "omega"];
    let mut builder = BankBuilder::new(g, vec![mini(&g, "base", 1.0)], 0.0).unwrap();
    let tenants: Vec<TaskAdapter> = names
        .iter()
        .enumerate()
        .map(|(i, n)| mini(&g, n, 2.0 + i as f32))
        .collect();
    for t in &tenants {
        builder.add_tenant(t).unwrap();
    }
    let path = tmp("flip_src");
    builder.write(&path).unwrap();
    let bytes = fs::read(&path).unwrap();
    let tenant_start = tenant_start_of(&bytes);
    let recs = record_extents(&bytes);
    assert_eq!(recs.len(), names.len());
    let owner_of = |p: usize| {
        recs.iter().position(|&(off, total)| p >= off && p < off + total).unwrap()
    };

    let flip_path = tmp("flip_cut");
    // header flips: every one fatal
    for p in 0..48 {
        let mut c = bytes.clone();
        c[p] ^= 0x01;
        fs::write(&flip_path, &c).unwrap();
        assert!(BankReader::open(&flip_path).is_err(), "header flip at {p} must be fatal");
    }
    // centroid-table flips: every one fatal
    for p in 48..tenant_start {
        let mut c = bytes.clone();
        c[p] ^= 0x01;
        fs::write(&flip_path, &c).unwrap();
        assert!(BankReader::open(&flip_path).is_err(), "centroid flip at {p} must be fatal");
    }
    // tenant-log flips: exactly one tenant lost, everything else bitwise
    for p in tenant_start..bytes.len() {
        let mut c = bytes.clone();
        c[p] ^= 0x01;
        fs::write(&flip_path, &c).unwrap();
        let victim = owner_of(p);
        let mut r = BankReader::open(&flip_path)
            .unwrap_or_else(|e| panic!("log flip at {p} must salvage, not fail: {e}"));
        assert_eq!(r.len(), names.len() - 1, "flip at {p}: exactly one tenant lost");
        assert_eq!(r.damage().len(), 1, "flip at {p}: one contiguous damage region");
        let d = &r.damage()[0];
        assert_eq!(d.offset, recs[victim].0 as u64, "flip at {p}: damage names the record");
        if victim == names.len() - 1 {
            assert_eq!(d.kind, DamageKind::TornTail, "flip at {p}: trailing damage is torn");
            assert_eq!(r.quarantined(), 0, "a torn tail is not quarantine");
        } else {
            assert!(
                matches!(
                    d.kind,
                    DamageKind::BadMagic | DamageKind::Truncated | DamageKind::BadChecksum
                ),
                "flip at {p}: mid-log damage must be typed, got {:?}",
                d.kind
            );
            assert_eq!(r.quarantined(), 1);
        }
        for (i, t) in tenants.iter().enumerate() {
            if i == victim {
                assert!(!r.contains(&t.task), "flip at {p}: the victim is unserved");
                continue;
            }
            let mut got = r.blank_adapter();
            r.read_into(&t.task, &mut got)
                .unwrap_or_else(|e| panic!("flip at {p}: survivor '{}': {e}", t.task));
            assert_eq!(adapter_bits(&got), adapter_bits(t), "flip at {p} survivor {}", t.task);
        }
    }
    fs::remove_file(&path).ok();
    fs::remove_file(&flip_path).ok();
}

/// The same blast-radius claim at fleet scale: a 1000-tenant bank with a
/// sampled set of single-byte flips — each flip costs at most one of the
/// 1000 tenants and a reload stays cheap and typed.
#[test]
fn thousand_tenant_bank_survives_sampled_flips_with_unit_blast_radius() {
    let g = BankGeometry { layers: 1, hidden: 2, classes: 2 };
    let n = 1000usize;
    let mut builder = BankBuilder::new(g, vec![mini(&g, "base", 1.0)], 0.0).unwrap();
    for i in 0..n {
        builder.add_tenant(&mini(&g, &format!("t{i:06}"), 1.0 + (i % 17) as f32 * 0.25)).unwrap();
    }
    let path = tmp("flip1000_src");
    builder.write(&path).unwrap();
    let bytes = fs::read(&path).unwrap();
    let tenant_start = tenant_start_of(&bytes);
    let log_len = bytes.len() - tenant_start;

    let flip_path = tmp("flip1000_cut");
    for k in 0..25usize {
        let p = tenant_start + (k.wrapping_mul(2654435761) + 13) % log_len;
        let mut c = bytes.clone();
        c[p] ^= 0xff;
        fs::write(&flip_path, &c).unwrap();
        let r = BankReader::open(&flip_path)
            .unwrap_or_else(|e| panic!("sampled flip at {p} must salvage: {e}"));
        assert!(r.len() >= n - 1, "flip at {p}: lost {} tenants", n - r.len());
        assert_eq!(r.damage().len(), 1, "flip at {p}");
        assert!(r.quarantined() <= 1, "flip at {p}");
    }
    fs::remove_file(&path).ok();
    fs::remove_file(&flip_path).ok();
}

/// Regression for the PR 7 data-loss bug: `upsert` used to truncate the
/// file at the *first* bad record's offset, permanently destroying every
/// valid record behind mid-log damage. Now it appends past the last
/// structurally complete record: the tail survives the upsert, the
/// damage stays quarantined, and a reload sees old tail + new record.
#[test]
fn upsert_after_mid_log_damage_never_deletes_valid_records() {
    let g = BankGeometry { layers: 1, hidden: 3, classes: 2 };
    let mut builder = BankBuilder::new(g, vec![mini(&g, "base", 1.0)], 0.0).unwrap();
    for (name, fill) in [("alpha", 2.0), ("beta", 3.0), ("gamma", 4.0)] {
        builder.add_tenant(&mini(&g, name, fill)).unwrap();
    }
    let path = tmp("upsert_after_damage");
    builder.write(&path).unwrap();

    let mut bytes = fs::read(&path).unwrap();
    let recs = record_extents(&bytes);
    bytes[recs[1].0 + 10] ^= 0xff; // corrupt 'beta', mid-log
    fs::write(&path, &bytes).unwrap();

    {
        let mut r = BankReader::open(&path).unwrap();
        assert_eq!(r.quarantined(), 1);
        assert!(r.contains("gamma"), "the tail is salvaged on open");
        r.upsert(&mini(&g, "fresh", 9.0)).unwrap();
    }

    let mut r = BankReader::open(&path).unwrap();
    assert!(r.contains("gamma"), "upsert must not have truncated the salvaged tail");
    assert!(r.contains("alpha") && r.contains("fresh"));
    assert_eq!(r.len(), 3);
    assert_eq!(r.quarantined(), 1, "the damaged region is preserved, not deleted");
    let mut got = r.blank_adapter();
    r.read_into("gamma", &mut got).unwrap();
    assert_eq!(adapter_bits(&got), adapter_bits(&mini(&g, "gamma", 4.0)));
    r.read_into("fresh", &mut got).unwrap();
    assert_eq!(adapter_bits(&got), adapter_bits(&mini(&g, "fresh", 9.0)));
    fs::remove_file(&path).ok();
}

/// Regression for the PR 7 scan bug: a checksum-valid record whose name
/// is not UTF-8 ended the whole scan (`Err(_) => break`), silently
/// dropping the tail. Now it quarantines exactly that record as
/// [`DamageKind::BadName`] and keeps indexing.
#[test]
fn non_utf8_name_quarantines_one_record_not_the_tail() {
    let g = BankGeometry { layers: 1, hidden: 3, classes: 2 };
    let mut builder = BankBuilder::new(g, vec![mini(&g, "base", 1.0)], 0.0).unwrap();
    for (name, fill) in [("aa", 2.0), ("bb", 3.0), ("cc", 4.0)] {
        builder.add_tenant(&mini(&g, name, fill)).unwrap();
    }
    let path = tmp("badname");
    builder.write(&path).unwrap();

    // overwrite 'bb''s name bytes with invalid UTF-8, then re-forge the
    // payload checksum so the record stays structurally valid
    let mut bytes = fs::read(&path).unwrap();
    let recs = record_extents(&bytes);
    let (off, total) = recs[1];
    let payload_len = total - 16;
    bytes[off + 10] = 0xff; // name bytes start at off + 8 (head) + 2 (u16 len)
    bytes[off + 11] = 0xfe;
    let sum = fnv1a(&bytes[off + 8..off + 8 + payload_len]);
    bytes[off + 8 + payload_len..off + total].copy_from_slice(&sum.to_le_bytes());
    fs::write(&path, &bytes).unwrap();

    let mut r = BankReader::open(&path).unwrap();
    assert_eq!(r.len(), 2, "only the doctored record is lost");
    assert!(r.contains("aa") && r.contains("cc"));
    assert_eq!(r.damage().len(), 1);
    assert_eq!(r.damage()[0].kind, DamageKind::BadName);
    assert_eq!(r.damage()[0].offset, off as u64);
    assert_eq!(r.quarantined(), 1);
    let mut got = r.blank_adapter();
    r.read_into("cc", &mut got).unwrap();
    assert_eq!(adapter_bits(&got), adapter_bits(&mini(&g, "cc", 4.0)), "tail reads bitwise");
    fs::remove_file(&path).ok();
}

/// Compaction end to end at the byte level: shadowed and quarantined
/// records are dropped, the generation is bumped durably, survivors read
/// back bitwise, and a scrub of the new image is clean.
#[test]
fn compact_drops_waste_bumps_generation_and_scrubs_clean() {
    let g = BankGeometry { layers: 2, hidden: 3, classes: 2 };
    let mut builder = BankBuilder::new(g, vec![mini(&g, "base", 1.0)], 0.0).unwrap();
    for (name, fill) in [("aa", 2.0), ("bb", 3.0), ("cc", 4.0), ("dd", 5.0)] {
        builder.add_tenant(&mini(&g, name, fill)).unwrap();
    }
    let path = tmp("compact_e2e");
    builder.write(&path).unwrap();

    // corrupt 'bb' mid-log, then shadow 'aa' three times through upserts
    let mut bytes = fs::read(&path).unwrap();
    let recs = record_extents(&bytes);
    bytes[recs[1].0 + 9] ^= 0xff;
    fs::write(&path, &bytes).unwrap();
    let mut r = BankReader::open(&path).unwrap();
    assert_eq!(r.quarantined(), 1);
    let mut aa = mini(&g, "aa", 2.0);
    for fill in [6.0f32, 7.0, 8.0] {
        aa.had_b[1][0] = fill;
        r.upsert(&aa).unwrap();
    }
    let report = r.scrub().unwrap();
    assert_eq!(report.quarantined, 1);
    assert_eq!(report.shadowed, 3);
    assert!(report.live_fraction < 1.0);

    let before = fs::metadata(&path).unwrap().len();
    let s = r.compact().unwrap();
    assert_eq!(s.generation, 1);
    assert_eq!(s.tenants, 3, "aa, cc, dd — bb stays lost");
    assert_eq!(s.dropped_shadowed, 3);
    assert_eq!(s.dropped_quarantined, 1);
    assert_eq!(s.bytes_before, before);
    assert!(s.bytes_after < s.bytes_before);
    assert_eq!(s.reclaimed_bytes, s.bytes_before - s.bytes_after);

    // the live reader serves the new image; a fresh open agrees
    let mut got = r.blank_adapter();
    r.read_into("aa", &mut got).unwrap();
    assert_eq!(got.had_b[1][0], 8.0, "the newest shadow wins the rewrite");
    let mut r2 = BankReader::open(&path).unwrap();
    assert_eq!(r2.generation(), 1, "generation survives reopen");
    assert_eq!(r2.len(), 3);
    assert!(r2.damage().is_empty(), "the rewrite carries no damage");
    r2.read_into("dd", &mut got).unwrap();
    assert_eq!(adapter_bits(&got), adapter_bits(&mini(&g, "dd", 5.0)));
    let clean = r2.scrub().unwrap();
    assert_eq!((clean.quarantined, clean.shadowed, clean.torn_bytes), (0, 0, 0));
    assert_eq!(clean.generation, 1);
    assert!((clean.live_fraction - 1.0).abs() < 1e-12);
    fs::remove_file(&path).ok();
}

/// The online-swap contract: compacting the attached store between waves
/// must not change a single logit bit, must keep the hot tier resident
/// (no re-faulting of hot tenants), and must leave the session serving
/// the generation-bumped file.
#[test]
fn online_compact_between_waves_is_bitwise_invisible() {
    let engine = engine2();
    let seed = 303;
    let info = engine.manifest().model("tiny").unwrap().clone();
    let store = ParamStore::init(&info, seed);
    let base_tasks = vec!["sst2".to_string(), "rte".to_string()];
    let bases = synthetic_adapters(&info, &store, &base_tasks, seed).unwrap();
    let fleet: Vec<TaskAdapter> = (0..8).map(|i| synthetic_tenant(&bases, i, seed)).collect();

    let path = tmp("online_compact");
    let mut builder = BankBuilder::new(tiny_geom(&engine), bases.clone(), 0.0).unwrap();
    for t in &fleet {
        builder.add_tenant(t).unwrap();
    }
    builder.write(&path).unwrap();
    // shadow half the fleet so the compact has something to reclaim
    {
        let mut r = BankReader::open(&path).unwrap();
        for t in fleet.iter().take(4) {
            let mut nudged = t.clone();
            nudged.had_b[0][0] += 0.5;
            r.upsert(&nudged).unwrap();
        }
        assert!(r.live_fraction() < 1.0);
    }

    let mut session = ServeSession::new(&engine, "tiny", &store, 4).unwrap();
    session.attach_store(BankReader::open(&path).unwrap(), 4).unwrap();
    let reqs: Vec<ServeRequest> = fleet
        .iter()
        .enumerate()
        .map(|(i, t)| ServeRequest {
            task: t.task.clone(),
            seq_a: (0..6).map(|j| 3 + ((i * 13 + j * 7) % 400) as i32).collect(),
            seq_b: None,
        })
        .collect();
    let serve_all = |session: &mut ServeSession, reqs: &[ServeRequest]| -> Vec<Vec<u32>> {
        let mut out = Vec::new();
        for wave in reqs.chunks(4) {
            for r in wave {
                session.submit(r.clone()).unwrap();
            }
            for reply in session.run_pending().unwrap() {
                out.push(reply.logits.iter().map(|x| x.to_bits()).collect());
            }
        }
        out
    };

    // first full pass: ends with the last wave (fleet[4..8]) resident hot
    let before = serve_all(&mut session, &reqs);
    let hot_before = session.bank().bank_stats();
    assert_eq!(session.bank().store().unwrap().generation(), 0);

    let s = session.compact_bank().unwrap();
    assert_eq!(s.generation, 1);
    assert_eq!(s.dropped_shadowed, 4);
    assert_eq!(session.bank().store().unwrap().generation(), 1);

    // the resident hot set survives the swap: re-serving the last wave
    // hits hot 4 times and faults zero times against the new generation
    let resident = serve_all(&mut session, &reqs[4..]);
    assert_eq!(before[4..], resident[..], "hot-tier replies bitwise across the swap");
    let hot_mid = session.bank().bank_stats();
    assert_eq!(hot_mid.hot_hits - hot_before.hot_hits, 4, "resident tenants stay hot");
    assert_eq!(hot_mid.cold_faults, hot_before.cold_faults, "no re-fault after the swap");

    // a full pass (hot hits and cold faults from the gen-1 file alike)
    // is bitwise identical to the pre-compact pass
    let after = serve_all(&mut session, &reqs);
    assert_eq!(before, after, "admitted replies must be bitwise identical across the swap");
    assert!(
        session.bank().bank_stats().cold_faults > hot_mid.cold_faults,
        "evicted tenants fault in from the new generation"
    );
    fs::remove_file(&path).ok();
}

#[test]
fn hot_tier_promotion_and_eviction_are_deterministic() {
    let engine = engine2();
    let seed = 5;
    let info = engine.manifest().model("tiny").unwrap().clone();
    let store = ParamStore::init(&info, seed);
    let bases = synthetic_adapters(&info, &store, &["sst2".to_string()], seed).unwrap();
    let fleet: Vec<TaskAdapter> =
        (0..5).map(|i| synthetic_tenant(&bases, i, seed)).collect();
    let path = tmp("lru");
    let mut builder = BankBuilder::new(tiny_geom(&engine), bases.clone(), 0.0).unwrap();
    for t in &fleet {
        builder.add_tenant(t).unwrap();
    }
    builder.write(&path).unwrap();

    // the same access pattern through a 2-slot tier, twice: identical
    // slot assignments, identical final hot set, identical counters
    let run = || {
        let mut bank = AdapterBank::for_model(&info).unwrap();
        bank.attach_store(BankReader::open(&path).unwrap(), 2).unwrap();
        let pattern = ["sst2", "t000001", "sst2", "t000002", "t000001", "t000002"];
        let slots: Vec<usize> = pattern
            .iter()
            .map(|n| bank.resolve_pinned(n, |_| false).unwrap())
            .collect();
        let hot: Vec<String> = bank.names().map(str::to_string).collect();
        (slots, hot, bank.bank_stats())
    };
    let (slots_a, hot_a, stats_a) = run();
    let (slots_b, hot_b, stats_b) = run();
    assert_eq!(slots_a, slots_b, "slot assignment must be reproducible");
    assert_eq!(hot_a, hot_b, "final hot set must be reproducible");
    assert_eq!(stats_a, stats_b, "tier counters must be reproducible");
    assert_eq!(slots_a, vec![0, 1, 0, 1, 0, 1]);
    assert_eq!(hot_a, vec!["t000001".to_string(), "t000002".to_string()]);
    assert_eq!(stats_a.hot_hits, 2);
    assert_eq!(stats_a.cold_faults, 4);
    assert_eq!(stats_a.promotions, 4);
    assert_eq!(stats_a.evictions, 2);

    // eviction skips pinned slots: with the true LRU slot pinned, the
    // fault recycles the younger slot instead
    let mut bank = AdapterBank::for_model(&info).unwrap();
    bank.attach_store(BankReader::open(&path).unwrap(), 2).unwrap();
    assert_eq!(bank.resolve_pinned("t000003", |_| false).unwrap(), 0);
    assert_eq!(bank.resolve_pinned("t000004", |_| false).unwrap(), 1);
    let got = bank.resolve_pinned("sst2", |i| i == 0).unwrap();
    assert_eq!(got, 1, "eviction must skip the pinned LRU slot");
    assert!(bank.contains("t000003"), "the pinned tenant survives");
    assert!(!bank.contains("t000004"), "the unpinned one is recycled");

    // a promoted entry is the generator's tenant, bitwise
    let slot = bank.resolve_pinned("t000002", |_| false).unwrap();
    let got = bank.by_index(slot).unwrap();
    assert_eq!(adapter_bits(got), adapter_bits(&fleet[2]));
    fs::remove_file(&path).ok();
}
