//! Tiered-bank persistence contracts, end to end:
//!
//! 1. **Tier transparency**: a session paging tenants through a small
//!    LRU hot tier over the on-disk bank returns **bitwise** the logits
//!    of a flat in-memory bank holding every tenant — reconstruction
//!    (centroid + delta rows) is exact for the ε=0 encoding, and the
//!    fault/evict machinery never leaks into the math.
//! 2. **Compression**: a Zipf-clustered synthetic fleet (duplicates,
//!    single-layer deviations, full tunes — the shape the paper's
//!    redundant-layer finding predicts) stores at ≥10x below the naive
//!    per-tenant scalar total, and cold reads reconstruct tenants
//!    bitwise.
//! 3. **Crash safety**: truncating an upsert at *every* byte boundary
//!    still reloads, and always yields the last committed state; a
//!    corrupt byte anywhere in the appended record is caught by its
//!    checksum and falls back the same way.
//! 4. **Determinism**: promotion/eviction order, slot assignment and the
//!    tier counters are identical across repeated runs, and eviction
//!    provably skips pinned slots.

use std::fs;
use std::path::PathBuf;

use hadapt::model::ParamStore;
use hadapt::runtime::{
    synthetic_adapters, synthetic_tenant, AdapterBank, BankBuilder, BankGeometry, BankReader,
    Engine, ServeRequest, ServeSession, TaskAdapter,
};

fn engine2() -> Engine {
    Engine::new_with_threads("/definitely/not/a/dir", 2).unwrap()
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("hadapt_bankp_{}_{tag}.bank", std::process::id()))
}

/// Every float of every family as raw bits, in a fixed family order —
/// one flat value to compare two adapters bitwise (`-0.0` vs `0.0` and
/// exact payloads included).
fn adapter_bits(a: &TaskAdapter) -> Vec<u32> {
    let mut out = Vec::new();
    for fam in [&a.had_w, &a.had_b, &a.norm_w, &a.norm_b] {
        for row in fam.iter() {
            out.extend(row.iter().map(|x| x.to_bits()));
        }
    }
    for flat in [&a.pooler_w, &a.pooler_b, &a.cls_w, &a.cls_b] {
        out.extend(flat.iter().map(|x| x.to_bits()));
    }
    out
}

fn tiny_geom(engine: &Engine) -> BankGeometry {
    let info = engine.manifest().model("tiny").unwrap();
    let classes = info.params[info.param_index("classifier.bias").unwrap()].shape[0];
    BankGeometry { layers: info.layers, hidden: info.hidden, classes }
}

/// A hand-shaped adapter at an arbitrary mini geometry (no engine
/// involved) for the byte-level crash-safety test.
fn mini(g: &BankGeometry, name: &str, fill: f32) -> TaskAdapter {
    TaskAdapter {
        task: name.to_string(),
        classes: g.classes,
        had_w: vec![vec![fill; g.hidden]; g.layers],
        had_b: vec![vec![fill * 0.5; g.hidden]; g.layers],
        norm_w: vec![vec![1.0; g.hidden]; g.layers],
        norm_b: vec![vec![0.0; g.hidden]; g.layers],
        pooler_w: vec![fill; g.hidden * g.hidden],
        pooler_b: vec![0.0; g.hidden],
        cls_w: vec![fill; g.hidden * g.classes],
        cls_b: vec![0.0; g.classes],
    }
}

#[test]
fn tiered_serve_is_bitwise_identical_to_a_flat_bank() {
    let engine = engine2();
    let seed = 71;
    let info = engine.manifest().model("tiny").unwrap().clone();
    let store = ParamStore::init(&info, seed);
    let base_tasks = vec!["sst2".to_string(), "mrpc".to_string(), "rte".to_string()];
    let bases = synthetic_adapters(&info, &store, &base_tasks, seed).unwrap();
    let fleet: Vec<TaskAdapter> =
        (0..12).map(|i| synthetic_tenant(&bases, i, seed)).collect();

    let path = tmp("roundtrip");
    let mut builder = BankBuilder::new(tiny_geom(&engine), bases.clone(), 0.0).unwrap();
    for t in &fleet {
        builder.add_tenant(t).unwrap();
    }
    let summary = builder.write(&path).unwrap();
    assert_eq!(summary.tenants, fleet.len());

    // 12 tenants through a 4-slot hot tier (= the wave size) vs all 12
    // resident in a flat bank
    let mut tiered = ServeSession::new(&engine, "tiny", &store, 4).unwrap();
    tiered.attach_store(BankReader::open(&path).unwrap(), 4).unwrap();
    let mut flat = ServeSession::new(&engine, "tiny", &store, 4).unwrap();
    for t in &fleet {
        flat.register_task(t.clone()).unwrap();
    }

    // three rounds over the whole fleet in wave-sized chunks (submission
    // resolves tenants immediately, so a wave can pin at most the hot
    // tier's 4 slots): every round churns the LRU, so the stream
    // constantly mixes hot hits, faults and evictions
    for round in 0..3usize {
        for chunk in fleet.iter().enumerate().collect::<Vec<_>>().chunks(4) {
            for &(i, t) in chunk {
                let req = ServeRequest {
                    task: t.task.clone(),
                    seq_a: (0..5 + (i + round) % 4)
                        .map(|j| 3 + ((i * 31 + round * 7 + j * 11) % 500) as i32)
                        .collect(),
                    seq_b: (i % 2 == 0).then(|| vec![9 + i as i32, 17, 23]),
                };
                tiered.submit(req.clone()).unwrap();
                flat.submit(req).unwrap();
            }
            let got = tiered.run_pending().unwrap();
            let want = flat.run_pending().unwrap();
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.task, w.task, "round {round}");
                assert_eq!(g.label, w.label, "round {round} task {}", g.task);
                let gb: Vec<u32> = g.logits.iter().map(|x| x.to_bits()).collect();
                let wb: Vec<u32> = w.logits.iter().map(|x| x.to_bits()).collect();
                assert_eq!(
                    gb, wb,
                    "round {round} task {}: paged reconstruction must be bitwise",
                    g.task
                );
            }
        }
    }

    let stats = tiered.bank().bank_stats();
    assert!(stats.cold_faults > 0, "a 4-slot tier over 12 tenants must fault");
    assert!(stats.evictions > 0, "and recycle slots");
    assert_eq!(stats.promotions, stats.cold_faults, "every fault promotes");
    assert!(tiered.bank().len() <= 4, "hot tier stays capped");
    assert_eq!(tiered.bank().tenant_count(), 12, "both tiers together serve the fleet");
    assert!(
        tiered.bank().resident_bytes() < flat.bank().resident_bytes(),
        "the tiered bank must hold fewer bytes resident than the flat bank"
    );
    let flat_stats = flat.bank().bank_stats();
    assert_eq!((flat_stats.cold_faults, flat_stats.evictions), (0, 0));
    fs::remove_file(&path).ok();
}

/// Regression: the owned `submit` path must resolve (and fault in) the
/// tenant at submit time, exactly like `submit_borrowed` — an unknown
/// task rejects immediately instead of poisoning the whole wave at
/// `run_pending`, and a queued row pins a *slot*, not a name.
#[test]
fn owned_submit_resolves_and_rejects_at_submit_time() {
    let engine = engine2();
    let seed = 83;
    let info = engine.manifest().model("tiny").unwrap().clone();
    let store = ParamStore::init(&info, seed);
    let base_tasks = vec!["sst2".to_string(), "mrpc".to_string(), "rte".to_string()];
    let bases = synthetic_adapters(&info, &store, &base_tasks, seed).unwrap();
    let path = tmp("submit_time");
    let mut builder = BankBuilder::new(tiny_geom(&engine), bases.clone(), 0.0).unwrap();
    for i in 0..6 {
        builder.add_tenant(&synthetic_tenant(&bases, i, seed)).unwrap();
    }
    builder.write(&path).unwrap();

    let mut session = ServeSession::new(&engine, "tiny", &store, 4).unwrap();
    session.attach_store(BankReader::open(&path).unwrap(), 4).unwrap();
    let req = |task: &str| ServeRequest {
        task: task.to_string(),
        seq_a: vec![4, 5, 6],
        seq_b: None,
    };

    // an unknown task fails at submit — the error arrives before any
    // neighbor row is dragged into a failing wave
    let err = session.submit(req("not-a-tenant")).unwrap_err();
    assert!(err.to_string().contains("no adapter in either tier"), "{err}");

    // a cold tenant faults in *at submit*: the queue holds a resolved,
    // pinned slot from that point on
    let before = session.bank().bank_stats().cold_faults;
    session.submit(req("t000004")).unwrap();
    assert_eq!(
        session.bank().bank_stats().cold_faults,
        before + 1,
        "resolution (and the cold fault) happens at submit time"
    );
    session.submit(req("t000005")).unwrap();

    // the earlier rejection cost nothing: both admitted rows serve
    let replies = session.run_pending().unwrap();
    assert_eq!(replies.len(), 2);
    assert_eq!(replies[0].task, "t000004");
    assert_eq!(replies[1].task, "t000005");
    fs::remove_file(&path).ok();
}

#[test]
fn zipf_fleet_bank_compresses_at_least_10x_and_reads_back_bitwise() {
    let engine = engine2();
    let seed = 1234;
    let info = engine.manifest().model("tiny").unwrap().clone();
    let store = ParamStore::init(&info, seed);
    let base_tasks = vec!["sst2".to_string(), "mrpc".to_string(), "rte".to_string()];
    let bases = synthetic_adapters(&info, &store, &base_tasks, seed).unwrap();

    let n = 1000usize;
    let path = tmp("zipf");
    let mut builder = BankBuilder::new(tiny_geom(&engine), bases.clone(), 0.0).unwrap();
    for i in 0..n {
        builder.add_tenant(&synthetic_tenant(&bases, i, seed)).unwrap();
    }
    let summary = builder.write(&path).unwrap();
    assert_eq!(summary.tenants, n);
    assert_eq!(
        summary.naive_scalars,
        (n * bases[0].scalars()) as u64,
        "naive accounting is logical scalars × tenants"
    );
    assert!(
        summary.compression_ratio >= 10.0,
        "fleet must store <10% of the dense total, got {:.2}x over {} bytes",
        summary.compression_ratio,
        summary.file_bytes
    );

    // cold reads reconstruct exactly what the generator produced
    let mut reader = BankReader::open(&path).unwrap();
    assert_eq!(reader.len(), n);
    for idx in [0usize, 2, 17, 500, n - 1] {
        let want = synthetic_tenant(&bases, idx, seed);
        let mut got = reader.blank_adapter();
        reader.read_into(&want.task, &mut got).unwrap();
        assert_eq!(got.task, want.task);
        assert_eq!(got.classes, want.classes);
        assert_eq!(adapter_bits(&got), adapter_bits(&want), "tenant {idx}");
    }
    fs::remove_file(&path).ok();
}

#[test]
fn torn_upsert_always_reloads_the_last_committed_state() {
    let g = BankGeometry { layers: 2, hidden: 4, classes: 2 };
    let base = mini(&g, "base", 1.0);
    let mut old = mini(&g, "t1", 1.0);
    old.had_b[1][2] = -0.75; // deviates, so the record carries delta rows
    let path = tmp("torn_src");
    let mut builder = BankBuilder::new(g, vec![base], 0.0).unwrap();
    builder.add_tenant(&old).unwrap();
    builder.write(&path).unwrap();

    // shadow t1 through the reader's append path
    let mut new = old.clone();
    new.had_w[0][0] = 2.5;
    new.had_b[1][2] = -0.5;
    let len0 = fs::metadata(&path).unwrap().len() as usize;
    {
        let mut r = BankReader::open(&path).unwrap();
        r.upsert(&new).unwrap();
    }
    let bytes = fs::read(&path).unwrap();
    let len1 = bytes.len();
    assert!(len1 > len0, "the upsert must append a shadowing record");

    // truncate the file at every byte boundary of the appended record:
    // reload must always succeed and always yield the last state whose
    // record is fully on disk
    let cut_path = tmp("torn_cut");
    for cut in len0..=len1 {
        fs::write(&cut_path, &bytes[..cut]).unwrap();
        let mut r = BankReader::open(&cut_path).unwrap_or_else(|e| {
            panic!("cut at {cut}/{len1}: reload must survive a torn tail: {e}")
        });
        let mut got = r.blank_adapter();
        r.read_into("t1", &mut got).unwrap_or_else(|e| panic!("cut at {cut}: {e}"));
        let want = if cut == len1 { &new } else { &old };
        assert_eq!(got.task, "t1");
        assert_eq!(adapter_bits(&got), adapter_bits(want), "cut at {cut}/{len1}");
    }

    // a flipped byte anywhere in the appended record (magic, payload or
    // trailing checksum) is detected and the reload falls back the same
    // way a torn tail does
    for i in [len0 + 2, len0 + (len1 - len0) / 2, len1 - 1] {
        let mut corrupt = bytes.clone();
        corrupt[i] ^= 0x40;
        fs::write(&cut_path, &corrupt).unwrap();
        let mut r = BankReader::open(&cut_path).unwrap();
        let mut got = r.blank_adapter();
        r.read_into("t1", &mut got).unwrap();
        assert_eq!(adapter_bits(&got), adapter_bits(&old), "corrupt byte at {i}");
    }
    fs::remove_file(&path).ok();
    fs::remove_file(&cut_path).ok();
}

#[test]
fn hot_tier_promotion_and_eviction_are_deterministic() {
    let engine = engine2();
    let seed = 5;
    let info = engine.manifest().model("tiny").unwrap().clone();
    let store = ParamStore::init(&info, seed);
    let bases = synthetic_adapters(&info, &store, &["sst2".to_string()], seed).unwrap();
    let fleet: Vec<TaskAdapter> =
        (0..5).map(|i| synthetic_tenant(&bases, i, seed)).collect();
    let path = tmp("lru");
    let mut builder = BankBuilder::new(tiny_geom(&engine), bases.clone(), 0.0).unwrap();
    for t in &fleet {
        builder.add_tenant(t).unwrap();
    }
    builder.write(&path).unwrap();

    // the same access pattern through a 2-slot tier, twice: identical
    // slot assignments, identical final hot set, identical counters
    let run = || {
        let mut bank = AdapterBank::for_model(&info).unwrap();
        bank.attach_store(BankReader::open(&path).unwrap(), 2).unwrap();
        let pattern = ["sst2", "t000001", "sst2", "t000002", "t000001", "t000002"];
        let slots: Vec<usize> = pattern
            .iter()
            .map(|n| bank.resolve_pinned(n, |_| false).unwrap())
            .collect();
        let hot: Vec<String> = bank.names().map(str::to_string).collect();
        (slots, hot, bank.bank_stats())
    };
    let (slots_a, hot_a, stats_a) = run();
    let (slots_b, hot_b, stats_b) = run();
    assert_eq!(slots_a, slots_b, "slot assignment must be reproducible");
    assert_eq!(hot_a, hot_b, "final hot set must be reproducible");
    assert_eq!(stats_a, stats_b, "tier counters must be reproducible");
    assert_eq!(slots_a, vec![0, 1, 0, 1, 0, 1]);
    assert_eq!(hot_a, vec!["t000001".to_string(), "t000002".to_string()]);
    assert_eq!(stats_a.hot_hits, 2);
    assert_eq!(stats_a.cold_faults, 4);
    assert_eq!(stats_a.promotions, 4);
    assert_eq!(stats_a.evictions, 2);

    // eviction skips pinned slots: with the true LRU slot pinned, the
    // fault recycles the younger slot instead
    let mut bank = AdapterBank::for_model(&info).unwrap();
    bank.attach_store(BankReader::open(&path).unwrap(), 2).unwrap();
    assert_eq!(bank.resolve_pinned("t000003", |_| false).unwrap(), 0);
    assert_eq!(bank.resolve_pinned("t000004", |_| false).unwrap(), 1);
    let got = bank.resolve_pinned("sst2", |i| i == 0).unwrap();
    assert_eq!(got, 1, "eviction must skip the pinned LRU slot");
    assert!(bank.contains("t000003"), "the pinned tenant survives");
    assert!(!bank.contains("t000004"), "the unpinned one is recycled");

    // a promoted entry is the generator's tenant, bitwise
    let slot = bank.resolve_pinned("t000002", |_| false).unwrap();
    let got = bank.by_index(slot).unwrap();
    assert_eq!(adapter_bits(got), adapter_bits(&fleet[2]));
    fs::remove_file(&path).ok();
}
