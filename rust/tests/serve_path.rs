//! Serve-path contracts, counter-verified:
//!
//! 1. A request's logits are identical whether it is served alone
//!    (`max_batch = 1`) or inside a mixed-task micro-batch — every kernel
//!    on the eval forward is row/example-local, so cross-tenant batching
//!    is free of cross-talk.
//! 2. The inference entry with no adapter overlays reproduces the forward
//!    artifact's logits exactly (same kernels, same order — the eval path
//!    only *skips* training slabs, it never changes math).
//! 3. Hot-swapping adapters in the bank never touches the frozen
//!    backbone's pack cache: task switching costs vector copies, not
//!    repacks.
//! 4. The steady-state serve loop inherits the training loop's
//!    zero-allocation / zero-spawn contracts: arena misses and pool
//!    spawns freeze after the first (warm-up) batch.
//! 5. A request served over the HTTP front door returns **bitwise** the
//!    same logits as the same request through the in-process session:
//!    the wire layer (pull-JSON decode into resident buffers, shortest
//!    round-trip float serialization) adds zero numeric drift.

#[path = "common/wire_client.rs"]
mod wire_client;

use hadapt::data::{generate, make_batch, task_info};
use hadapt::model::ParamStore;
use hadapt::runtime::{
    spawn_synthetic_server, synthetic_adapters, Engine, InferBatch, InferOut, IntTensor,
    Manifest, ServeReply, ServeRequest, ServeSession, SpawnOpts, TaskAdapter, Tensor,
};
use hadapt::util::json;

fn engine2() -> Engine {
    Engine::new_with_threads("/definitely/not/a/dir", 2).unwrap()
}

fn store_for(engine: &Engine, model: &str, seed: u64) -> ParamStore {
    ParamStore::init(engine.manifest().model(model).unwrap(), seed)
}

/// Two deliberately-different synthetic task adapters.
fn two_tasks(engine: &Engine, store: &ParamStore) -> (TaskAdapter, TaskAdapter) {
    let info = engine.manifest().model("tiny").unwrap();
    let mut a = TaskAdapter::from_store(info, store, "a", 2).unwrap();
    let mut b = TaskAdapter::from_store(info, store, "b", 3).unwrap();
    for (j, v) in a.had_w[0].iter_mut().enumerate() {
        *v += 0.01 * (j as f32 + 1.0);
    }
    for v in a.had_b[1].iter_mut() {
        *v -= 0.05;
    }
    for v in b.norm_b[0].iter_mut() {
        *v += 0.1;
    }
    for (j, v) in b.cls_w.iter_mut().enumerate() {
        *v += 0.002 * (j % 7) as f32;
    }
    (a, b)
}

fn mixed_requests(n: usize) -> Vec<ServeRequest> {
    (0..n)
        .map(|i| ServeRequest {
            task: if i % 2 == 0 { "a".into() } else { "b".into() },
            seq_a: (0..6 + i % 5).map(|j| 5 + (i * 13 + j * 7) as i32 % 500).collect(),
            seq_b: if i % 3 == 0 {
                Some((0..4).map(|j| 9 + (i * 11 + j * 3) as i32 % 500).collect())
            } else {
                None
            },
        })
        .collect()
}

#[test]
fn mixed_task_micro_batch_matches_single_request_serves() {
    let engine = engine2();
    let store = store_for(&engine, "tiny", 42);
    let (ta, tb) = two_tasks(&engine, &store);

    let mut batched = ServeSession::new(&engine, "tiny", &store, 4).unwrap();
    batched.register_task(ta.clone()).unwrap();
    batched.register_task(tb.clone()).unwrap();
    let mut solo = ServeSession::new(&engine, "tiny", &store, 1).unwrap();
    solo.register_task(ta).unwrap();
    solo.register_task(tb).unwrap();

    let reqs = mixed_requests(6);
    for r in &reqs {
        batched.submit(r.clone()).unwrap();
    }
    // 6 requests at max_batch=4: one full batch + one padded batch
    let batch_replies = batched.run_pending().unwrap();
    assert_eq!(batch_replies.len(), 6);

    for (i, r) in reqs.iter().enumerate() {
        solo.submit(r.clone()).unwrap();
        let one = solo.run_pending().unwrap();
        assert_eq!(one.len(), 1);
        assert_eq!(
            one[0].logits, batch_replies[i].logits,
            "request {i} ({}): mixed-task micro-batch must reproduce the \
             single-request logits exactly",
            r.task
        );
        assert_eq!(one[0].label, batch_replies[i].label);
    }

    // the two tasks' adapters genuinely disagree on identical input
    let same_input = ServeRequest { task: "a".into(), seq_a: vec![7, 8, 9], seq_b: None };
    let mut as_b = same_input.clone();
    as_b.task = "b".into();
    solo.submit(same_input).unwrap();
    let ra = solo.run_pending().unwrap();
    solo.submit(as_b).unwrap();
    let rb = solo.run_pending().unwrap();
    assert_ne!(ra[0].logits, rb[0].logits, "different tenants, different logits");
}

#[test]
fn infer_without_adapters_matches_forward_artifact() {
    let engine = engine2();
    let store = store_for(&engine, "tiny", 7);
    let (b, l) = (engine.manifest().batch, engine.manifest().seq_len);
    let ds = generate(task_info("sst2").unwrap(), 3, "dev", b);
    let idx: Vec<usize> = (0..b).collect();
    let bt = make_batch(&ds, &idx, b, l);

    let params: Vec<_> = store.tensors.iter().map(|t| engine.upload(t).unwrap()).collect();
    let mut inputs: Vec<&_> = params.iter().collect();
    let batch_bufs = vec![
        engine
            .upload_int_owned(IntTensor::new(vec![b, l], bt.tokens.clone()).unwrap())
            .unwrap(),
        engine
            .upload_int_owned(IntTensor::new(vec![b, l], bt.type_ids.clone()).unwrap())
            .unwrap(),
        engine
            .upload_owned(Tensor::new(vec![b, l], bt.attn_mask.clone()).unwrap())
            .unwrap(),
    ];
    inputs.extend(batch_bufs.iter());
    let artifact_outs = engine.run(&Manifest::fwd_name("tiny"), &inputs).unwrap();

    let mut out = InferOut::default();
    engine
        .infer(
            "tiny",
            &params,
            InferBatch {
                b,
                l,
                tokens: &bt.tokens,
                type_ids: &bt.type_ids,
                attn_mask: &bt.attn_mask,
            },
            None,
            &mut out,
        )
        .unwrap();
    assert_eq!(out.logits, artifact_outs[0].data, "logits must match the artifact");
    assert_eq!(out.regression, artifact_outs[1].data, "regression must match");
}

#[test]
fn adapter_swap_leaves_the_pack_cache_frozen() {
    let engine = engine2();
    let store = store_for(&engine, "tiny", 9);
    let (ta, tb) = two_tasks(&engine, &store);
    let mut s = ServeSession::new(&engine, "tiny", &store, 4).unwrap();
    s.register_task(ta.clone()).unwrap();
    s.register_task(tb).unwrap();

    let reqs = mixed_requests(4);
    for r in &reqs {
        s.submit(r.clone()).unwrap();
    }
    s.run_pending().unwrap();
    let (live0, repacks0) = engine.pack_stats();
    assert!(live0 > 0, "serving must pack the frozen backbone");
    assert_eq!(repacks0, 0);

    // redeploy task 'a' repeatedly, serving between swaps; capture logits
    // before/after one swap to prove the new vectors actually apply
    s.submit(reqs[0].clone()).unwrap();
    let before = s.run_pending().unwrap()[0].logits.clone();
    for round in 0..3 {
        let mut swapped = ta.clone();
        for v in swapped.had_b[0].iter_mut() {
            *v += 0.2 + round as f32 * 0.1;
        }
        s.register_task(swapped).unwrap();
        for r in &reqs {
            s.submit(r.clone()).unwrap();
        }
        s.run_pending().unwrap();
    }
    s.submit(reqs[0].clone()).unwrap();
    let after = s.run_pending().unwrap()[0].logits.clone();
    assert_ne!(before, after, "a swapped adapter must change the tenant's logits");

    let (live1, repacks1) = engine.pack_stats();
    assert_eq!(
        (live1, repacks1),
        (live0, 0),
        "adapter swaps must never repack the frozen backbone"
    );
}

#[test]
fn serve_steady_state_freezes_arena_and_pool_counters() {
    let engine = engine2();
    let store = store_for(&engine, "tiny", 21);
    let (ta, tb) = two_tasks(&engine, &store);
    let mut s = ServeSession::new(&engine, "tiny", &store, 8).unwrap();
    s.register_task(ta).unwrap();
    s.register_task(tb).unwrap();

    let reqs = mixed_requests(8);
    // warm-up batch: arena fills, workers spawn, backbone packs
    for r in &reqs {
        s.submit(r.clone()).unwrap();
    }
    s.run_pending().unwrap();
    let (hits0, misses0) = engine.arena_stats();
    let pool0 = engine.pool_stats();
    assert_eq!(pool0.threads_spawned, 1, "a 2-thread engine spawns one worker");

    for _ in 0..3 {
        for r in &reqs {
            s.submit(r.clone()).unwrap();
        }
        s.run_pending().unwrap();
    }
    let (hits1, misses1) = engine.arena_stats();
    let pool1 = engine.pool_stats();
    assert_eq!(misses1, misses0, "steady-state serve batches must not miss the arena");
    assert!(hits1 > hits0, "steady-state serve batches must hit the arena");
    assert_eq!(
        pool1.threads_spawned, pool0.threads_spawned,
        "steady-state serve batches must not spawn threads"
    );
    assert!(pool1.jobs_dispatched > pool0.jobs_dispatched, "batches keep dispatching");

    // short (padded) batches at the same geometry stay steady too
    s.submit(reqs[0].clone()).unwrap();
    s.run_pending().unwrap();
    let (_, misses2) = engine.arena_stats();
    assert_eq!(misses2, misses1, "padded batches reuse the same fixed geometry");
}

fn assert_reply_bitwise(body: &str, want: &ServeReply, i: usize) {
    let v = json::parse(body).unwrap_or_else(|e| panic!("case {i}: {e}\n{body}"));
    assert_eq!(v.get("task").unwrap().as_str().unwrap(), want.task, "case {i}");
    assert_eq!(v.get("label").unwrap().as_usize().unwrap(), want.label, "case {i}");
    let logits: Vec<f32> = v
        .get("logits")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect();
    assert_eq!(logits.len(), want.logits.len(), "case {i}");
    for (j, (got, exp)) in logits.iter().zip(&want.logits).enumerate() {
        assert_eq!(
            got.to_bits(),
            exp.to_bits(),
            "case {i} logit {j}: {got} vs {exp} — the wire's shortest round-trip \
             decimal must reproduce the f32 bits exactly"
        );
    }
}

#[test]
fn wire_serve_matches_in_process_bitwise() {
    let seed = 33;
    let tasks = vec!["sst2".to_string(), "rte".to_string()];
    // in-process reference: the same deterministic backbone + synthetic
    // tenants SpawnOpts::tiny(seed) builds inside the server thread
    let engine = engine2();
    let info = engine.manifest().model("tiny").unwrap().clone();
    let store = ParamStore::init(&info, seed);
    let mut session = ServeSession::new(&engine, "tiny", &store, 4).unwrap();
    for a in synthetic_adapters(&info, &store, &tasks, seed).unwrap() {
        session.register_task(a).unwrap();
    }
    let seq = session.geometry().1 as i32;

    // boundary budgets through the wire path: 0 / 1 / seq-1 / seq /
    // seq+k tokens, with absent, empty and truncating text_b
    let cases: Vec<(&str, Vec<i32>, Option<Vec<i32>>)> = vec![
        ("sst2", vec![], None),
        ("sst2", vec![5], None),
        ("rte", (0..seq - 1).map(|j| 2 + j % 37).collect(), None),
        ("sst2", (0..seq).map(|j| 1 + j % 29).collect(), Some(vec![])),
        (
            "rte",
            (0..seq + 9).map(|j| 3 + j % 31).collect(),
            Some((0..7).map(|j| 4 + j).collect()),
        ),
        ("sst2", vec![8, 9, 10], Some((0..seq).map(|j| 2 + j % 23).collect())),
    ];
    let mut expected = Vec::new();
    for (task, a, b) in &cases {
        session
            .submit(ServeRequest {
                task: task.to_string(),
                seq_a: a.clone(),
                seq_b: b.clone(),
            })
            .unwrap();
        expected.push(session.run_pending().unwrap().pop().unwrap());
    }

    let (addr, handle) = spawn_synthetic_server(SpawnOpts::tiny(seed)).unwrap();
    // one request per round trip (each rides a padded single-row wave)
    for (i, (task, a, b)) in cases.iter().enumerate() {
        let req = wire_client::infer_req(task, a, b.as_deref());
        let resp = wire_client::send_and_read(addr, &req, 1, false).pop().unwrap();
        assert_eq!(resp.status, 200, "case {i}: {}", resp.body);
        assert_reply_bitwise(&resp.body, &expected[i], i);
    }

    // pipelined: four requests in one write become one full wave, and
    // replies come back in request order, still bit-identical
    let mut burst = Vec::new();
    for (task, a, b) in cases.iter().take(4) {
        burst.extend_from_slice(&wire_client::infer_req(task, a, b.as_deref()));
    }
    use std::io::Write as _;
    let mut c = std::net::TcpStream::connect(addr).unwrap();
    c.write_all(&burst).unwrap();
    let resps = wire_client::read_responses(&mut c, 4);
    for (i, resp) in resps.iter().enumerate() {
        assert_eq!(resp.status, 200, "pipelined case {i}: {}", resp.body);
        assert_reply_bitwise(&resp.body, &expected[i], i);
    }
    drop(c);

    let mut sh = std::net::TcpStream::connect(addr).unwrap();
    sh.write_all(&wire_client::post("/shutdown")).unwrap();
    let r = wire_client::read_responses(&mut sh, 1).pop().unwrap();
    assert_eq!(r.status, 200);
    let stats = handle.join().unwrap().unwrap();
    assert_eq!(stats.replies, cases.len() as u64 + 4);
    assert_eq!(stats.rejects_parse + stats.rejects_http + stats.rejects_submit, 0);
}
