//! Zero-allocation *and* zero-spawn steady state, pinned by a counting
//! global allocator plus the pool's dispatch counters.
//!
//! A train-step-shaped kernel sequence (fused GEMM forward, LayerNorm,
//! Hadamard adapter, attention, then the backward kernels with in-place NT
//! accumulation) runs entirely on `_into` kernels over a `Workspace`
//! arena. Iteration 1 warms the arena (misses allocate); iterations 2..N
//! must perform **zero** heap allocations — every `take` is a hit and no
//! kernel allocates internally.
//!
//! The loop runs twice: once on the serial pool (the PR 3 contract) and
//! once on a persistent 2-worker pool with a geometry large enough that
//! the GEMM/LayerNorm/attention kernels actually fork. Since PR 4 the
//! parallel dispatch is also allocation-free (the job descriptor lives on
//! the caller's stack; PR 2 collected a `Vec` of chunk slices per call)
//! and spawn-free after warmup (`PoolStats::threads_spawned` freezes at
//! `threads - 1`), so the counting allocator covers the threaded phase
//! too — worker wake/park is condvar traffic, not heap traffic. This is
//! the counter-verified "steps >= 2 spawn no threads and allocate no
//! kernel memory" acceptance test; `native.rs` has the artifact-level
//! twin (`steady_train_steps_spawn_no_threads`).
//!
//! Since PR 5 the loop has an eval-shaped sibling: the serve path's
//! forward-only kernel sequence (fused GEMM with **no** pre-activation
//! tap, LayerNorm, per-example Hadamard adapter rows, attention forward)
//! must hold the same zero-allocation steady state — the counter-proof
//! behind `ServeSession`'s fixed-geometry micro-batches.
//!
//! Since PR 6 the loop has a third act, one level up the stack: a
//! [`WireServer`] on its own thread serves pipelined `/infer` waves plus
//! the entire adversarial wire-fixture corpus through a real socket while
//! the allocator counts. The allocator is process-global, so the server
//! thread's parse → admit → batch → respond path is counted alongside the
//! (deliberately alloc-free) test client — any steady-state allocation on
//! either side of the socket trips the zero.
//!
//! Since PR 7 there is a fourth act: a [`ServeSession`] paging tenants
//! from an on-disk tiered bank (`bankstore`) serves a hot-resident
//! working set — once the working set is faulted into the LRU hot tier,
//! steady waves are hot hits only (a map probe plus a stamp write) and
//! must add **zero** allocations to the serve path's zero.
//!
//! And since the multi-connection ingress PR, a fifth: four persistent
//! concurrent connections, one tenant each, submit into shared waves
//! (every wave mixes all four connections) for three tracked rounds —
//! the connection-slot table, the shared decode scratch and the shared
//! response accumulator must keep the whole multiplexed path at zero
//! allocations, with arena/spawn/repack/bank-fault counters frozen and
//! the reply/batch/cross-connection-wave counters advancing by exactly
//! their predicted deltas.
//!
//! This file intentionally holds a single test: the counting allocator is
//! process-global, and a sibling test running on another thread would
//! pollute the count.

use std::alloc::{GlobalAlloc, Layout, System};
use std::io::{Read as _, Write as _};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use hadapt::model::ParamStore;
use hadapt::runtime::kernels as k;
use hadapt::runtime::{
    spawn_synthetic_server, synthetic_adapters, synthetic_tenant, BankBuilder, BankGeometry,
    BankReader, Engine, Pool, ServePolicy, ServeSession, SpawnOpts, TaskAdapter, Workspace,
};
use hadapt::util::Rng;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static TRACKING: AtomicBool = AtomicBool::new(false);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if TRACKING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if TRACKING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if TRACKING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() * 0.5).collect()
}

/// Run 4 train-shaped kernel iterations at the given geometry on `pool`.
/// Iteration 0 warms the arena (and, on a parallel pool, spawns the
/// persistent workers); iterations 1..3 run under the counting allocator
/// and must allocate nothing and miss the arena never.
fn steady_kernel_loop(pool: &Pool, b: usize, l: usize, nh: usize, h: usize, label: &str) {
    let hd = h / nh;
    let t = b * l;
    let mut rng = Rng::new(0xA110C);

    // All model-side operands exist before the loop, like resident params.
    let x = randv(&mut rng, t * h);
    let wmat = randv(&mut rng, h * h);
    let pw_nn = k::PackedMat::pack_nn(&wmat, h, h);
    let pw_nt = k::PackedMat::pack_nt(&wmat, h, h);
    let bias = randv(&mut rng, h);
    let gain = randv(&mut rng, h);
    let beta = randv(&mut rng, h);
    let hw = randv(&mut rng, h);
    let hb = randv(&mut rng, h);
    let mask_add = vec![0.0f32; b * l];

    let mut ws = Workspace::new();
    let mut misses_after_warm = 0u64;
    let mut sink = 0.0f32;

    for iter in 0..4 {
        if iter == 1 {
            misses_after_warm = ws.misses();
            assert!(misses_after_warm > 0, "{label}: warm-up step must populate the arena");
            ALLOCS.store(0, Ordering::SeqCst);
            TRACKING.store(true, Ordering::SeqCst);
        }

        // ---- forward: fused GEMM -> LN -> hadamard -> attention ----
        let mut y = ws.take(t * h);
        let mut pre = ws.take(t * h);
        let epi = k::Epilogue { add1: Some(&x), bias: Some(&bias), add2: None, gelu: true };
        let pw = k::BMat::Packed(&pw_nn);
        k::gemm_fused_into(pool, &x, pw, &mut y, t, h, h, epi, Some(&mut pre));
        let mut ln_y = ws.take(t * h);
        let mut xh = ws.take(t * h);
        let mut inv = ws.take(t);
        k::layernorm_fwd_into(pool, &y, &gain, &beta, &mut ln_y, &mut xh, &mut inv);
        let mut had = ws.take(t * h);
        k::hadamard_fwd_into(&ln_y, &hw, &hb, None, None, &mut had);
        let mut att = ws.take(t * h);
        let mut probs = ws.take(b * nh * l * l);
        k::attention_fwd_into(
            pool, &had, &ln_y, &y, &mask_add, b, nh, l, hd, &mut att, &mut probs,
        );

        // ---- backward: attention VJP -> hadamard VJP -> LN VJP -> dgelu
        //      -> NT-accumulated dx and TN-accumulated dW ----
        let mut dq = ws.take(t * h);
        let mut dk = ws.take(t * h);
        let mut dv = ws.take(t * h);
        let mut scratch = ws.take(b * nh * l * l);
        k::attention_vjp_into(
            pool, &att, &had, &ln_y, &y, &probs, b, nh, l, hd, &mut dq, &mut dk, &mut dv,
            &mut scratch,
        );
        let mut dx = ws.take(t * h);
        let mut dw = ws.take(h);
        let mut db = ws.take(h);
        k::hadamard_vjp_acc_into(
            pool,
            &ln_y,
            &hw,
            None,
            None,
            &dq,
            &mut dx,
            Some(&mut dw),
            Some(&mut db),
            None,
            None,
        );
        let mut dln = ws.take(t * h);
        k::layernorm_vjp_into(pool, &dx, &gain, &xh, &inv, None, None, &mut dln);
        let mut dg = ws.take(t * h);
        k::dgelu_mul_into(pool, &dln, &pre, &mut dg);
        k::matmul_nt_into(pool, &dg, k::NtMat::Packed(&pw_nt), &mut dx, t, h, h, true);
        let mut dwacc = ws.take(h * h);
        k::matmul_tn_acc(pool, &x, &dg, &mut dwacc, t, h, h);

        sink += dx[0] + dwacc[0] + dv[0] + dk[0] + dw[0] + db[0];

        for buf in [
            y, pre, ln_y, xh, had, att, dq, dk, dv, scratch, dx, dln, dg, dwacc,
        ] {
            ws.give(buf);
        }
        ws.give(inv);
        ws.give(dw);
        ws.give(db);
        ws.give(probs);
    }
    TRACKING.store(false, Ordering::SeqCst);

    std::hint::black_box(sink);
    assert_eq!(
        ALLOCS.load(Ordering::SeqCst),
        0,
        "{label}: steps 2..4 must perform zero heap allocations in kernel code"
    );
    assert_eq!(
        ws.misses(),
        misses_after_warm,
        "{label}: steps 2..4 must be served entirely from the arena"
    );
    assert!(ws.hits() > 0);
}

/// Run 4 serve-shaped (forward-only) kernel iterations at the given
/// geometry: the eval path's sequence — fused GEMM with bias+GELU and no
/// pre-activation tap, LayerNorm, **per-example** Hadamard adapter rows
/// (exactly how the multi-tenant serve path applies a gathered bank), and
/// the attention forward. Iterations 1..3 run under the counting
/// allocator and must allocate nothing and never miss the arena.
fn steady_eval_loop(pool: &Pool, b: usize, l: usize, nh: usize, h: usize, label: &str) {
    let hd = h / nh;
    let t = b * l;
    let mut rng = Rng::new(0xE7A1);

    let x = randv(&mut rng, t * h);
    let wmat = randv(&mut rng, h * h);
    let pw_nn = k::PackedMat::pack_nn(&wmat, h, h);
    let bias = randv(&mut rng, h);
    let gain = randv(&mut rng, h);
    let beta = randv(&mut rng, h);
    // per-example adapter rows, as the serve path gathers them from a bank
    let hw_rows = randv(&mut rng, b * h);
    let hb_rows = randv(&mut rng, b * h);
    let mask_add = vec![0.0f32; b * l];

    let mut ws = Workspace::new();
    let mut misses_after_warm = 0u64;
    let mut sink = 0.0f32;
    for iter in 0..4 {
        if iter == 1 {
            misses_after_warm = ws.misses();
            assert!(misses_after_warm > 0, "{label}: warm-up must populate the arena");
            ALLOCS.store(0, Ordering::SeqCst);
            TRACKING.store(true, Ordering::SeqCst);
        }

        let mut y = ws.take_dirty(t * h);
        let epi = k::Epilogue { add1: None, bias: Some(&bias), add2: None, gelu: true };
        k::gemm_fused_into(pool, &x, k::BMat::Packed(&pw_nn), &mut y, t, h, h, epi, None);
        let mut ln_y = ws.take_dirty(t * h);
        let mut xh = ws.take_dirty(t * h);
        let mut inv = ws.take_dirty(t);
        k::layernorm_fwd_into(pool, &y, &gain, &beta, &mut ln_y, &mut xh, &mut inv);
        let mut had = ws.take_dirty(t * h);
        for bi in 0..b {
            k::hadamard_fwd_into(
                &ln_y[bi * l * h..(bi + 1) * l * h],
                &hw_rows[bi * h..(bi + 1) * h],
                &hb_rows[bi * h..(bi + 1) * h],
                None,
                None,
                &mut had[bi * l * h..(bi + 1) * l * h],
            );
        }
        let mut att = ws.take_dirty(t * h);
        let mut probs = ws.take_dirty(b * nh * l * l);
        k::attention_fwd_into(
            pool, &had, &ln_y, &y, &mask_add, b, nh, l, hd, &mut att, &mut probs,
        );

        sink += att[0] + had[0] + ln_y[0] + xh[0];
        for buf in [y, ln_y, xh, had, att, probs] {
            ws.give(buf);
        }
        ws.give(inv);
    }
    TRACKING.store(false, Ordering::SeqCst);

    std::hint::black_box(sink);
    assert_eq!(
        ALLOCS.load(Ordering::SeqCst),
        0,
        "{label}: eval steps 2..4 must perform zero heap allocations in kernel code"
    );
    assert_eq!(
        ws.misses(),
        misses_after_warm,
        "{label}: eval steps 2..4 must be served entirely from the arena"
    );
    assert!(ws.hits() > 0);
}

// ---------------------------------------------------------------------------
// Wire ingress steady state (PR 6): the same counting allocator, but the
// traffic now enters through a real socket against a `WireServer` running
// on its own thread.
// ---------------------------------------------------------------------------

/// Alloc-free test-side HTTP client. Every buffer is sized during setup
/// and reused; a connection is opened per round (connect is a syscall,
/// not a heap allocation) and dropped once its frames are drained, which
/// is also what hands the single-threaded server back to `accept`.
struct WireProbe {
    addr: SocketAddr,
    buf: Vec<u8>,
    stats_resp: Vec<u8>,
}

impl WireProbe {
    fn new(addr: SocketAddr) -> Self {
        Self { addr, buf: Vec::with_capacity(64 * 1024), stats_resp: Vec::with_capacity(4096) }
    }

    /// Open a fresh connection, send `req` (optionally half-closing the
    /// write side, the convention for `truncated-*` fixtures), and read
    /// exactly `nresp` Content-Length-framed responses into `self.buf`.
    fn round(&mut self, req: &[u8], nresp: usize, half_close: bool) {
        let mut s = TcpStream::connect(self.addr).expect("connect to wire server");
        s.write_all(req).unwrap();
        if half_close {
            s.shutdown(Shutdown::Write).unwrap();
        }
        wire_read_frames(&mut s, &mut self.buf, nresp);
    }

    /// A `/stats` round that keeps the raw response bytes so they can be
    /// parsed *after* tracking ends (parsing allocates; copying into the
    /// pre-sized keep buffer does not).
    fn stats_round(&mut self, req: &[u8]) {
        self.round(req, 1, false);
        self.stats_resp.clear();
        self.stats_resp.extend_from_slice(&self.buf);
    }
}

/// Read exactly `n` framed responses into `buf` without allocating: the
/// buffer only ever regrows past its warmed capacity if a response
/// outgrows the 64 KiB high-water mark, which none can.
fn wire_read_frames(s: &mut TcpStream, buf: &mut Vec<u8>, n: usize) {
    buf.clear();
    let mut done = 0usize;
    let mut start = 0usize;
    loop {
        while done < n {
            let Some(rel) = wire_find(&buf[start..], b"\r\n\r\n") else { break };
            let head_end = start + rel + 4;
            assert!(buf[start..].starts_with(b"HTTP/1.1 "), "malformed response frame");
            let total = head_end + wire_content_length(&buf[start..head_end]);
            if buf.len() < total {
                break;
            }
            start = total;
            done += 1;
        }
        if done == n {
            return;
        }
        let old = buf.len();
        buf.resize(old + 4096, 0);
        let r = match s.read(&mut buf[old..]) {
            Ok(r) => r,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {
                buf.truncate(old);
                continue;
            }
            Err(e) => panic!("wire read: {e}"),
        };
        buf.truncate(old + r);
        assert!(r > 0, "server closed after {done} of {n} responses");
    }
}

fn wire_content_length(head: &[u8]) -> usize {
    let mut at = 0;
    while let Some(rel) = wire_find(&head[at..], b"\r\n") {
        let line = &head[at..at + rel];
        at += rel + 2;
        if line.len() >= 15 && line[..15].eq_ignore_ascii_case(b"content-length:") {
            let mut v = 0usize;
            for &b in &line[15..] {
                if b != b' ' {
                    v = v * 10 + (b - b'0') as usize;
                }
            }
            return v;
        }
    }
    0
}

fn wire_find(hay: &[u8], needle: &[u8]) -> Option<usize> {
    hay.windows(needle.len()).position(|w| w == needle)
}

fn wire_post_infer(body: &str) -> Vec<u8> {
    format!("POST /infer HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}", body.len()).into_bytes()
}

struct WireCounters {
    replies: u64,
    batches: u64,
    rejects: u64,
    cross_conn_waves: u64,
    conns_open: u64,
    arena_misses: u64,
    pool_threads_spawned: u64,
    repacks: u64,
    bank_cold_faults: u64,
}

/// Parse the server + engine counters out of a kept `/stats` response.
/// Allocates freely — only ever called outside the tracked region.
fn parse_wire_stats(resp: &[u8]) -> WireCounters {
    let head_end = wire_find(resp, b"\r\n\r\n").expect("stats response head") + 4;
    let body = std::str::from_utf8(&resp[head_end..]).unwrap();
    let v = hadapt::util::json::parse(body).unwrap();
    let n = |k: &str| v.get(k).unwrap().as_usize().unwrap() as u64;
    WireCounters {
        replies: n("replies"),
        batches: n("batches"),
        rejects: n("rejects_http") + n("rejects_parse") + n("rejects_submit"),
        cross_conn_waves: n("cross_conn_waves"),
        conns_open: n("conns_open"),
        arena_misses: n("arena_misses"),
        pool_threads_spawned: n("pool_threads_spawned"),
        repacks: n("repacks"),
        bank_cold_faults: n("bank_cold_faults"),
    }
}

/// Serve traffic through the socket front door for 4 rounds. Round 0
/// warms every path — connection buffers, parser scratch, resident batch
/// buffers, response scratch, the engine's arena and its worker thread.
/// Rounds 1..3 run under the counting allocator: a full pipelined wave,
/// the entire adversarial fixture corpus over fresh connections, and a
/// final tracked `/stats` round must allocate nothing process-wide, and
/// the counters parsed from `/stats` must show zero new arena misses,
/// zero thread spawns, and zero frozen-weight repacks.
fn steady_wire_loop() {
    let (addr, handle) = spawn_synthetic_server(SpawnOpts::tiny(41)).expect("spawn wire server");

    // ---- setup (untracked): pre-serialize every request byte string ----
    let long_ids = (0..40).map(|i| (i * 7 % 512).to_string()).collect::<Vec<_>>().join(",");
    let wave: Vec<u8> = [
        wire_post_infer("{\"task\":\"sst2\",\"text_a\":[1,2,3]}"),
        wire_post_infer("{\"task\":\"rte\",\"text_a\":[4,5],\"text_b\":[6,7]}"),
        // escaped task name: the parser's unescape scratch runs tracked
        wire_post_infer("{\"task\":\"sst\\u0032\",\"text_a\":[8,9]}"),
        // over-length text_a: the truncation path runs tracked
        wire_post_infer(&format!("{{\"task\":\"sst2\",\"text_a\":[{long_ids}]}}")),
    ]
    .concat();
    let stats_req = b"GET /stats HTTP/1.1\r\n\r\n".to_vec();
    let shutdown_req = b"POST /shutdown HTTP/1.1\r\nContent-Length: 0\r\n\r\n".to_vec();

    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/wire");
    let fixtures: Vec<(bool, bool, Vec<u8>)> = std::fs::read_dir(dir)
        .expect("fixture corpus missing — run tools/gen_wire_fixtures.py")
        .map(|e| {
            let p = e.unwrap().path();
            let name = p.file_stem().unwrap().to_str().unwrap().to_string();
            let code = name.split("__").next().unwrap();
            (code == "ok", code.starts_with("truncated"), std::fs::read(&p).unwrap())
        })
        .collect();
    let ok_n = fixtures.iter().filter(|f| f.0).count() as u64;
    let err_n = fixtures.len() as u64 - ok_n;
    assert!(ok_n >= 3 && err_n >= 25, "corpus shape: {ok_n} ok / {err_n} err");

    // ---- round 0 (untracked warm-up, same traffic shape as tracked) ----
    let mut probe = WireProbe::new(addr);
    probe.round(&wave, 4, false);
    for (_, half_close, bytes) in &fixtures {
        probe.round(bytes, 1, *half_close);
    }
    probe.stats_round(&stats_req);
    let s0 = parse_wire_stats(&probe.stats_resp);
    assert_eq!(s0.pool_threads_spawned, 1, "tiny server: one worker, spawned at warm-up");
    assert_eq!(s0.replies, 4 + ok_n);

    // ---- rounds 1..3 under the counting allocator ----
    ALLOCS.store(0, Ordering::SeqCst);
    TRACKING.store(true, Ordering::SeqCst);
    for _ in 0..3 {
        probe.round(&wave, 4, false);
        for (_, half_close, bytes) in &fixtures {
            probe.round(bytes, 1, *half_close);
        }
    }
    // the /stats render path itself must also be alloc-free
    probe.stats_round(&stats_req);
    TRACKING.store(false, Ordering::SeqCst);

    assert_eq!(
        ALLOCS.load(Ordering::SeqCst),
        0,
        "wire rounds 2..4 must allocate nothing on either side of the socket"
    );
    let s1 = parse_wire_stats(&probe.stats_resp);
    assert_eq!(s1.arena_misses, s0.arena_misses, "steady wire waves never miss the arena");
    assert_eq!(s1.pool_threads_spawned, s0.pool_threads_spawned, "and never spawn a thread");
    assert_eq!(s1.repacks, s0.repacks, "and never repack frozen weights");
    assert_eq!(s1.replies - s0.replies, 3 * (4 + ok_n));
    assert_eq!(s1.batches - s0.batches, 3 * (1 + ok_n));
    assert_eq!(s1.rejects - s0.rejects, 3 * err_n);

    probe.round(&shutdown_req, 1, false);
    let st = handle.join().unwrap().expect("server exits cleanly on /shutdown");
    assert_eq!(st.replies, 4 * (4 + ok_n));
    assert_eq!(st.batches, 4 * (1 + ok_n));
    assert_eq!(st.rejects_http + st.rejects_parse + st.rejects_submit, 4 * err_n);
}

/// Alloc-free test-side client for the multi-connection act: four
/// persistent connections plus one reusable read buffer, every byte
/// string pre-serialized during setup.
struct MultiConnProbe {
    conns: Vec<TcpStream>,
    buf: Vec<u8>,
    stats_resp: Vec<u8>,
}

impl MultiConnProbe {
    fn new(addr: SocketAddr, n: usize) -> Self {
        Self {
            conns: (0..n).map(|_| TcpStream::connect(addr).expect("connect")).collect(),
            buf: Vec::with_capacity(64 * 1024),
            stats_resp: Vec::with_capacity(4096),
        }
    }

    /// One concurrent wave: write request `i` down connection `i` (all
    /// four before reading anything, so the rows land in one shared
    /// queue window), then read exactly one reply per connection and
    /// assert it names that connection's own tenant — a reply routed
    /// off another connection would carry a foreign task name.
    fn wave(&mut self, reqs: &[Vec<u8>], needles: &[Vec<u8>]) {
        let MultiConnProbe { conns, buf, .. } = self;
        for (c, req) in conns.iter_mut().zip(reqs) {
            c.write_all(req).unwrap();
        }
        for (c, needle) in conns.iter_mut().zip(needles) {
            wire_read_frames(c, buf, 1);
            assert!(buf.starts_with(b"HTTP/1.1 200"), "multi-conn wave reply: {buf:?}");
            assert!(
                wire_find(buf, needle).is_some(),
                "reply bled across connections: wanted {:?} in {:?}",
                std::str::from_utf8(needle),
                std::str::from_utf8(buf)
            );
        }
    }

    /// A `/stats` round down connection 0, keeping the raw bytes for
    /// untracked parsing later.
    fn stats_round(&mut self, req: &[u8]) {
        let MultiConnProbe { conns, buf, stats_resp } = self;
        conns[0].write_all(req).unwrap();
        wire_read_frames(&mut conns[0], buf, 1);
        stats_resp.clear();
        stats_resp.extend_from_slice(buf);
    }
}

/// Four concurrent connections serve shared waves for 4 rounds. Round 0
/// warms everything — the connection-slot table entries, the shared
/// decode scratch and response accumulator, the session's resident
/// batch buffers. Rounds 1..3 run under the counting allocator: two
/// waves per round, each wave one request from each of the four
/// connections gathered into a single four-row micro-batch
/// (`queue_cap = 4` forces the flush the moment all four rows are in,
/// and WRR admission places one row per tenant), must allocate nothing
/// process-wide. The `/stats` deltas then pin the shape exactly: +24
/// replies, +6 batches, +6 cross-connection waves, with arena misses,
/// thread spawns, repacks and bank faults all frozen.
fn steady_multi_conn_loop() {
    let mut opts = SpawnOpts::tiny(43);
    opts.tasks = vec![
        "sst2".to_string(),
        "rte".to_string(),
        "mrpc".to_string(),
        "cola".to_string(),
    ];
    // a 4-row cap flushes the instant the fourth connection's row lands;
    // the long window is only the fallback if a scan ever sees fewer
    opts.policy = ServePolicy { queue_cap: 4, window_us: 50_000, ..ServePolicy::default() };
    let (addr, handle) = spawn_synthetic_server(opts).expect("spawn wire server");

    // ---- setup (untracked): pre-serialize per-connection bytes ----
    let tasks = ["sst2", "rte", "mrpc", "cola"];
    let reqs: Vec<Vec<u8>> = tasks
        .iter()
        .enumerate()
        .map(|(i, t)| {
            wire_post_infer(&format!(
                "{{\"task\":\"{t}\",\"text_a\":[{},{},{}]}}",
                3 + i,
                4 + i,
                5 + i
            ))
        })
        .collect();
    let needles: Vec<Vec<u8>> =
        tasks.iter().map(|t| format!("\"task\":\"{t}\"").into_bytes()).collect();
    let stats_req = b"GET /stats HTTP/1.1\r\n\r\n".to_vec();
    let shutdown_req = b"POST /shutdown HTTP/1.1\r\nContent-Length: 0\r\n\r\n".to_vec();

    // ---- round 0 (untracked warm-up, same traffic shape as tracked) ----
    let mut probe = MultiConnProbe::new(addr, 4);
    for _ in 0..2 {
        probe.wave(&reqs, &needles);
    }
    probe.stats_round(&stats_req);
    let s0 = parse_wire_stats(&probe.stats_resp);
    assert_eq!(s0.conns_open, 4, "all four connections resident after warm-up");
    assert_eq!(s0.replies, 8);
    assert_eq!(s0.batches, 2, "each warm wave is one four-row micro-batch");
    assert_eq!(s0.cross_conn_waves, 2, "each warm wave mixes all four connections");
    assert_eq!(s0.pool_threads_spawned, 1);

    // ---- rounds 1..3 under the counting allocator ----
    ALLOCS.store(0, Ordering::SeqCst);
    TRACKING.store(true, Ordering::SeqCst);
    for _ in 0..3 {
        for _ in 0..2 {
            probe.wave(&reqs, &needles);
        }
    }
    probe.stats_round(&stats_req);
    TRACKING.store(false, Ordering::SeqCst);

    assert_eq!(
        ALLOCS.load(Ordering::SeqCst),
        0,
        "steady four-connection rounds must allocate nothing on either side of the socket"
    );
    let s1 = parse_wire_stats(&probe.stats_resp);
    assert_eq!(s1.replies - s0.replies, 24, "3 rounds x 2 waves x 4 connections");
    assert_eq!(s1.batches - s0.batches, 6, "every tracked wave is one micro-batch");
    assert_eq!(
        s1.cross_conn_waves - s0.cross_conn_waves,
        6,
        "every tracked wave mixes connections"
    );
    assert_eq!(s1.conns_open, 4, "no slot churn during the steady rounds");
    assert_eq!(s1.arena_misses, s0.arena_misses, "steady multi-conn waves never miss the arena");
    assert_eq!(s1.pool_threads_spawned, s0.pool_threads_spawned, "and never spawn a thread");
    assert_eq!(s1.repacks, s0.repacks, "and never repack frozen weights");
    assert_eq!(s1.bank_cold_faults, s0.bank_cold_faults, "and never fault the bank tier");
    assert_eq!(s1.rejects, 0);

    // shutdown from connection 0 drains the other three gracefully
    probe.stats_round(&shutdown_req);
    let st = handle.join().unwrap().expect("server exits cleanly on /shutdown");
    assert_eq!(st.replies, 32);
    assert_eq!(st.connections, 4);
    assert_eq!(st.conns_rejected, 0);
}

/// One serve round over the resident working set: two-row waves through
/// the borrowed (wire-shaped) submit path, replies drained by borrow.
fn bank_round(session: &mut ServeSession<'_>, working: &[&str], seqs: &[&[i32]], sink: &mut f32) {
    for (pair, sq) in working.chunks(2).zip(seqs.chunks(2)) {
        for (task, seq) in pair.iter().zip(sq) {
            session.submit_borrowed(task, seq, None).expect("resident submit");
        }
        session.run_direct().expect("resident wave");
        for r in session.direct_replies() {
            *sink += r.logits[0];
        }
    }
}

/// Serve a hot-resident working set from a tiered on-disk bank for 4
/// rounds. Round 0 faults the working set into the hot tier (allocating:
/// slot growth, index strings, batch-buffer warm-up); rounds 1..3 run
/// under the counting allocator — every lookup must be a hot hit and the
/// tiered bank must add zero allocations to the serve path's zero. An
/// online compaction (generation swap) between steady phases must be
/// invisible: three more tracked rounds after it stay at zero
/// allocations with the tier counters frozen.
fn steady_bank_loop() {
    // ---- setup (untracked): fleet -> bank file -> tiered session ----
    let engine = Engine::new_with_threads("/definitely/not/a/dir", 2).expect("engine");
    let info = engine.manifest().model("tiny").unwrap().clone();
    let store = ParamStore::init(&info, 97);
    let bases =
        synthetic_adapters(&info, &store, &["sst2".to_string(), "rte".to_string()], 97).unwrap();
    let fleet: Vec<TaskAdapter> = (0..6).map(|i| synthetic_tenant(&bases, i, 97)).collect();
    let classes = info.params[info.param_index("classifier.bias").unwrap()].shape[0];
    let geom = BankGeometry { layers: info.layers, hidden: info.hidden, classes };
    let path =
        std::env::temp_dir().join(format!("hadapt_alloc_bank_{}.bank", std::process::id()));
    let mut builder = BankBuilder::new(geom, bases, 0.0).unwrap();
    for t in &fleet {
        builder.add_tenant(t).unwrap();
    }
    builder.write(&path).unwrap();

    let mut session = ServeSession::new(&engine, "tiny", &store, 2).expect("session");
    session.attach_store(BankReader::open(&path).expect("open bank"), 4).expect("attach");
    let working: [&str; 4] = ["sst2", "rte", "t000002", "t000003"];
    let seqs: [&[i32]; 4] = [&[5, 6, 7], &[9, 10], &[3, 4, 5, 6], &[11]];
    let mut sink = 0.0f32;

    // round 0 (untracked): fault the whole working set in, warm buffers
    bank_round(&mut session, &working, &seqs, &mut sink);
    let warm = session.bank().bank_stats();
    assert_eq!(warm.cold_faults, 4, "warm-up faults the whole working set in");
    assert_eq!(warm.evictions, 0, "a 4-slot tier holds the 4-tenant working set");

    // ---- rounds 1..3 under the counting allocator ----
    ALLOCS.store(0, Ordering::SeqCst);
    TRACKING.store(true, Ordering::SeqCst);
    for _ in 0..3 {
        bank_round(&mut session, &working, &seqs, &mut sink);
    }
    TRACKING.store(false, Ordering::SeqCst);
    std::hint::black_box(sink);

    assert_eq!(
        ALLOCS.load(Ordering::SeqCst),
        0,
        "hot-resident tiered serve must add zero allocations to the serve path"
    );
    let steady = session.bank().bank_stats();
    assert_eq!(steady.cold_faults, warm.cold_faults, "steady rounds never fault");
    assert_eq!(steady.evictions, warm.evictions, "or evict");
    assert_eq!(steady.hot_hits - warm.hot_hits, 12, "every steady lookup is a hot hit");

    // ---- online compaction is invisible to the steady path ----
    // (untracked: the rewrite itself may allocate — it is a maintenance
    // op, not a serve op)
    let summary = session.compact_bank().expect("online compact");
    assert_eq!(summary.generation, 1);
    assert_eq!(session.bank().store().unwrap().generation(), 1);

    // the generation swap must leave the serve path exactly as it was:
    // zero allocations, zero new faults or evictions, all hot hits
    ALLOCS.store(0, Ordering::SeqCst);
    TRACKING.store(true, Ordering::SeqCst);
    for _ in 0..3 {
        bank_round(&mut session, &working, &seqs, &mut sink);
    }
    TRACKING.store(false, Ordering::SeqCst);
    assert_eq!(
        ALLOCS.load(Ordering::SeqCst),
        0,
        "steady serve across an online compaction must stay allocation-free"
    );
    let post = session.bank().bank_stats();
    assert_eq!(post.cold_faults, steady.cold_faults, "the swap never re-faults the hot tier");
    assert_eq!(post.evictions, steady.evictions);
    assert_eq!(post.hot_hits - steady.hot_hits, 12);

    // a cold tenant still faults in after the steady phase, evicting one
    // resident entry to make room (untracked: faults may allocate)
    session.submit_borrowed("t000004", &[2, 3], None).expect("cold fault");
    session.run_direct().unwrap();
    let after = session.bank().bank_stats();
    assert_eq!(after.cold_faults, steady.cold_faults + 1);
    assert_eq!(after.evictions, steady.evictions + 1);
    std::fs::remove_file(&path).ok();
}

#[test]
fn kernel_steady_state_allocates_nothing_and_spawns_nothing() {
    // Serial pool: the original PR 3 zero-allocation contract. A serial
    // pool never spawns, trivially.
    let serial = Pool::serial();
    steady_kernel_loop(&serial, 2, 8, 2, 16, "serial");
    assert_eq!(serial.stats().threads_spawned, 0, "serial pools never spawn");
    assert_eq!(serial.stats().jobs_dispatched, 0);

    // Persistent 2-worker pool at a geometry whose GEMM (64 rows > the
    // 16-row grain), LayerNorm (64 rows > the 32-row grain) and attention
    // (16 batch*head items) kernels genuinely fork. The worker spawns in
    // iteration 0 (untracked warm-up); iterations 1..3 run under the
    // counting allocator, so a stray spawn OR a dispatch-path allocation
    // would trip the zero-alloc assertion — and the spawn counter below
    // pins it explicitly.
    let pool = Pool::with_threads(2);
    steady_kernel_loop(&pool, 8, 8, 2, 16, "2-worker");
    let st = pool.stats();
    assert_eq!(st.threads_spawned, 1, "exactly one worker, spawned once at warm-up");
    assert!(st.jobs_dispatched > 0, "the larger geometry must actually fork");

    // The serve path's forward-only sequence holds the same contract —
    // serially and on the already-warm persistent pool (which must not
    // spawn again for eval work).
    steady_eval_loop(&serial, 2, 8, 2, 16, "serial-eval");
    steady_eval_loop(&pool, 8, 8, 2, 16, "2-worker-eval");
    assert_eq!(
        pool.stats().threads_spawned,
        1,
        "eval dispatch reuses the persistent worker"
    );

    // The whole serve stack through a real socket: waves of pipelined
    // /infer requests plus the adversarial fixture corpus hold the same
    // zero-alloc / zero-spawn / zero-repack steady state. Runs after the
    // kernel-level loops so they see an unpolluted allocator.
    steady_wire_loop();

    // Concurrency adds nothing to the zero: four persistent connections
    // multiplexed into shared waves hold the same steady state, with the
    // wave/reply counters advancing by exactly their predicted deltas.
    steady_multi_conn_loop();

    // And the tiered bank: once the working set is hot-resident, paging
    // machinery (LRU stamps, the cold-tier index) must be invisible to
    // the allocator.
    steady_bank_loop();
}
