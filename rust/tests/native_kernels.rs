//! Golden-fixture parity tests for the native kernels.
//!
//! `tests/fixtures/native_kernels.json` is generated once from the JAX
//! oracles in `python/compile/kernels/ref.py` (forward + VJP values; see
//! `python/tools/gen_golden_fixtures.py`) and checked in, so this suite
//! pins the native hadamard / layernorm / attention kernels — and the
//! Hadamard-group backward — against the L1 ground truth with no Python
//! at test time.

use hadapt::runtime::kernels as k;
use hadapt::runtime::Pool;
use hadapt::util::json::{self, Json};

/// Fixed 2-worker pool: exercises the sharded kernel paths against the
/// JAX oracles deterministically on any machine.
fn pool() -> Pool {
    Pool::with_threads(2)
}

struct Arr {
    shape: Vec<usize>,
    data: Vec<f32>,
}

fn load() -> Json {
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/native_kernels.json"
    );
    let text = std::fs::read_to_string(path).expect("fixture file");
    json::parse(&text).expect("fixture json")
}

fn arr(j: &Json, key: &str) -> Arr {
    let a = j.get(key).unwrap();
    let shape: Vec<usize> = a
        .get("shape")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_usize().unwrap())
        .collect();
    let data: Vec<f32> = a
        .get("data")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect();
    assert_eq!(shape.iter().product::<usize>(), data.len());
    Arr { shape, data }
}

const TOL: f32 = 1e-5;

fn assert_close(got: &[f32], want: &Arr, what: &str) {
    assert_eq!(got.len(), want.data.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(&want.data).enumerate() {
        assert!(
            (g - w).abs() <= TOL * (1.0 + w.abs()),
            "{what}[{i}]: got {g}, oracle {w}"
        );
    }
}

// ----------------------------------------------------------------- hadamard

#[test]
fn hadamard_forward_matches_oracle() {
    let f = load();
    let h = f.get("hadamard").unwrap();
    let (x, w, b) = (arr(h, "x"), arr(h, "w"), arr(h, "b"));
    let (w2, w3) = (arr(h, "w2"), arr(h, "w3"));
    let y1 = k::hadamard_fwd(&x.data, &w.data, &b.data, None, None);
    assert_close(&y1, &arr(h, "y1"), "hadamard y1");
    let y3 = k::hadamard_fwd(&x.data, &w.data, &b.data, Some(&w2.data), Some(&w3.data));
    assert_close(&y3, &arr(h, "y3"), "hadamard y3");
}

#[test]
fn hadamard_backward_matches_oracle() {
    let f = load();
    let h = f.get("hadamard").unwrap();
    let (x, w) = (arr(h, "x"), arr(h, "w"));
    let (w2, w3) = (arr(h, "w2"), arr(h, "w3"));
    let dy = arr(h, "dy");
    let g = k::hadamard_vjp(&pool(), &x.data, &w.data, Some(&w2.data), Some(&w3.data), &dy.data);
    assert_close(&g.dx, &arr(h, "dx"), "hadamard dx");
    assert_close(&g.dw, &arr(h, "dw"), "hadamard dw");
    assert_close(&g.db, &arr(h, "db"), "hadamard db");
    assert_close(g.dw2.as_ref().unwrap(), &arr(h, "dw2"), "hadamard dw2");
    assert_close(g.dw3.as_ref().unwrap(), &arr(h, "dw3"), "hadamard dw3");
}

#[test]
fn hadamard_identity_init_is_bit_exact_noop() {
    // Paper Sec. 3.1: w=1, b=0 (w2=w3=0) is "equivalent to not adding any
    // adapter" — the native kernel honors that bit-exactly.
    let f = load();
    let h = f.get("hadamard").unwrap();
    let x = arr(h, "x");
    let hdim = x.shape[1];
    let ones = vec![1.0f32; hdim];
    let zeros = vec![0.0f32; hdim];
    let y = k::hadamard_fwd(&x.data, &ones, &zeros, Some(&zeros), Some(&zeros));
    assert_eq!(y, x.data, "identity-init adapter changed the activations");
}

// ---------------------------------------------------------------- layernorm

#[test]
fn layernorm_forward_matches_oracle() {
    let f = load();
    let ln = f.get("layernorm").unwrap();
    let (x, g, b) = (arr(ln, "x"), arr(ln, "g"), arr(ln, "b"));
    let (y, _) = k::layernorm_fwd(&pool(), &x.data, &g.data, &b.data);
    assert_close(&y, &arr(ln, "y"), "layernorm y");
}

#[test]
fn layernorm_backward_matches_oracle() {
    let f = load();
    let ln = f.get("layernorm").unwrap();
    let (x, g, b) = (arr(ln, "x"), arr(ln, "g"), arr(ln, "b"));
    let dy = arr(ln, "dy");
    let (_, cache) = k::layernorm_fwd(&pool(), &x.data, &g.data, &b.data);
    let hdim = g.data.len();
    let mut dg = vec![0.0f32; hdim];
    let mut db = vec![0.0f32; hdim];
    let dx = k::layernorm_vjp(&pool(), &dy.data, &g.data, &cache, Some(&mut dg), Some(&mut db));
    assert_close(&dx, &arr(ln, "dx"), "layernorm dx");
    assert_close(&dg, &arr(ln, "dg"), "layernorm dg");
    assert_close(&db, &arr(ln, "db"), "layernorm db");
}

// ---------------------------------------------------------------- attention

#[test]
fn attention_forward_matches_oracle() {
    let f = load();
    let at = f.get("attention").unwrap();
    let (q, kk, v) = (arr(at, "q"), arr(at, "k"), arr(at, "v"));
    let mask = arr(at, "mask_add");
    let (b, nh, l, d) = (q.shape[0], q.shape[1], q.shape[2], q.shape[3]);
    let (out, probs) =
        k::attention_fwd(&pool(), &q.data, &kk.data, &v.data, &mask.data, b, nh, l, d);
    assert_close(&out, &arr(at, "out"), "attention out");
    // probs rows are simplex points
    for row in probs.chunks_exact(l) {
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(row.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }
}

#[test]
fn attention_backward_matches_oracle() {
    let f = load();
    let at = f.get("attention").unwrap();
    let (q, kk, v) = (arr(at, "q"), arr(at, "k"), arr(at, "v"));
    let mask = arr(at, "mask_add");
    let dy = arr(at, "dy");
    let (b, nh, l, d) = (q.shape[0], q.shape[1], q.shape[2], q.shape[3]);
    let (_, probs) = k::attention_fwd(&pool(), &q.data, &kk.data, &v.data, &mask.data, b, nh, l, d);
    let (dq, dk, dv) =
        k::attention_vjp(&pool(), &dy.data, &q.data, &kk.data, &v.data, &probs, b, nh, l, d);
    assert_close(&dq, &arr(at, "dq"), "attention dq");
    assert_close(&dk, &arr(at, "dk"), "attention dk");
    assert_close(&dv, &arr(at, "dv"), "attention dv");
}

// ------------------------------------------------- masked positions get ~0

#[test]
fn attention_masked_keys_get_zero_probability() {
    let f = load();
    let at = f.get("attention").unwrap();
    let (q, kk, v) = (arr(at, "q"), arr(at, "k"), arr(at, "v"));
    let mask = arr(at, "mask_add");
    let (b, nh, l, d) = (q.shape[0], q.shape[1], q.shape[2], q.shape[3]);
    let (_, probs) = k::attention_fwd(&pool(), &q.data, &kk.data, &v.data, &mask.data, b, nh, l, d);
    for bi in 0..b {
        for hi in 0..nh {
            for i in 0..l {
                for j in 0..l {
                    if mask.data[bi * l + j] < -1e8 {
                        let p = probs[((bi * nh + hi) * l + i) * l + j];
                        assert!(p < 1e-12, "masked key {bi}/{hi}/{i}/{j} got {p}");
                    }
                }
            }
        }
    }
}
