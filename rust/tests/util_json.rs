//! Dedicated test suite for `util::json` — the persistence layer under the
//! run cache, the result tables and the AOT manifest. Property-style
//! round-trip coverage (hand-rolled generator loop; `util::Rng` drives
//! randomized cases with stable seeds so failures are reproducible) plus
//! targeted escape/ordering/error cases.

use hadapt::util::json::{self, Json};
use hadapt::util::Rng;

const CASES: usize = 120;

fn gen_value(rng: &mut Rng, depth: usize) -> Json {
    match if depth > 3 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.chance(0.5)),
        2 => {
            // mix of integers, negatives and fractions
            let base = (rng.next_u64() % 2_000_000) as f64 - 1_000_000.0;
            Json::Num(base / [1.0, 2.0, 8.0, 1000.0][rng.below(4)])
        }
        3 => {
            let n = rng.range(0, 12);
            Json::Str(
                (0..n)
                    .map(|_| match rng.below(6) {
                        0 => '"',
                        1 => '\\',
                        2 => '\n',
                        3 => '\t',
                        4 => char::from_u32(rng.range(0x20, 0x2500) as u32).unwrap_or('x'),
                        _ => char::from_u32(rng.range(1, 0x20) as u32).unwrap_or('\u{1}'),
                    })
                    .collect(),
            )
        }
        4 => Json::Arr((0..rng.range(0, 5)).map(|_| gen_value(rng, depth + 1)).collect()),
        _ => {
            let mut o = Json::obj();
            let n = rng.range(0, 5);
            for i in 0..n {
                o.set(&format!("key_{i}"), gen_value(rng, depth + 1));
            }
            o
        }
    }
}

#[test]
fn prop_parse_write_parse_is_identity() {
    let mut rng = Rng::new(0x1A50_2024);
    for case in 0..CASES {
        let v = gen_value(&mut rng, 0);
        let compact = v.render();
        let back = json::parse(&compact)
            .unwrap_or_else(|e| panic!("case {case} compact: {e}\n{compact}"));
        assert_eq!(back, v, "case {case} compact");
        let pretty = v.render_pretty();
        let back = json::parse(&pretty)
            .unwrap_or_else(|e| panic!("case {case} pretty: {e}\n{pretty}"));
        assert_eq!(back, v, "case {case} pretty");
        // write is deterministic: render(parse(render(v))) == render(v)
        assert_eq!(back.render(), compact, "case {case} stability");
    }
}

#[test]
fn prop_key_order_preserved_through_roundtrip() {
    let mut rng = Rng::new(0xBEEF_CAFE);
    for case in 0..CASES {
        let n = rng.range(1, 10);
        let mut o = Json::obj();
        let mut names: Vec<String> = Vec::new();
        for _ in 0..n {
            // shuffled, non-sorted key names
            let name = format!("k{}", rng.next_u64() % 10_000);
            if !names.contains(&name) {
                o.set(&name, Json::num(rng.below(100) as f64));
                names.push(name);
            }
        }
        let back = json::parse(&o.render()).unwrap();
        let keys: Vec<String> = back
            .as_obj()
            .unwrap()
            .iter()
            .map(|(kk, _)| kk.clone())
            .collect();
        assert_eq!(keys, names, "case {case}: insertion order lost");
        // duplicate set() overwrites in place, keeping position
        if let Some(first) = names.first() {
            let mut o2 = back.clone();
            o2.set(first, Json::str("overwritten"));
            let keys2: Vec<String> = o2
                .as_obj()
                .unwrap()
                .iter()
                .map(|(kk, _)| kk.clone())
                .collect();
            assert_eq!(keys2, names, "case {case}: overwrite moved key");
        }
    }
}

#[test]
fn escape_handling_exhaustive() {
    let nasty = "quote\" back\\slash new\nline tab\t cr\r ctrl\u{1} unicode é漢 done";
    let v = Json::str(nasty);
    let text = v.render();
    // the rendered form is ASCII-safe for control chars
    assert!(text.contains("\\\""));
    assert!(text.contains("\\\\"));
    assert!(text.contains("\\n"));
    assert!(text.contains("\\t"));
    assert!(text.contains("\\r"));
    assert!(text.contains("\\u0001"));
    let back = json::parse(&text).unwrap();
    assert_eq!(back.as_str().unwrap(), nasty);
    // \u escapes parse too (incl. surrogate-free BMP chars)
    assert_eq!(json::parse(r#""é""#).unwrap().as_str().unwrap(), "é");
    assert_eq!(json::parse(r#""\/""#).unwrap().as_str().unwrap(), "/");
    assert_eq!(json::parse(r#""\b\f""#).unwrap().as_str().unwrap(), "\u{8}\u{c}");
}

#[test]
fn number_fidelity() {
    // integers survive exactly up to 2^53-ish; render stays integral
    for n in ["0", "7", "-13", "123456789", "9007199254740991"] {
        let v = json::parse(n).unwrap();
        assert_eq!(v.render(), n, "integer {n}");
    }
    let v = json::parse("-1.5e3").unwrap();
    assert_eq!(v.as_f64().unwrap(), -1500.0);
    let v = json::parse("0.125").unwrap();
    assert_eq!(v.as_f64().unwrap(), 0.125);
    // round-trips through render
    let text = v.render();
    assert_eq!(json::parse(&text).unwrap().as_f64().unwrap(), 0.125);
}

#[test]
fn malformed_inputs_error_not_panic() {
    for bad in [
        "",
        "{",
        "}",
        "[1,]",
        "{\"a\" 1}",
        "{\"a\": }",
        "{a: 1}",
        "[1 2]",
        "12 34",
        "tru",
        "nul",
        "\"unterminated",
        "\"bad \\x escape\"",
        "\"bad \\u12 escape\"",
        "{\"a\": 1,}",
        "[,]",
        "+-3",
        "--1",
        "1.2.3",
    ] {
        assert!(json::parse(bad).is_err(), "accepted malformed input: {bad:?}");
    }
}

#[test]
fn typed_accessor_errors() {
    let v = json::parse(r#"{"s": "x", "n": 3, "b": true, "a": [1]}"#).unwrap();
    assert!(v.get("s").unwrap().as_str().is_ok());
    assert!(v.get("s").unwrap().as_f64().is_err());
    assert!(v.get("n").unwrap().as_usize().is_ok());
    assert!(v.get("n").unwrap().as_bool().is_err());
    assert!(v.get("b").unwrap().as_bool().is_ok());
    assert!(v.get("a").unwrap().as_arr().is_ok());
    assert!(v.get("a").unwrap().as_obj().is_err());
    assert!(v.get("missing").is_err());
    assert!(v.opt("missing").is_none());
    assert!(Json::Null.get("x").is_err());
    // str_vec rejects mixed arrays
    assert!(json::parse(r#"["a", 1]"#).unwrap().str_vec().is_err());
    assert_eq!(
        json::parse(r#"["a", "b"]"#).unwrap().str_vec().unwrap(),
        vec!["a".to_string(), "b".to_string()]
    );
}
