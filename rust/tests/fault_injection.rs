//! Fault-injection drills: arm each named fault point on the serve path
//! and prove the failure degrades to a **typed** outcome with the server
//! still serving afterwards — the four faults the robustness contract
//! names (forced queue-full, forced slow tenant, a torn reply write,
//! a panic mid-wave).
//!
//! This suite lives in its own test binary on purpose: the armed-point
//! table is process-global, so arming in a shared binary could perturb
//! unrelated parallel tests. Within this binary, tests serialize on a
//! mutex and each leaves every point disarmed.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Mutex;

use hadapt::runtime::{faultpoint, spawn_synthetic_server, SpawnOpts};

static SERIAL: Mutex<()> = Mutex::new(());

fn post_infer(body: &str) -> Vec<u8> {
    format!("POST /infer HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}", body.len()).into_bytes()
}

const SST2: &str = r#"{"task":"sst2","text_a":[5,6,7]}"#;
const SHUTDOWN: &[u8] = b"POST /shutdown HTTP/1.1\r\nContent-Length: 0\r\n\r\n";

/// Send one request and read one full response frame.
fn roundtrip(stream: &mut TcpStream, req: &[u8]) -> (u16, String) {
    stream.write_all(req).unwrap();
    let mut buf = Vec::new();
    let mut chunk = [0u8; 4096];
    let head_end = loop {
        if let Some(i) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break i + 4;
        }
        let n = stream.read(&mut chunk).unwrap();
        assert!(n > 0, "eof mid-head: {:?}", String::from_utf8_lossy(&buf));
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8(buf[..head_end].to_vec()).unwrap();
    let status: u16 = head.split_whitespace().nth(1).unwrap().parse().unwrap();
    let cl: usize = head
        .lines()
        .find(|l| l.to_ascii_lowercase().starts_with("content-length:"))
        .map(|l| l.split(':').nth(1).unwrap().trim().parse().unwrap())
        .unwrap_or(0);
    while buf.len() < head_end + cl {
        let n = stream.read(&mut chunk).unwrap();
        assert!(n > 0, "eof mid-body");
        buf.extend_from_slice(&chunk[..n]);
    }
    (status, String::from_utf8_lossy(&buf[head_end..head_end + cl]).to_string())
}

fn connect(addr: SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).unwrap();
    s.set_nodelay(true).unwrap();
    s
}

#[test]
fn forced_queue_full_sheds_typed_503_then_recovers() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    faultpoint::reset();
    let (addr, handle) = spawn_synthetic_server(SpawnOpts::tiny(101)).unwrap();
    let mut c = connect(addr);

    faultpoint::arm("serve.queue-full", 1);
    let (status, body) = roundtrip(&mut c, &post_infer(SST2));
    assert_eq!(status, 503, "{body}");
    assert!(body.contains("\"error\":\"queue-full\""), "{body}");

    // the injected rejection consumed the armed hit: same connection,
    // next request serves
    let (status, body) = roundtrip(&mut c, &post_infer(SST2));
    assert_eq!(status, 200, "{body}");

    let (status, _) = roundtrip(&mut c, SHUTDOWN);
    assert_eq!(status, 200);
    let stats = handle.join().unwrap().unwrap();
    assert_eq!(stats.rejects_shed, 1);
    assert_eq!(stats.replies, 1);
}

#[test]
fn forced_slow_tenant_throttles_typed_429_then_recovers() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    faultpoint::reset();
    let (addr, handle) = spawn_synthetic_server(SpawnOpts::tiny(103)).unwrap();
    let mut c = connect(addr);

    faultpoint::arm("admit.slow-tenant", 1);
    let (status, body) = roundtrip(&mut c, &post_infer(SST2));
    assert_eq!(status, 429, "{body}");
    assert!(body.contains("\"error\":\"tenant-throttled\""), "{body}");
    assert!(body.contains("\"retry_after_ms\":"), "{body}");

    let (status, body) = roundtrip(&mut c, &post_infer(SST2));
    assert_eq!(status, 200, "{body}");

    let (status, _) = roundtrip(&mut c, SHUTDOWN);
    assert_eq!(status, 200);
    let stats = handle.join().unwrap().unwrap();
    assert_eq!(stats.rejects_throttle, 1);
    assert_eq!(stats.replies, 1);
}

#[test]
fn torn_reply_drops_the_connection_but_the_server_keeps_serving() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    faultpoint::reset();
    let (addr, handle) = spawn_synthetic_server(SpawnOpts::tiny(107)).unwrap();

    faultpoint::arm("wire.torn-reply", 1);
    let mut torn = connect(addr);
    torn.write_all(&post_infer(SST2)).unwrap();
    let mut raw = Vec::new();
    torn.read_to_end(&mut raw).unwrap();
    assert!(!raw.is_empty(), "half the reply must make it out before the tear");
    // the frame is provably incomplete: either the head never finished,
    // or the body is short of its declared Content-Length
    let complete = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .map(|i| {
            let head = String::from_utf8_lossy(&raw[..i + 4]).to_string();
            let cl: usize = head
                .lines()
                .find(|l| l.to_ascii_lowercase().starts_with("content-length:"))
                .map(|l| l.split(':').nth(1).unwrap().trim().parse().unwrap())
                .unwrap_or(0);
            raw.len() >= i + 4 + cl
        })
        .unwrap_or(false);
    assert!(!complete, "the reply must be torn, got {:?}", String::from_utf8_lossy(&raw));

    // a fresh connection serves bitwise-normally
    let mut c = connect(addr);
    let (status, body) = roundtrip(&mut c, &post_infer(SST2));
    assert_eq!(status, 200, "{body}");
    let (status, _) = roundtrip(&mut c, SHUTDOWN);
    assert_eq!(status, 200);
    let stats = handle.join().unwrap().unwrap();
    assert_eq!(stats.connections, 2);
}

#[test]
fn mid_wave_panic_degrades_to_typed_500_and_the_thread_survives() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    faultpoint::reset();
    let (addr, handle) = spawn_synthetic_server(SpawnOpts::tiny(109)).unwrap();

    faultpoint::arm("serve.mid-wave-panic", 1);
    let mut c = connect(addr);
    let (status, body) = roundtrip(&mut c, &post_infer(SST2));
    assert_eq!(status, 500, "{body}");
    assert!(body.contains("\"error\":\"internal\""), "{body}");
    // a lost wave is fatal for the connection (the client must not see
    // a silently re-run request)…
    let mut rest = Vec::new();
    assert_eq!(c.read_to_end(&mut rest).unwrap(), 0, "{rest:?}");

    // …but never for the server: the panic was caught, the queue
    // aborted, and the next connection serves
    let mut c = connect(addr);
    let (status, body) = roundtrip(&mut c, &post_infer(SST2));
    assert_eq!(status, 200, "{body}");
    let (status, _) = roundtrip(&mut c, SHUTDOWN);
    assert_eq!(status, 200);
    let stats = handle.join().unwrap().unwrap();
    assert_eq!(stats.connections, 2);
    assert_eq!(stats.replies, 1);
}
