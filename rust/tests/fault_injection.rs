//! Fault-injection drills: arm each named fault point on the serve path
//! and prove the failure degrades to a **typed** outcome with the server
//! still serving afterwards — the serve faults the robustness
//! contract names (forced queue-full, forced slow tenant, a torn reply
//! write, a panic mid-wave, a forced accept-shed, a crawling reader
//! against the per-connection progress deadline) plus the four bank
//! storage faults
//! (`bank.short-write`, `bank.fsync-fail`, `bank.rename-fail`,
//! `bank.compact-crash`), each of which must leave the previous
//! on-disk generation loadable and whoever held the bank still serving.
//!
//! This suite lives in its own test binary on purpose: the armed-point
//! table is process-global, so arming in a shared binary could perturb
//! unrelated parallel tests. Within this binary, tests serialize on a
//! mutex and each leaves every point disarmed.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Mutex;

use hadapt::model::ParamStore;
use hadapt::runtime::{
    faultpoint, spawn_synthetic_server, synthetic_adapters, synthetic_tenant, BankBuilder,
    BankGeometry, BankReader, Engine, SpawnOpts, TaskAdapter,
};

static SERIAL: Mutex<()> = Mutex::new(());

fn post_infer(body: &str) -> Vec<u8> {
    format!("POST /infer HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}", body.len()).into_bytes()
}

const SST2: &str = r#"{"task":"sst2","text_a":[5,6,7]}"#;
const SHUTDOWN: &[u8] = b"POST /shutdown HTTP/1.1\r\nContent-Length: 0\r\n\r\n";

/// Send one request and read one full response frame.
fn roundtrip(stream: &mut TcpStream, req: &[u8]) -> (u16, String) {
    stream.write_all(req).unwrap();
    let mut buf = Vec::new();
    let mut chunk = [0u8; 4096];
    let head_end = loop {
        if let Some(i) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break i + 4;
        }
        let n = stream.read(&mut chunk).unwrap();
        assert!(n > 0, "eof mid-head: {:?}", String::from_utf8_lossy(&buf));
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8(buf[..head_end].to_vec()).unwrap();
    let status: u16 = head.split_whitespace().nth(1).unwrap().parse().unwrap();
    let cl: usize = head
        .lines()
        .find(|l| l.to_ascii_lowercase().starts_with("content-length:"))
        .map(|l| l.split(':').nth(1).unwrap().trim().parse().unwrap())
        .unwrap_or(0);
    while buf.len() < head_end + cl {
        let n = stream.read(&mut chunk).unwrap();
        assert!(n > 0, "eof mid-body");
        buf.extend_from_slice(&chunk[..n]);
    }
    (status, String::from_utf8_lossy(&buf[head_end..head_end + cl]).to_string())
}

fn connect(addr: SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).unwrap();
    s.set_nodelay(true).unwrap();
    s
}

#[test]
fn forced_queue_full_sheds_typed_503_then_recovers() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    faultpoint::reset();
    let (addr, handle) = spawn_synthetic_server(SpawnOpts::tiny(101)).unwrap();
    let mut c = connect(addr);

    faultpoint::arm("serve.queue-full", 1);
    let (status, body) = roundtrip(&mut c, &post_infer(SST2));
    assert_eq!(status, 503, "{body}");
    assert!(body.contains("\"error\":\"queue-full\""), "{body}");

    // the injected rejection consumed the armed hit: same connection,
    // next request serves
    let (status, body) = roundtrip(&mut c, &post_infer(SST2));
    assert_eq!(status, 200, "{body}");

    let (status, _) = roundtrip(&mut c, SHUTDOWN);
    assert_eq!(status, 200);
    let stats = handle.join().unwrap().unwrap();
    assert_eq!(stats.rejects_shed, 1);
    assert_eq!(stats.replies, 1);
}

#[test]
fn forced_slow_tenant_throttles_typed_429_then_recovers() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    faultpoint::reset();
    let (addr, handle) = spawn_synthetic_server(SpawnOpts::tiny(103)).unwrap();
    let mut c = connect(addr);

    faultpoint::arm("admit.slow-tenant", 1);
    let (status, body) = roundtrip(&mut c, &post_infer(SST2));
    assert_eq!(status, 429, "{body}");
    assert!(body.contains("\"error\":\"tenant-throttled\""), "{body}");
    assert!(body.contains("\"retry_after_ms\":"), "{body}");

    let (status, body) = roundtrip(&mut c, &post_infer(SST2));
    assert_eq!(status, 200, "{body}");

    let (status, _) = roundtrip(&mut c, SHUTDOWN);
    assert_eq!(status, 200);
    let stats = handle.join().unwrap().unwrap();
    assert_eq!(stats.rejects_throttle, 1);
    assert_eq!(stats.replies, 1);
}

#[test]
fn torn_reply_drops_the_connection_but_the_server_keeps_serving() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    faultpoint::reset();
    let (addr, handle) = spawn_synthetic_server(SpawnOpts::tiny(107)).unwrap();

    faultpoint::arm("wire.torn-reply", 1);
    let mut torn = connect(addr);
    torn.write_all(&post_infer(SST2)).unwrap();
    let mut raw = Vec::new();
    torn.read_to_end(&mut raw).unwrap();
    assert!(!raw.is_empty(), "half the reply must make it out before the tear");
    // the frame is provably incomplete: either the head never finished,
    // or the body is short of its declared Content-Length
    let complete = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .map(|i| {
            let head = String::from_utf8_lossy(&raw[..i + 4]).to_string();
            let cl: usize = head
                .lines()
                .find(|l| l.to_ascii_lowercase().starts_with("content-length:"))
                .map(|l| l.split(':').nth(1).unwrap().trim().parse().unwrap())
                .unwrap_or(0);
            raw.len() >= i + 4 + cl
        })
        .unwrap_or(false);
    assert!(!complete, "the reply must be torn, got {:?}", String::from_utf8_lossy(&raw));

    // a fresh connection serves bitwise-normally
    let mut c = connect(addr);
    let (status, body) = roundtrip(&mut c, &post_infer(SST2));
    assert_eq!(status, 200, "{body}");
    let (status, _) = roundtrip(&mut c, SHUTDOWN);
    assert_eq!(status, 200);
    let stats = handle.join().unwrap().unwrap();
    assert_eq!(stats.connections, 2);
}

#[test]
fn mid_wave_panic_degrades_to_typed_500_and_the_thread_survives() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    faultpoint::reset();
    let (addr, handle) = spawn_synthetic_server(SpawnOpts::tiny(109)).unwrap();

    faultpoint::arm("serve.mid-wave-panic", 1);
    let mut c = connect(addr);
    let (status, body) = roundtrip(&mut c, &post_infer(SST2));
    assert_eq!(status, 500, "{body}");
    assert!(body.contains("\"error\":\"internal\""), "{body}");
    // a lost wave is fatal for the connection (the client must not see
    // a silently re-run request)…
    let mut rest = Vec::new();
    assert_eq!(c.read_to_end(&mut rest).unwrap(), 0, "{rest:?}");

    // …but never for the server: the panic was caught, the queue
    // aborted, and the next connection serves
    let mut c = connect(addr);
    let (status, body) = roundtrip(&mut c, &post_infer(SST2));
    assert_eq!(status, 200, "{body}");
    let (status, _) = roundtrip(&mut c, SHUTDOWN);
    assert_eq!(status, 200);
    let stats = handle.join().unwrap().unwrap();
    assert_eq!(stats.connections, 2);
    assert_eq!(stats.replies, 1);
}

#[test]
fn forced_accept_failure_sheds_typed_503_and_the_next_connection_serves() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    faultpoint::reset();
    let (addr, handle) = spawn_synthetic_server(SpawnOpts::tiny(127)).unwrap();

    // the armed accept fault sheds this connection exactly as a full
    // slot table would: typed too-many-connections 503, then EOF
    faultpoint::arm("wire.accept-fail", 1);
    let mut shed = connect(addr);
    let (status, body) = {
        let mut buf = Vec::new();
        shed.read_to_end(&mut buf).unwrap();
        let raw = String::from_utf8_lossy(&buf).to_string();
        let head_end = raw.find("\r\n\r\n").expect("full reject frame") + 4;
        let status: u16 =
            raw.split_whitespace().nth(1).unwrap().parse().unwrap();
        (status, raw[head_end..].to_string())
    };
    assert_eq!(status, 503, "{body}");
    assert!(body.contains("\"error\":\"too-many-connections\""), "{body}");

    // the shed consumed the armed hit: the very next connection occupies
    // a slot and serves, and the ledger shows exactly one accept reject
    let mut c = connect(addr);
    let (status, body) = roundtrip(&mut c, &post_infer(SST2));
    assert_eq!(status, 200, "{body}");
    let (status, body) = roundtrip(&mut c, b"GET /stats HTTP/1.1\r\n\r\n");
    assert_eq!(status, 200);
    assert!(body.contains("\"conns_rejected\":1"), "{body}");
    assert!(body.contains("\"conns_open\":1"), "{body}");

    let (status, _) = roundtrip(&mut c, SHUTDOWN);
    assert_eq!(status, 200);
    let stats = handle.join().unwrap().unwrap();
    assert_eq!(stats.conns_rejected, 1);
    assert_eq!(stats.connections, 1, "a shed connection never occupies a slot");
    assert_eq!(stats.rejects_shed, 1);
    assert_eq!(stats.replies, 1);
}

#[test]
fn injected_slow_reader_hits_the_progress_deadline_while_others_serve() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    faultpoint::reset();
    let mut opts = SpawnOpts::tiny(131);
    opts.limits.progress_timeout_ms = 50;
    let (addr, handle) = spawn_synthetic_server(opts).unwrap();

    // the first accepted connection is the crawler: the server consumes
    // its bytes at one per millisecond, so this ~300-byte frame cannot
    // complete inside the 50ms progress deadline even though the client
    // sent it whole
    faultpoint::arm("conn.slow-reader", 1);
    let mut slow = connect(addr);
    let big: Vec<String> = (0..60).map(|i| (3 + i % 200).to_string()).collect();
    let slow_req = post_infer(&format!("{{\"task\":\"sst2\",\"text_a\":[{}]}}", big.join(",")));
    assert!(slow_req.len() > 200, "the crawling frame must outlast the deadline");
    slow.write_all(&slow_req).unwrap();

    // while the crawler trickles, a healthy connection round-trips
    // normally — one stalled peer does not wedge the table
    let mut c = connect(addr);
    for _ in 0..3 {
        let (status, body) = roundtrip(&mut c, &post_infer(SST2));
        assert_eq!(status, 200, "{body}");
    }

    // the crawler gets the typed mid-frame deadline and a close
    let (status, body) = roundtrip(&mut slow, &[]);
    assert_eq!(status, 408, "{body}");
    assert!(body.contains("\"error\":\"progress-timeout\""), "{body}");
    let mut rest = Vec::new();
    assert_eq!(slow.read_to_end(&mut rest).unwrap(), 0, "{rest:?}");

    let (status, _) = roundtrip(&mut c, SHUTDOWN);
    assert_eq!(status, 200);
    let stats = handle.join().unwrap().unwrap();
    assert_eq!(stats.connections, 2);
    assert_eq!(stats.rejects_http, 1, "the deadline lands in the http bucket");
    assert_eq!(stats.replies, 3);
}

// ---------------------------------------------------------------------------
// Bank storage faults
// ---------------------------------------------------------------------------

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("hadapt_faultb_{}_{tag}.bank", std::process::id()))
}

/// A small hand-geometry bank on disk: `base` centroid plus `names`,
/// each tenant filled with a distinct constant.
fn mini_bank(path: &PathBuf, names: &[&str]) -> BankGeometry {
    let g = BankGeometry { layers: 1, hidden: 3, classes: 2 };
    let mut b = BankBuilder::new(g, vec![mini(&g, "base", 1.0)], 0.0).unwrap();
    for (i, n) in names.iter().enumerate() {
        b.add_tenant(&mini(&g, n, 2.0 + i as f32)).unwrap();
    }
    b.write(path).unwrap();
    g
}

fn mini(g: &BankGeometry, name: &str, fill: f32) -> TaskAdapter {
    TaskAdapter {
        task: name.to_string(),
        classes: g.classes,
        had_w: vec![vec![fill; g.hidden]; g.layers],
        had_b: vec![vec![fill * 0.5; g.hidden]; g.layers],
        norm_w: vec![vec![1.0; g.hidden]; g.layers],
        norm_b: vec![vec![0.0; g.hidden]; g.layers],
        pooler_w: vec![fill; g.hidden * g.hidden],
        pooler_b: vec![0.0; g.hidden],
        cls_w: vec![fill; g.hidden * g.classes],
        cls_b: vec![0.0; g.classes],
    }
}

#[test]
fn short_write_fails_the_upsert_typed_and_the_committed_state_survives() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    faultpoint::reset();
    let path = tmp("short_write");
    let g = mini_bank(&path, &["aa", "bb"]);

    let mut r = BankReader::open(&path).unwrap();
    faultpoint::arm("bank.short-write", 1);
    let err = r.upsert(&mini(&g, "cc", 9.0)).unwrap_err();
    assert!(err.to_string().contains("short write"), "{err}");
    assert!(!r.contains("cc"), "a failed append must not be indexed");

    // the half-written bytes are a torn tail: a reopen salvages straight
    // back to the committed state
    let mut r2 = BankReader::open(&path).unwrap();
    assert_eq!(r2.len(), 2);
    assert!(r2.contains("aa") && r2.contains("bb") && !r2.contains("cc"));
    assert_eq!(r2.quarantined(), 0);

    // same reader, disarmed: the retry truncates the garbage and lands
    faultpoint::reset();
    r.upsert(&mini(&g, "cc", 9.0)).unwrap();
    assert!(r.contains("cc"));
    let mut r3 = BankReader::open(&path).unwrap();
    assert_eq!(r3.len(), 3);
    assert!(r3.damage().is_empty(), "the retry leaves no damage behind");
    let mut got = r3.blank_adapter();
    r3.read_into("cc", &mut got).unwrap();
    assert_eq!(got.had_w[0][0], 9.0);
    std::fs::remove_file(&path).ok();
}

#[test]
fn fsync_failure_fails_the_rewrite_before_the_commit_point() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    faultpoint::reset();
    let path = tmp("fsync");
    let g = mini_bank(&path, &["aa", "bb"]);
    let committed = std::fs::read(&path).unwrap();

    // a full rewrite of the same path dies at fsync — before the rename,
    // so the committed image is untouched byte for byte
    let mut b = BankBuilder::new(g, vec![mini(&g, "base", 1.0)], 0.0).unwrap();
    b.add_tenant(&mini(&g, "zz", 7.0)).unwrap();
    faultpoint::arm("bank.fsync-fail", 1);
    let err = b.write(&path).unwrap_err();
    assert!(err.to_string().contains("fsync failed"), "{err}");
    assert_eq!(std::fs::read(&path).unwrap(), committed, "commit point never reached");
    let r = BankReader::open(&path).unwrap();
    assert!(r.contains("aa") && r.contains("bb") && !r.contains("zz"));

    faultpoint::reset();
    b.write(&path).unwrap();
    assert!(BankReader::open(&path).unwrap().contains("zz"));
    std::fs::remove_file(&path).ok();
    let mut tmp_os = path.clone().into_os_string();
    tmp_os.push(".tmp");
    std::fs::remove_file(PathBuf::from(tmp_os)).ok();
}

#[test]
fn rename_failure_fails_the_compact_and_the_old_generation_keeps_serving() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    faultpoint::reset();
    let path = tmp("rename");
    let g = mini_bank(&path, &["aa", "bb", "cc"]);

    let mut r = BankReader::open(&path).unwrap();
    let mut aa = mini(&g, "aa", 2.0);
    aa.had_b[0][0] = 6.0;
    r.upsert(&aa).unwrap();
    assert!(r.live_fraction() < 1.0);

    faultpoint::arm("bank.rename-fail", 1);
    let err = r.compact().unwrap_err();
    assert!(err.to_string().contains("rename"), "{err}");

    // the reader that failed to compact still serves the old generation…
    assert_eq!(r.generation(), 0);
    let mut got = r.blank_adapter();
    r.read_into("aa", &mut got).unwrap();
    assert_eq!(got.had_b[0][0], 6.0, "the shadowing upsert is still the live row");
    r.read_into("cc", &mut got).unwrap();
    assert_eq!(got.had_w[0][0], 4.0);
    // …and so does a fresh open of the path
    assert_eq!(BankReader::open(&path).unwrap().generation(), 0);

    faultpoint::reset();
    let s = r.compact().unwrap();
    assert_eq!((s.generation, s.tenants, s.dropped_shadowed), (1, 3, 1));
    assert_eq!(BankReader::open(&path).unwrap().generation(), 1);
    std::fs::remove_file(&path).ok();
}

#[test]
fn compact_crash_leaves_a_partial_tmp_and_an_intact_previous_generation() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    faultpoint::reset();
    let path = tmp("crash");
    let g = mini_bank(&path, &["aa", "bb"]);
    let committed = std::fs::read(&path).unwrap();

    let mut r = BankReader::open(&path).unwrap();
    r.upsert(&mini(&g, "dd", 8.0)).unwrap();
    let churned = std::fs::read(&path).unwrap();

    faultpoint::arm("bank.compact-crash", 1);
    let err = r.compact().unwrap_err();
    assert!(err.to_string().contains("crash mid-rewrite"), "{err}");
    let mut tmp_os = path.clone().into_os_string();
    tmp_os.push(".tmp");
    let tmp_path = PathBuf::from(tmp_os);
    assert!(tmp_path.exists(), "the crash leaves a partial sibling behind");
    assert_eq!(std::fs::read(&path).unwrap(), churned, "the served file is untouched");
    assert_ne!(committed, churned);
    assert_eq!(BankReader::open(&path).unwrap().generation(), 0);

    // recovery is just running compact again: the retry truncates the
    // partial sibling and commits generation 1
    faultpoint::reset();
    let s = r.compact().unwrap();
    assert_eq!(s.generation, 1);
    assert!(!tmp_path.exists(), "the commit consumed the sibling");
    let mut r2 = BankReader::open(&path).unwrap();
    assert_eq!(r2.generation(), 1);
    assert_eq!(r2.len(), 3);
    let mut got = r2.blank_adapter();
    r2.read_into("dd", &mut got).unwrap();
    assert_eq!(got.had_w[0][0], 8.0);
    std::fs::remove_file(&path).ok();
}

/// The server-level drill: a `--compact-at` server whose self-compaction
/// hits an injected rename failure counts the failure, keeps serving the
/// old generation, and compacts successfully once the fault clears —
/// all observed over the wire via `/stats`.
#[test]
fn server_survives_a_failed_self_compaction_and_retries_into_generation_one() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    faultpoint::reset();

    // a pre-churned tiny-geometry bank: enough shadowed bytes to cross
    // any reasonable --compact-at threshold
    let path = tmp("server_compact");
    let engine = Engine::new_with_threads("/definitely/not/a/dir", 2).unwrap();
    let info = engine.manifest().model("tiny").unwrap().clone();
    let store = ParamStore::init(&info, 113);
    let bases =
        synthetic_adapters(&info, &store, &["sst2".to_string(), "rte".to_string()], 113).unwrap();
    let classes = info.params[info.param_index("classifier.bias").unwrap()].shape[0];
    let geom = BankGeometry { layers: info.layers, hidden: info.hidden, classes };
    let mut b = BankBuilder::new(geom, bases.clone(), 0.0).unwrap();
    for i in 0..4 {
        b.add_tenant(&synthetic_tenant(&bases, i, 113)).unwrap();
    }
    b.write(&path).unwrap();
    {
        let mut r = BankReader::open(&path).unwrap();
        for i in 0..4 {
            let mut t = synthetic_tenant(&bases, i, 113);
            t.had_b[0][0] += 0.25;
            r.upsert(&t).unwrap();
        }
        assert!(1.0 - r.live_fraction() > 0.2);
    }

    let mut opts = SpawnOpts::tiny(113);
    opts.bank_path = Some(path.to_string_lossy().into_owned());
    opts.compact_at = Some(0.1);
    let (addr, handle) = spawn_synthetic_server(opts).unwrap();
    let mut c = connect(addr);

    // first reply's wave boundary triggers self-compaction into the
    // armed rename failure: counted, generation unchanged, still serving
    faultpoint::arm("bank.rename-fail", 1);
    let (status, body) = roundtrip(&mut c, &post_infer(SST2));
    assert_eq!(status, 200, "{body}");
    let (status, body) = roundtrip(&mut c, b"GET /stats HTTP/1.1\r\n\r\n");
    assert_eq!(status, 200);
    assert!(body.contains("\"compact_failures\":1"), "{body}");
    assert!(body.contains("\"bank_generation\":0"), "{body}");

    // the /stats wave boundary retried with the fault cleared: the next
    // snapshot shows the committed generation and a fully-live log
    let (status, body) = roundtrip(&mut c, b"GET /stats HTTP/1.1\r\n\r\n");
    assert_eq!(status, 200);
    assert!(body.contains("\"compactions\":1"), "{body}");
    assert!(body.contains("\"compact_failures\":1"), "{body}");
    assert!(body.contains("\"bank_generation\":1"), "{body}");
    assert!(body.contains("\"bank_log_live_frac\":1.0000"), "{body}");

    let (status, body) = roundtrip(&mut c, &post_infer(SST2));
    assert_eq!(status, 200, "{body}");
    let (status, _) = roundtrip(&mut c, SHUTDOWN);
    assert_eq!(status, 200);
    let stats = handle.join().unwrap().unwrap();
    assert_eq!(stats.compactions, 1);
    assert_eq!(stats.compact_failures, 1);
    assert_eq!(stats.replies, 2);
    assert_eq!(BankReader::open(&path).unwrap().generation(), 1);
    std::fs::remove_file(&path).ok();
}
