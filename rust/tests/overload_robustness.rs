//! Socket-level overload robustness, end to end:
//!
//! 1. **Deadline batching**: a short wave flushes once its oldest row
//!    has waited the policy window — a lone request is never stranded
//!    waiting for a batch that will not fill.
//! 2. **Bounded queue**: submits past `queue_cap` shed as typed
//!    `queue-full` 503s in arrival order; the connection (and the
//!    server) keeps serving afterwards.
//! 3. **Per-tenant throttling**: a tenant over its token bucket gets a
//!    429 with `Retry-After`, while other tenants on the same
//!    connection keep being admitted.
//! 4. **Graceful drain**: requests pipelined behind `POST /shutdown`
//!    get typed `shutting-down` 503s, never a reset, and the server
//!    thread joins cleanly.
//! 5. **Slowloris guard**: a client trickling bytes resets the idle
//!    clock forever but still hits the per-frame progress deadline and
//!    gets a typed `progress-timeout` 408.
//! 6. **Cross-connection drain**: `POST /shutdown` arriving on one
//!    connection serves every other connection's already-queued rows as
//!    200s, answers every connection's pipelined tail with typed
//!    `shutting-down` 503s, and slams nobody.
//!
//! Every test ends with the server provably still serving (or cleanly
//! down), because "degrades, never falls over" is the contract.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use hadapt::runtime::{spawn_synthetic_server, ServePolicy, SpawnOpts};

/// A pipelining-aware test client: one persistent read buffer, so
/// responses are consumed frame by frame no matter how the kernel
/// chunks them.
struct Client {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        Client { stream, buf: Vec::new() }
    }

    fn send(&mut self, bytes: &[u8]) {
        self.stream.write_all(bytes).unwrap();
    }

    /// Read one response frame: `(status, head, body)`.
    fn response(&mut self) -> (u16, String, String) {
        let mut chunk = [0u8; 4096];
        let head_end = loop {
            if let Some(i) = self.buf.windows(4).position(|w| w == b"\r\n\r\n") {
                break i + 4;
            }
            let n = self.stream.read(&mut chunk).unwrap();
            assert!(n > 0, "eof mid-head: {:?}", String::from_utf8_lossy(&self.buf));
            self.buf.extend_from_slice(&chunk[..n]);
        };
        let head = String::from_utf8(self.buf[..head_end].to_vec()).unwrap();
        let status: u16 = head.split_whitespace().nth(1).unwrap().parse().unwrap();
        let cl: usize = head
            .lines()
            .find(|l| l.to_ascii_lowercase().starts_with("content-length:"))
            .map(|l| l.split(':').nth(1).unwrap().trim().parse().unwrap())
            .unwrap_or(0);
        while self.buf.len() < head_end + cl {
            let n = self.stream.read(&mut chunk).unwrap();
            assert!(n > 0, "eof mid-body");
            self.buf.extend_from_slice(&chunk[..n]);
        }
        let body = String::from_utf8_lossy(&self.buf[head_end..head_end + cl]).to_string();
        self.buf.drain(..head_end + cl);
        (status, head, body)
    }
}

fn post_infer(body: &str) -> Vec<u8> {
    format!("POST /infer HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}", body.len()).into_bytes()
}

const SST2: &str = r#"{"task":"sst2","text_a":[5,6,7]}"#;
const RTE: &str = r#"{"task":"rte","text_a":[4,5],"text_b":[6,7]}"#;
const STATS: &[u8] = b"GET /stats HTTP/1.1\r\n\r\n";
const SHUTDOWN: &[u8] = b"POST /shutdown HTTP/1.1\r\nContent-Length: 0\r\n\r\n";

/// Pull an integer counter out of a `/stats` body.
fn stat(body: &str, key: &str) -> u64 {
    let tag = format!("\"{key}\":");
    let at = body.find(&tag).unwrap_or_else(|| panic!("no {key} in {body}")) + tag.len();
    body[at..]
        .chars()
        .take_while(|c| c.is_ascii_digit())
        .collect::<String>()
        .parse()
        .unwrap()
}

#[test]
fn deadline_window_flushes_short_waves() {
    let mut opts = SpawnOpts::tiny(23);
    opts.policy = ServePolicy { queue_cap: 8, window_us: 20_000, ..ServePolicy::default() };
    let (addr, handle) = spawn_synthetic_server(opts).unwrap();
    let mut c = Client::connect(addr);

    // a lone request rides the window deadline out, then serves — it is
    // not stranded waiting for a wave that never fills
    let t0 = Instant::now();
    c.send(&post_infer(SST2));
    let (status, _, body) = c.response();
    assert_eq!(status, 200, "{body}");
    assert!(
        t0.elapsed() >= Duration::from_micros(20_000),
        "the short wave must wait out the batching window, got {:?}",
        t0.elapsed()
    );

    // a second short wave flushes by deadline too, and the counter says
    // the window (not pipe-drain) triggered both flushes
    c.send(&post_infer(SST2));
    c.send(&post_infer(RTE));
    let (status, _, _) = c.response();
    assert_eq!(status, 200);
    let (status, _, _) = c.response();
    assert_eq!(status, 200);
    c.send(STATS);
    let (status, _, body) = c.response();
    assert_eq!(status, 200, "{body}");
    assert!(stat(&body, "window_flushes") >= 2, "{body}");
    assert_eq!(stat(&body, "window_us"), 20_000, "{body}");
    assert_eq!(stat(&body, "serve_admitted"), 3, "{body}");

    c.send(SHUTDOWN);
    let (status, _, _) = c.response();
    assert_eq!(status, 200);
    let stats = handle.join().unwrap().unwrap();
    assert_eq!(stats.replies, 3);
    assert!(stats.window_flushes >= 2);
}

#[test]
fn bounded_queue_sheds_typed_503s_in_arrival_order() {
    let mut opts = SpawnOpts::tiny(29);
    // a long window keeps the server gathering while the burst lands,
    // so the shed pattern is deterministic even if reads fragment; the
    // full queue itself forces the flush long before the window
    opts.policy = ServePolicy { queue_cap: 2, window_us: 500_000, ..ServePolicy::default() };
    let (addr, handle) = spawn_synthetic_server(opts).unwrap();
    let mut c = Client::connect(addr);

    let burst: Vec<u8> = (0..5).flat_map(|_| post_infer(SST2)).collect();
    c.send(&burst);
    let mut outcomes = Vec::new();
    for _ in 0..5 {
        let (status, _, body) = c.response();
        outcomes.push((status, body));
    }
    let statuses: Vec<u16> = outcomes.iter().map(|o| o.0).collect();
    assert_eq!(statuses, [200, 200, 503, 503, 503], "first two admit, the rest shed");
    for (_, body) in &outcomes[2..] {
        assert!(body.contains("\"error\":\"queue-full\""), "{body}");
    }

    // queue-full is not fatal: the same connection serves the next wave
    // (the control frame flushes it, so no window wait)
    c.send(&post_infer(SST2));
    c.send(STATS);
    let (status, _, body) = c.response();
    assert_eq!(status, 200, "{body}");
    let (status, _, body) = c.response();
    assert_eq!(status, 200, "{body}");
    assert_eq!(stat(&body, "rejects_shed"), 3, "{body}");
    assert_eq!(stat(&body, "queue_cap"), 2, "{body}");
    assert_eq!(stat(&body, "serve_admitted"), 3, "{body}");

    c.send(SHUTDOWN);
    let (status, _, _) = c.response();
    assert_eq!(status, 200);
    let stats = handle.join().unwrap().unwrap();
    assert_eq!(stats.replies, 3);
    assert_eq!(stats.rejects_shed, 3);
}

#[test]
fn tenant_over_rate_gets_429_with_retry_after_while_others_admit() {
    let mut opts = SpawnOpts::tiny(31);
    opts.policy = ServePolicy { tenant_rps: 1, tenant_burst: 1, ..ServePolicy::default() };
    let (addr, handle) = spawn_synthetic_server(opts).unwrap();
    let mut c = Client::connect(addr);

    // sst2 drains its one-token bucket, then throttles; rte's bucket is
    // untouched, so fairness holds on the very same connection
    c.send(&post_infer(SST2));
    c.send(&post_infer(SST2));
    c.send(&post_infer(RTE));
    c.send(STATS);
    let (status, _, body) = c.response();
    assert_eq!(status, 200, "{body}");
    let (status, head, body) = c.response();
    assert_eq!(status, 429, "{body}");
    assert!(body.contains("\"error\":\"tenant-throttled\""), "{body}");
    assert!(body.contains("\"retry_after_ms\":"), "{body}");
    assert!(head.contains("Retry-After: "), "{head}");
    let (status, _, body) = c.response();
    assert_eq!(status, 200, "a throttled neighbor must not starve rte: {body}");
    let (status, _, body) = c.response();
    assert_eq!(status, 200, "{body}");
    assert_eq!(stat(&body, "rejects_throttle"), 1, "{body}");
    assert_eq!(stat(&body, "tenant_rps"), 1, "{body}");
    assert_eq!(stat(&body, "serve_admitted"), 2, "{body}");

    c.send(SHUTDOWN);
    let (status, _, _) = c.response();
    assert_eq!(status, 200);
    let stats = handle.join().unwrap().unwrap();
    assert_eq!(stats.replies, 2);
    assert_eq!(stats.rejects_throttle, 1);
}

#[test]
fn graceful_drain_answers_pipelined_tail_with_typed_503s() {
    let (addr, handle) = spawn_synthetic_server(SpawnOpts::tiny(37)).unwrap();
    let mut c = Client::connect(addr);

    // two requests, shutdown, two more — all on the wire at once
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&post_infer(SST2));
    bytes.extend_from_slice(&post_infer(RTE));
    bytes.extend_from_slice(SHUTDOWN);
    bytes.extend_from_slice(&post_infer(SST2));
    bytes.extend_from_slice(&post_infer(RTE));
    c.send(&bytes);

    // in-flight work completes, the ack lands, the tail degrades typed
    let (status, _, body) = c.response();
    assert_eq!(status, 200, "{body}");
    let (status, _, body) = c.response();
    assert_eq!(status, 200, "{body}");
    let (status, _, body) = c.response();
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"shutting_down\":true"), "{body}");
    for _ in 0..2 {
        let (status, _, body) = c.response();
        assert_eq!(status, 503, "{body}");
        assert!(body.contains("\"error\":\"shutting-down\""), "{body}");
    }
    drop(c);

    let stats = handle.join().unwrap().unwrap();
    assert_eq!(stats.connections, 1);
    assert_eq!(stats.requests, 5);
    assert_eq!(stats.replies, 2);
    assert_eq!(stats.rejects_shed, 2, "the drained tail is typed, not dropped");
}

#[test]
fn slowloris_trickle_hits_progress_deadline_not_idle() {
    let mut opts = SpawnOpts::tiny(43);
    opts.limits.idle_timeout_ms = 150;
    opts.limits.progress_timeout_ms = 450;
    let (addr, handle) = spawn_synthetic_server(opts).unwrap();

    // trickle one header byte every 60ms: each byte resets the idle
    // clock (150ms), so only the per-frame progress deadline (450ms,
    // anchored at the first byte) can fire
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let t0 = Instant::now();
    stream.write_all(b"POST /infer HTTP/1.1\r\n").unwrap();
    for _ in 0..6 {
        std::thread::sleep(Duration::from_millis(60));
        let _ = stream.write_all(b"X");
    }
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).unwrap();
    let text = String::from_utf8_lossy(&raw);
    assert!(text.starts_with("HTTP/1.1 408 "), "{text}");
    assert!(text.contains("\"error\":\"progress-timeout\""), "{text}");
    assert!(
        t0.elapsed() >= Duration::from_millis(400),
        "the trickle must outlive the idle deadline and die on progress, got {:?}",
        t0.elapsed()
    );

    // the single serve thread is free again
    let mut c = Client::connect(addr);
    c.send(&post_infer(SST2));
    let (status, _, body) = c.response();
    assert_eq!(status, 200, "{body}");
    c.send(SHUTDOWN);
    let (status, _, _) = c.response();
    assert_eq!(status, 200);
    let stats = handle.join().unwrap().unwrap();
    assert_eq!(stats.connections, 2);
    assert_eq!(stats.rejects_http, 1, "the progress timeout lands in the http bucket");
    assert_eq!(stats.replies, 1);
}

#[test]
fn shutdown_from_one_connection_drains_the_others_without_slamming_them() {
    // a long flush window so the bystander's rows are still queued when
    // the other connection's shutdown is processed: the drain must serve
    // them as 200s first, not shed them
    let mut opts = SpawnOpts::tiny(47);
    opts.policy = ServePolicy { queue_cap: 16, window_us: 50_000, ..ServePolicy::default() };
    let (addr, handle) = spawn_synthetic_server(opts).unwrap();

    // connection B queues two rows into the open window…
    let mut b = Client::connect(addr);
    let mut b_bytes = Vec::new();
    b_bytes.extend_from_slice(&post_infer(RTE));
    b_bytes.extend_from_slice(&post_infer(RTE));
    b.send(&b_bytes);

    // …then connection A pipelines one row plus the shutdown; the
    // control frame forces the flush, so the wave mixes A's and B's rows
    let mut a = Client::connect(addr);
    let mut a_bytes = Vec::new();
    a_bytes.extend_from_slice(&post_infer(SST2));
    a_bytes.extend_from_slice(SHUTDOWN);
    a.send(&a_bytes);

    // A: its row, then the ack
    let (status, _, body) = a.response();
    assert_eq!(status, 200, "{body}");
    let (status, _, body) = a.response();
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"shutting_down\":true"), "{body}");

    // B was not slammed: its queued rows come back as 200s on B
    for i in 0..2 {
        let (status, _, body) = b.response();
        assert_eq!(status, 200, "bystander reply {i}: {body}");
        assert!(body.contains("\"task\":\"rte\""), "bystander reply {i}: {body}");
    }

    // B's post-shutdown tail degrades typed on B's own connection…
    let mut tail = Vec::new();
    tail.extend_from_slice(&post_infer(RTE));
    tail.extend_from_slice(&post_infer(RTE));
    b.send(&tail);
    for i in 0..2 {
        let (status, _, body) = b.response();
        assert_eq!(status, 503, "bystander tail {i}: {body}");
        assert!(body.contains("\"error\":\"shutting-down\""), "bystander tail {i}: {body}");
    }
    drop(b);
    drop(a);

    let stats = handle.join().unwrap().unwrap();
    assert_eq!(stats.connections, 2);
    assert_eq!(stats.replies, 3, "A's row plus B's two queued rows all served");
    assert_eq!(stats.rejects_shed, 2, "B's tail is typed, not dropped");
    assert_eq!(stats.requests, 6);
}
