//! Property-based tests over coordinator invariants (hand-rolled generator
//! loop — proptest is unavailable offline; `util::Rng` drives randomized
//! cases with printed seeds so failures are reproducible).

use std::collections::HashMap;

use hadapt::data::{generate, make_batch, Label, TASKS};
use hadapt::metrics::{accuracy, f1, matthews, pearson};
use hadapt::model::{layer_of, parse_modules, FreezeMask, LayerRange, Module};
use hadapt::optim::{clip_global_norm, AdamW, LrSchedule};
use hadapt::runtime::{InitKind, ModelInfo, ParamSpec};
use hadapt::util::{json, Json, Rng};

const CASES: usize = 60;

fn rand_model(rng: &mut Rng) -> ModelInfo {
    let layers = rng.range(1, 6);
    let hidden = [16, 32, 64][rng.below(3)];
    let mut params = Vec::new();
    params.push(ParamSpec {
        name: "embeddings.word_embeddings.weight".into(),
        shape: vec![rng.range(16, 64), hidden],
        init: InitKind::Normal,
    });
    for l in 0..layers {
        for (suffix, shape, init) in [
            ("attention.self.query.weight", vec![hidden, hidden], InitKind::Normal),
            ("hadamard.weight", vec![hidden], InitKind::Ones),
            ("hadamard.bias", vec![hidden], InitKind::Zeros),
            ("attention.output.LayerNorm.weight", vec![hidden], InitKind::Ones),
            ("output.LayerNorm.weight", vec![hidden], InitKind::Ones),
            ("output.LayerNorm.bias", vec![hidden], InitKind::Zeros),
        ] {
            params.push(ParamSpec {
                name: format!("encoder.layer.{l}.{suffix}"),
                shape,
                init,
            });
        }
    }
    params.push(ParamSpec {
        name: "classifier.weight".into(),
        shape: vec![hidden, 3],
        init: InitKind::Normal,
    });
    let index = params
        .iter()
        .enumerate()
        .map(|(i, p)| (p.name.clone(), i))
        .collect();
    let mut groups = HashMap::new();
    groups.insert(
        "full".to_string(),
        params
            .iter()
            .filter(|p| !p.name.contains(".hadamard."))
            .map(|p| p.name.clone())
            .collect::<Vec<_>>(),
    );
    ModelInfo {
        name: "prop".into(),
        layers,
        hidden,
        heads: 2,
        ffn: hidden * 2,
        vocab: 64,
        max_len: 16,
        lora_alpha: 8.0,
        params,
        index,
        groups,
        mlm_group: vec![],
    }
}

#[test]
fn prop_mask_union_is_monotone_and_counts_add_up() {
    let mut rng = Rng::new(0xA11CE);
    for case in 0..CASES {
        let info = rand_model(&mut rng);
        let a = FreezeMask::stage2(&info, &[Module::HadamardWeight], LayerRange::All, false);
        let b = FreezeMask::stage2(&info, &[Module::HadamardBias], LayerRange::All, false);
        let u = a.union(&b);
        for i in 0..info.params.len() {
            assert_eq!(
                u.trainable[i],
                a.trainable[i] || b.trainable[i],
                "case {case} param {i}"
            );
        }
        // W and B are disjoint, so counts add exactly
        assert_eq!(
            u.trainable_scalars(&info),
            a.trainable_scalars(&info) + b.trainable_scalars(&info),
            "case {case}"
        );
    }
}

#[test]
fn prop_layer_restriction_never_adds_params() {
    let mut rng = Rng::new(0xB0B);
    for case in 0..CASES {
        let info = rand_model(&mut rng);
        let all = FreezeMask::stage2(
            &info,
            &[Module::HadamardWeight, Module::HadamardBias, Module::Norm],
            LayerRange::All,
            true,
        );
        let mut prev = 0usize;
        for k in 1..=info.layers {
            let m = all.restrict_layers(&info, LayerRange::LastK(k));
            let n = m.trainable_scalars(&info);
            assert!(n >= prev, "case {case}: k={k} shrank {prev}->{n}");
            assert!(n <= all.trainable_scalars(&info));
            for (i, p) in info.params.iter().enumerate() {
                if m.trainable[i] {
                    assert!(all.trainable[i]);
                    if let Some(l) = layer_of(&p.name) {
                        assert!(l + k >= info.layers, "case {case} layer {l} k {k}");
                    }
                }
            }
            prev = n;
        }
        // full restriction == original
        let m = all.restrict_layers(&info, LayerRange::LastK(info.layers));
        assert_eq!(m.trainable_scalars(&info), all.trainable_scalars(&info));
    }
}

#[test]
fn prop_parse_modules_roundtrip() {
    let mut rng = Rng::new(0xC0DE);
    let all = [
        Module::HadamardWeight,
        Module::HadamardBias,
        Module::Norm,
        Module::AttNorm,
    ];
    for _ in 0..CASES {
        let k = rng.range(1, 5);
        let picked = rng.choose_distinct(4, k);
        let combo: Vec<&str> = picked.iter().map(|&i| all[i].label()).collect();
        let text = combo.join("+");
        let parsed = parse_modules(&text);
        assert_eq!(parsed.len(), picked.len(), "{text}");
        for &i in &picked {
            assert!(parsed.contains(&all[i]), "{text}");
        }
    }
}

#[test]
fn prop_adamw_untouched_params_never_move() {
    // simulate a masked optimizer pass: untouched tensors stay identical
    let mut rng = Rng::new(0xDEAD);
    for _ in 0..CASES {
        let n = rng.range(1, 40);
        let mut opt = AdamW::new(0.01);
        let frozen: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let snapshot = frozen.clone();
        let mut trained = frozen.clone();
        for _ in 0..5 {
            opt.next_step();
            let g: Vec<f32> = (0..n).map(|_| rng.normal() + 0.1).collect();
            opt.update("t.weight", &mut trained, &g, 0.01);
            // frozen: simply not updated
        }
        assert_eq!(frozen, snapshot);
        assert_ne!(trained, snapshot);
    }
}

#[test]
fn prop_clip_never_increases_norm() {
    let mut rng = Rng::new(0xFEED);
    for _ in 0..CASES {
        let tensors = rng.range(1, 5);
        let mut grads: Vec<Vec<f32>> = (0..tensors)
            .map(|_| {
                let n = rng.range(1, 30);
                (0..n).map(|_| rng.normal() * 10.0).collect()
            })
            .collect();
        let max = 0.5 + rng.next_f32() * 3.0;
        let before: f32 = grads.iter().flatten().map(|x| x * x).sum::<f32>().sqrt();
        let reported = clip_global_norm(&mut grads, max);
        let after: f32 = grads.iter().flatten().map(|x| x * x).sum::<f32>().sqrt();
        assert!((reported - before).abs() < before.max(1.0) * 1e-4);
        assert!(after <= max * 1.001 || after <= before);
        if before <= max {
            assert!((after - before).abs() < 1e-5);
        }
    }
}

#[test]
fn prop_schedule_bounded_and_nonnegative() {
    let mut rng = Rng::new(0x5EED);
    for _ in 0..CASES {
        let base = rng.next_f32() * 0.01 + 1e-5;
        let warmup = rng.range(0, 50) as u64;
        let total = warmup + rng.range(1, 200) as u64;
        let s = LrSchedule::warmup_decay(base, warmup, total);
        for step in 0..total + 20 {
            let lr = s.at(step);
            assert!(lr >= 0.0, "negative lr");
            assert!(lr <= base * 1.0001, "lr {lr} > base {base}");
        }
    }
}

#[test]
fn prop_metrics_bounded() {
    let mut rng = Rng::new(0xACC);
    for _ in 0..CASES {
        let n = rng.range(2, 60);
        let preds: Vec<usize> = (0..n).map(|_| rng.below(2)).collect();
        let golds: Vec<usize> = (0..n).map(|_| rng.below(2)).collect();
        let acc = accuracy(&preds, &golds);
        assert!((0.0..=1.0).contains(&acc));
        let mcc = matthews(&preds, &golds);
        assert!((-1.0..=1.0).contains(&mcc));
        let f = f1(&preds, &golds);
        assert!((0.0..=1.0).contains(&f));
        let xs: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let ys: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let p = pearson(&xs, &ys);
        assert!((-1.0001..=1.0001).contains(&p));
        // perfect prediction maxes every metric
        assert_eq!(accuracy(&golds, &golds), 1.0);
        assert!((pearson(&xs, &xs) - 1.0).abs() < 1e-6);
    }
}

#[test]
fn prop_batcher_rows_well_formed_for_all_tasks() {
    let mut rng = Rng::new(0xBA7C4);
    for info in TASKS {
        let ds = generate(info, 99, "train", 40);
        for _ in 0..10 {
            let k = rng.range(1, 17);
            let idx: Vec<usize> = (0..k).map(|_| rng.below(40)).collect();
            let b = make_batch(&ds, &idx, 16, 32);
            assert_eq!(b.tokens.len(), 16 * 32);
            for row in 0..16 {
                let r = &b.tokens[row * 32..(row + 1) * 32];
                assert_eq!(r[0], 1, "CLS first");
                // mask is a prefix: once 0, stays 0
                let m = &b.attn_mask[row * 32..(row + 1) * 32];
                let mut seen_pad = false;
                for (p, &v) in m.iter().enumerate() {
                    if v == 0.0 {
                        seen_pad = true;
                    } else {
                        assert!(!seen_pad, "mask not a prefix at {p}");
                    }
                }
                // type ids only 0/1
                assert!(b.type_ids[row * 32..(row + 1) * 32]
                    .iter()
                    .all(|&t| t == 0 || t == 1));
            }
            // labels consistent with dataset
            for (bi, &i) in idx.iter().enumerate().take(b.real) {
                match ds.examples[i].label {
                    Label::Class(c) => assert_eq!(b.labels[bi], c),
                    Label::Score(s) => assert_eq!(b.labels_f32[bi], s),
                }
            }
        }
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    let mut rng = Rng::new(0x75AF);
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth > 2 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.next_u64() % 100_000) as f64 / 8.0),
            3 => {
                let n = rng.range(0, 8);
                Json::Str((0..n).map(|_| {
                    char::from_u32(rng.range(32, 0x250) as u32).unwrap_or('x')
                }).collect())
            }
            4 => Json::Arr((0..rng.range(0, 4)).map(|_| gen(rng, depth + 1)).collect()),
            _ => {
                let mut o = Json::obj();
                for i in 0..rng.range(0, 4) {
                    o.set(&format!("k{i}"), gen(rng, depth + 1));
                }
                o
            }
        }
    }
    for case in 0..CASES {
        let v = gen(&mut rng, 0);
        let text = v.render_pretty();
        let back = json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(back, v, "case {case}");
        let compact = v.render();
        assert_eq!(json::parse(&compact).unwrap(), v, "case {case} compact");
    }
}

/// Build a random-but-valid `/infer` body: a task name exercising the
/// string escape space (quotes, backslashes, non-ASCII), token ids from
/// the full `i32` range, `text_b` in all three shapes (absent, `null`
/// is covered by the decoder's unit tests, array). Rendered through
/// `util::json` — an independent serializer, escaping included.
fn rand_wire_request(rng: &mut Rng) -> (String, Vec<i32>, Option<Vec<i32>>, String) {
    let task: String = (0..rng.range(1, 9))
        .map(|_| match rng.below(6) {
            0 => '"',
            1 => '\\',
            2 => '/',
            _ => char::from_u32(rng.range(32, 0x500) as u32).unwrap_or('x'),
        })
        .collect();
    let ids = |rng: &mut Rng| -> Vec<i32> {
        (0..rng.range(0, 12))
            .map(|_| match rng.below(4) {
                0 => rng.next_u64() as i32, // full range, signs included
                1 => i32::MAX - rng.below(3) as i32,
                2 => i32::MIN + rng.below(3) as i32,
                _ => rng.below(30_000) as i32,
            })
            .collect()
    };
    let seq_a = ids(rng);
    let seq_b = if rng.chance(0.5) { Some(ids(rng)) } else { None };
    let mut body = Json::obj();
    body.set("task", Json::Str(task.clone()));
    body.set(
        "text_a",
        Json::Arr(seq_a.iter().map(|&t| Json::Num(t as f64)).collect()),
    );
    if let Some(b) = &seq_b {
        body.set(
            "text_b",
            Json::Arr(b.iter().map(|&t| Json::Num(t as f64)).collect()),
        );
    }
    let text = body.render();
    (task, seq_a, seq_b, text)
}

#[test]
fn prop_wire_decode_roundtrips_exactly() {
    use hadapt::runtime::wire::decode_request;
    use hadapt::runtime::{RequestScratch, WireLimits};
    let mut rng = Rng::new(0x1B0B5);
    let limits = WireLimits::default();
    let mut scratch = RequestScratch::default();
    for case in 0..CASES {
        let (task, seq_a, seq_b, text) = rand_wire_request(&mut rng);
        decode_request(text.as_bytes(), &limits, &mut scratch)
            .unwrap_or_else(|e| panic!("case {case}: {:?} on {text}", e.code()));
        assert_eq!(scratch.task, task, "case {case}: {text}");
        assert_eq!(scratch.seq_a, seq_a, "case {case}: {text}");
        assert_eq!(scratch.text_b(), seq_b.as_deref(), "case {case}: {text}");
    }
}

#[test]
fn prop_wire_mutations_terminate_ok_or_typed() {
    use hadapt::runtime::wire::decode_request;
    use hadapt::runtime::{RequestScratch, WireLimits};
    use hadapt::util::{Event, PullParser};
    let mut rng = Rng::new(0xF422);
    let limits = WireLimits::default();
    let mut scratch = RequestScratch::default();
    let mut sbuf = Vec::new();
    for case in 0..CASES * 4 {
        let (_, _, _, text) = rand_wire_request(&mut rng);
        let mut body = text.into_bytes();
        for _ in 0..rng.range(1, 5) {
            let at = rng.below(body.len());
            body[at] = (rng.next_u64() & 0xFF) as u8;
        }
        // the extractor returns — servable or typed error, never a panic
        let _ = decode_request(&body, &limits, &mut scratch);
        // and the raw parser drains in bounded steps (non-recursive, no
        // livelock): every next() either consumes input or terminates
        let mut p = PullParser::new(&body, &mut sbuf);
        let mut steps = 0usize;
        loop {
            steps += 1;
            assert!(
                steps <= body.len() * 4 + 16,
                "case {case}: parser failed to terminate on {:?}",
                String::from_utf8_lossy(&body)
            );
            match p.next() {
                Err(_) | Ok(Event::End) => break,
                Ok(_) => {}
            }
        }
    }
}
