//! The multi-connection ingress battery: concurrent wire serving into
//! the single-owner session, proven four ways.
//!
//! 1. **Storm survival, typed throughout.** Eight client threads each
//!    replay the full adversarial fixture corpus on fresh connections
//!    while four well-formed clients stream pipelined requests on
//!    distinct tenants. Every fixture still classifies to its expected
//!    typed code, every streamed reply carries its own connection's
//!    task (no cross-connection reply bleed), and the server survives
//!    with its reject ledger accounting for every fixture × 8.
//! 2. **Bitwise equality across connection counts.** The same
//!    mixed-tenant request set served over 1 connection, over 8
//!    concurrent connections (waves mixing rows from several
//!    connections), and through the in-process [`ServeSession`] yields
//!    bitwise-identical logits per request — concurrency adds zero
//!    numeric drift.
//! 3. **Mid-burst disconnect degrades clean.** A client that drops
//!    mid-pipeline neither wedges the in-flight wave (the surviving
//!    connection's rows still serve) nor leaks its connection slot
//!    (`conns_open` returns to truth, the slot is reusable).
//! 4. **The accept-limit tier.** Connections past `max_conns` shed at
//!    accept with a typed `too-many-connections` 503 and an immediate
//!    close; freeing a slot makes the table accept again.

#[path = "common/wire_client.rs"]
mod wire_client;

use std::fs;
use std::io::{Read as _, Write as _};
use std::net::TcpStream;
use std::thread;
use std::time::{Duration, Instant};

use hadapt::model::ParamStore;
use hadapt::runtime::{
    spawn_synthetic_server, synthetic_adapters, Engine, ServePolicy, ServeRequest,
    ServeSession, SpawnOpts,
};
use hadapt::util::json;

fn fixtures() -> Vec<(String, Vec<u8>)> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/wire");
    let mut v: Vec<_> = fs::read_dir(dir)
        .expect("fixture corpus missing — run tools/gen_wire_fixtures.py")
        .map(|e| {
            let p = e.unwrap().path();
            let name = p.file_stem().unwrap().to_str().unwrap().to_string();
            (name, fs::read(&p).unwrap())
        })
        .collect();
    v.sort();
    assert!(v.len() >= 30, "corpus shrank: only {} fixtures", v.len());
    v
}

fn expected_code(name: &str) -> &str {
    name.split("__").next().unwrap()
}

/// Extract the logits array from a 200 reply body as raw f32 bits.
fn logit_bits(body: &str) -> Vec<u32> {
    let v = json::parse(body).unwrap_or_else(|e| panic!("{e}\n{body}"));
    v.get("logits")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| (x.as_f64().unwrap() as f32).to_bits())
        .collect()
}

/// The shared mixed-tenant request set for the equality test: varying
/// lengths, both tenants, some with `text_b`.
fn equality_requests() -> Vec<(String, Vec<i32>, Option<Vec<i32>>)> {
    (0..16)
        .map(|i| {
            let task = if i % 2 == 0 { "sst2" } else { "rte" };
            let a: Vec<i32> = (0..3 + i % 6).map(|j| 5 + (i * 13 + j * 7) as i32 % 400).collect();
            let b: Option<Vec<i32>> = if i % 3 == 0 {
                Some((0..2 + i % 3).map(|j| 9 + (i * 11 + j * 3) as i32 % 400).collect())
            } else {
                None
            };
            (task.to_string(), a, b)
        })
        .collect()
}

#[test]
fn concurrent_corpus_storm_classifies_typed_with_no_reply_bleed() {
    let mut opts = SpawnOpts::tiny(7);
    // four streaming tenants, each pinned to its own connection so a
    // reply carrying the wrong task would prove cross-connection bleed
    opts.tasks = vec![
        "sst2".to_string(),
        "rte".to_string(),
        "mrpc".to_string(),
        "cola".to_string(),
    ];
    // generous slot table: 12 concurrent clients plus churn headroom —
    // an accept-shed here would misclassify a fixture, so the final
    // stats assert none happened
    opts.max_conns = 32;
    let (addr, handle) = spawn_synthetic_server(opts).unwrap();

    let corpus = fixtures();
    let ok_per_pass = corpus.iter().filter(|(n, _)| expected_code(n) == "ok").count() as u64;
    let err_per_pass = corpus.len() as u64 - ok_per_pass;

    thread::scope(|s| {
        // 8 adversarial replayers, each the whole corpus on fresh conns
        for t in 0..8 {
            let corpus = &corpus;
            s.spawn(move || {
                for (name, bytes) in corpus.iter() {
                    let code = expected_code(name);
                    let half_close = code.starts_with("truncated");
                    let resp = wire_client::send_and_read(addr, bytes, 1, half_close)
                        .pop()
                        .unwrap();
                    if code == "ok" {
                        assert_eq!(resp.status, 200, "thread {t} fixture {name}: {}", resp.body);
                        assert!(
                            resp.body.contains("\"logits\":["),
                            "thread {t} fixture {name}: {}",
                            resp.body
                        );
                    } else {
                        assert_ne!(resp.status, 200, "thread {t} fixture {name}: {}", resp.body);
                        assert!(
                            resp.body.contains(&format!("\"error\":\"{code}\"")),
                            "thread {t} fixture {name}: status {} body {}",
                            resp.status,
                            resp.body
                        );
                    }
                }
            });
        }
        // 4 well-formed streamers, one tenant each, pipelined in bursts
        for (k, task) in ["sst2", "rte", "mrpc", "cola"].into_iter().enumerate() {
            s.spawn(move || {
                let mut c = TcpStream::connect(addr).unwrap();
                for round in 0..10 {
                    let mut burst = Vec::new();
                    for j in 0..3 {
                        let seq: Vec<i32> =
                            (0..4).map(|i| 3 + (k * 97 + round * 17 + j * 5 + i) as i32 % 300).collect();
                        burst.extend_from_slice(&wire_client::infer_req(task, &seq, None));
                    }
                    c.write_all(&burst).unwrap();
                    for (j, resp) in wire_client::read_responses(&mut c, 3).into_iter().enumerate()
                    {
                        assert_eq!(resp.status, 200, "streamer {task} r{round}.{j}: {}", resp.body);
                        // the bleed check: a reply routed off another
                        // connection would name that connection's tenant
                        assert!(
                            resp.body.contains(&format!("\"task\":\"{task}\"")),
                            "streamer {task} r{round}.{j} got a foreign reply: {}",
                            resp.body
                        );
                    }
                }
            });
        }
    });

    // the server survived the storm: counters account for everything
    let mut c = TcpStream::connect(addr).unwrap();
    c.write_all(&wire_client::get("/stats")).unwrap();
    let s = wire_client::read_responses(&mut c, 1).pop().unwrap();
    let stats = json::parse(&s.body).unwrap();
    let n = |k: &str| stats.get(k).unwrap().as_usize().unwrap() as u64;
    assert_eq!(n("replies"), 8 * ok_per_pass + 4 * 10 * 3, "stats: {}", s.body);
    assert_eq!(
        n("rejects_http") + n("rejects_parse") + n("rejects_submit"),
        8 * err_per_pass,
        "every non-ok fixture × 8 lands in exactly one reject counter: {}",
        s.body
    );
    assert_eq!(n("conns_rejected"), 0, "no accept-shed during the storm: {}", s.body);

    c.write_all(&wire_client::post("/shutdown")).unwrap();
    let r = wire_client::read_responses(&mut c, 1).pop().unwrap();
    assert_eq!(r.status, 200);
    let final_stats = handle.join().unwrap().unwrap();
    assert_eq!(final_stats.replies, 8 * ok_per_pass + 4 * 10 * 3);
    assert_eq!(final_stats.conns_rejected, 0);
}

#[test]
fn logits_are_bitwise_identical_across_1_conn_8_conns_and_in_process() {
    let seed = 33;
    let tasks = vec!["sst2".to_string(), "rte".to_string()];
    let cases = equality_requests();

    // in-process reference: the same deterministic backbone + synthetic
    // tenants SpawnOpts::tiny(seed) builds inside the server thread,
    // each request served as its own wave
    let engine = Engine::new_with_threads("/definitely/not/a/dir", 2).unwrap();
    let info = engine.manifest().model("tiny").unwrap().clone();
    let store = ParamStore::init(&info, seed);
    let mut session = ServeSession::new(&engine, "tiny", &store, 4).unwrap();
    for a in synthetic_adapters(&info, &store, &tasks, seed).unwrap() {
        session.register_task(a).unwrap();
    }
    let mut expected: Vec<Vec<u32>> = Vec::new();
    for (task, a, b) in &cases {
        session
            .submit(ServeRequest { task: task.clone(), seq_a: a.clone(), seq_b: b.clone() })
            .unwrap();
        let reply = session.run_pending().unwrap().pop().unwrap();
        expected.push(reply.logits.iter().map(|v| v.to_bits()).collect());
    }

    // one server for both wire runs: a 20ms flush window + a deep queue
    // so the 8-connection burst gathers into waves that mix connections
    let mut opts = SpawnOpts::tiny(seed);
    opts.policy = ServePolicy { queue_cap: 32, window_us: 20_000, ..ServePolicy::default() };
    opts.max_conns = 10;
    let (addr, handle) = spawn_synthetic_server(opts).unwrap();

    // run A: all 16 requests pipelined down one connection
    let mut one = TcpStream::connect(addr).unwrap();
    let mut burst = Vec::new();
    for (task, a, b) in &cases {
        burst.extend_from_slice(&wire_client::infer_req(task, a, b.as_deref()));
    }
    one.write_all(&burst).unwrap();
    for (i, resp) in wire_client::read_responses(&mut one, cases.len()).iter().enumerate() {
        assert_eq!(resp.status, 200, "1-conn case {i}: {}", resp.body);
        assert_eq!(
            logit_bits(&resp.body),
            expected[i],
            "1-conn case {i}: wire logits drifted from in-process"
        );
    }
    drop(one);

    // run B: the same 16 requests dealt round-robin over 8 concurrent
    // connections (request i on connection i % 8, two per connection,
    // pipelined) — replies must come back on the right connection, in
    // that connection's order, still bit-identical
    let mut conns: Vec<TcpStream> =
        (0..8).map(|_| TcpStream::connect(addr).unwrap()).collect();
    for (i, (task, a, b)) in cases.iter().enumerate() {
        conns[i % 8].write_all(&wire_client::infer_req(task, a, b.as_deref())).unwrap();
    }
    for (ci, c) in conns.iter_mut().enumerate() {
        let resps = wire_client::read_responses(c, 2);
        for (j, resp) in resps.iter().enumerate() {
            let i = ci + 8 * j;
            assert_eq!(resp.status, 200, "8-conn case {i}: {}", resp.body);
            assert_eq!(
                logit_bits(&resp.body),
                expected[i],
                "8-conn case {i} (conn {ci} reply {j}): logits drifted"
            );
        }
    }

    // the 8-connection run really did mix connections inside waves
    let mut c = conns.pop().unwrap();
    c.write_all(&wire_client::get("/stats")).unwrap();
    let s = wire_client::read_responses(&mut c, 1).pop().unwrap();
    let stats = json::parse(&s.body).unwrap();
    let mixed = stats.get("cross_conn_waves").unwrap().as_usize().unwrap();
    assert!(mixed >= 1, "expected at least one wave mixing connections: {}", s.body);

    c.write_all(&wire_client::post("/shutdown")).unwrap();
    let r = wire_client::read_responses(&mut c, 1).pop().unwrap();
    assert_eq!(r.status, 200);
    let final_stats = handle.join().unwrap().unwrap();
    assert_eq!(final_stats.replies, 2 * cases.len() as u64);
    assert_eq!(final_stats.conns_rejected, 0);
}

#[test]
fn mid_burst_disconnect_degrades_typed_without_wedging_or_leaking_a_slot() {
    let mut opts = SpawnOpts::tiny(21);
    // a long window so both connections' rows are queued together when
    // the disconnect lands mid-burst
    opts.policy = ServePolicy { queue_cap: 16, window_us: 50_000, ..ServePolicy::default() };
    opts.max_conns = 4;
    let (addr, handle) = spawn_synthetic_server(opts).unwrap();

    // connection B submits first and stays
    let mut b = TcpStream::connect(addr).unwrap();
    let mut b_burst = Vec::new();
    for j in 0..2 {
        b_burst.extend_from_slice(&wire_client::infer_req("rte", &[40 + j, 41 + j], None));
    }
    b.write_all(&b_burst).unwrap();

    // connection A pipelines three rows into the same window, then dies
    let mut a = TcpStream::connect(addr).unwrap();
    let mut a_burst = Vec::new();
    for j in 0..3 {
        a_burst.extend_from_slice(&wire_client::infer_req("sst2", &[7 + j, 8 + j, 9 + j], None));
    }
    a.write_all(&a_burst).unwrap();
    // give the server a beat to gather A's rows into the open window,
    // then disconnect mid-burst
    thread::sleep(Duration::from_millis(10));
    drop(a);

    // the wave is not wedged: B's rows still serve, correct task, 200s
    for (j, resp) in wire_client::read_responses(&mut b, 2).iter().enumerate() {
        assert_eq!(resp.status, 200, "survivor reply {j}: {}", resp.body);
        assert!(resp.body.contains("\"task\":\"rte\""), "survivor reply {j}: {}", resp.body);
    }

    // the dead connection's slot is released (no leak): conns_open
    // settles to B + this stats connection
    let mut c = TcpStream::connect(addr).unwrap();
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        c.write_all(&wire_client::get("/stats")).unwrap();
        let s = wire_client::read_responses(&mut c, 1).pop().unwrap();
        let stats = json::parse(&s.body).unwrap();
        let open = stats.get("conns_open").unwrap().as_usize().unwrap();
        if open == 2 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "conns_open stuck at {open} — the dead connection's slot leaked: {}",
            s.body
        );
        thread::sleep(Duration::from_millis(5));
    }

    // and the slot is reusable: a fresh connection serves normally
    let mut d = TcpStream::connect(addr).unwrap();
    d.write_all(&wire_client::infer_req("sst2", &[3, 4, 5], None)).unwrap();
    let resp = wire_client::read_responses(&mut d, 1).pop().unwrap();
    assert_eq!(resp.status, 200, "slot reuse after disconnect: {}", resp.body);

    c.write_all(&wire_client::post("/shutdown")).unwrap();
    let r = wire_client::read_responses(&mut c, 1).pop().unwrap();
    assert_eq!(r.status, 200);
    let final_stats = handle.join().unwrap().unwrap();
    // A, B, the stats connection and the reuse connection all accepted;
    // nothing shed — the disconnect consumed no extra slots
    assert_eq!(final_stats.connections, 4);
    assert_eq!(final_stats.conns_rejected, 0);
    // B's two rows and the reuse row always serve; A's three may or may
    // not land in the send buffer before the peer vanishes
    assert!(final_stats.replies >= 3, "survivor replies lost: {final_stats:?}");
}

#[test]
fn accept_limit_sheds_typed_503_and_a_freed_slot_accepts_again() {
    let mut opts = SpawnOpts::tiny(27);
    opts.max_conns = 2;
    let (addr, handle) = spawn_synthetic_server(opts).unwrap();

    // fill the two-slot table with live connections
    let mut a = TcpStream::connect(addr).unwrap();
    a.write_all(&wire_client::infer_req("sst2", &[5, 6], None)).unwrap();
    assert_eq!(wire_client::read_responses(&mut a, 1).pop().unwrap().status, 200);
    let mut b = TcpStream::connect(addr).unwrap();
    b.write_all(&wire_client::infer_req("rte", &[7, 8], None)).unwrap();
    assert_eq!(wire_client::read_responses(&mut b, 1).pop().unwrap().status, 200);

    // the third connection sheds at accept: typed 503, then EOF
    let mut c = TcpStream::connect(addr).unwrap();
    let resp = wire_client::read_responses(&mut c, 1).pop().unwrap();
    assert_eq!(resp.status, 503, "accept-limit reply: {}", resp.body);
    assert!(
        resp.body.contains("\"error\":\"too-many-connections\""),
        "accept-limit reply: {}",
        resp.body
    );
    let mut rest = Vec::new();
    assert_eq!(c.read_to_end(&mut rest).unwrap(), 0, "shed connection must close");

    // free a slot and the table accepts again (retry until the server's
    // scan notices the close)
    drop(a);
    let deadline = Instant::now() + Duration::from_secs(5);
    let mut d = loop {
        let mut d = TcpStream::connect(addr).unwrap();
        d.write_all(&wire_client::infer_req("sst2", &[9, 10], None)).unwrap();
        let resp = wire_client::read_responses(&mut d, 1).pop().unwrap();
        if resp.status == 200 {
            break d;
        }
        assert!(
            resp.body.contains("\"error\":\"too-many-connections\""),
            "unexpected rejection while waiting for the freed slot: {}",
            resp.body
        );
        assert!(Instant::now() < deadline, "freed slot never became acceptable");
        thread::sleep(Duration::from_millis(5));
    };

    // the ledger saw at least the one deliberate shed
    d.write_all(&wire_client::get("/stats")).unwrap();
    let s = wire_client::read_responses(&mut d, 1).pop().unwrap();
    let stats = json::parse(&s.body).unwrap();
    assert!(
        stats.get("conns_rejected").unwrap().as_usize().unwrap() >= 1,
        "stats: {}",
        s.body
    );
    assert_eq!(stats.get("max_conns").unwrap().as_usize().unwrap(), 2, "stats: {}", s.body);

    d.write_all(&wire_client::post("/shutdown")).unwrap();
    let r = wire_client::read_responses(&mut d, 1).pop().unwrap();
    assert_eq!(r.status, 200);
    drop(b);
    let final_stats = handle.join().unwrap().unwrap();
    assert!(final_stats.conns_rejected >= 1);
    assert_eq!(final_stats.replies, 3);
}
