//! Engine-level persistent-pool lifecycle: the session hot path spawns
//! workers once, reuses them across train steps, keeps serial and
//! parallel engines numerically in agreement, and joins the workers when
//! the engine drops.
//!
//! The pool's own failure modes (panic propagation, drop-while-idle,
//! auto-detect resolution, grain short-circuits) live in
//! `runtime::pool::tests`; the kernel-level zero-alloc/zero-spawn counter
//! proof lives in `tests/workspace_alloc.rs`; the artifact-level spawn
//! freeze lives in `runtime::native::tests`. This file pins the
//! user-visible surface: `Engine::pool_stats()` on a real `Session` loop.

use hadapt::data::{class_mask, generate, make_batch, task_info};
use hadapt::model::{FreezeMask, ParamStore};
use hadapt::optim::LrSchedule;
use hadapt::runtime::{Engine, Manifest, NativeBackend};
use hadapt::train::Session;

fn engine_with_threads(threads: usize) -> Engine {
    Engine::with_backend(
        Manifest::builtin("artifacts"),
        Box::new(NativeBackend::with_threads(threads)),
    )
}

/// Run `steps` hadamard train steps on a fresh tiny-model session and
/// return the per-step losses.
fn run_steps(engine: &Engine, steps: usize) -> Vec<f32> {
    let info = engine.manifest().model("tiny").unwrap().clone();
    let store = ParamStore::init(&info, 7);
    let mask = FreezeMask::from_names(&info, &info.group("hadamard").unwrap().to_vec());
    let (batch, seq) = (engine.manifest().batch, engine.manifest().seq_len);
    let ds = generate(task_info("sst2").unwrap(), 1, "dev", batch);
    let idx: Vec<usize> = (0..batch).collect();
    let bt = make_batch(&ds, &idx, batch, seq);
    let cm = class_mask(2);
    let mut session = Session::new(
        engine,
        &Manifest::train_name("cls", "hadamard", "tiny"),
        store,
        mask,
        LrSchedule::constant(1e-3),
    )
    .unwrap();
    (0..steps).map(|_| session.step_cls(&bt, &cm).unwrap()).collect()
}

#[test]
fn session_steps_reuse_persistent_workers() {
    let engine = engine_with_threads(2);
    let before = engine.pool_stats();
    assert_eq!(before.threads_spawned, 0, "workers spawn lazily, not at engine build");
    let losses = run_steps(&engine, 4);
    assert!(losses.iter().all(|l| l.is_finite()));
    let after = engine.pool_stats();
    assert_eq!(after.threads_spawned, 1, "threads=2 => exactly one persistent worker");
    assert!(after.jobs_dispatched > 0, "tiny-model steps must fork at least the GEMMs");
    // re-running on the same engine reuses the same worker
    run_steps(&engine, 2);
    assert_eq!(engine.pool_stats().threads_spawned, 1, "no respawn across sessions");
    // dropping the engine joins the worker; a hang here times the suite out
    drop(engine);
}

#[test]
fn serial_and_parallel_engines_agree_on_losses() {
    // The CI workflow runs the whole suite twice (default and
    // HADAPT_THREADS=1); this test additionally pins the serial/parallel
    // agreement inside one process. Activation math may reorder float
    // reductions across thread counts (~1e-7 relative); losses after a
    // few steps must agree far inside kernel-parity tolerance.
    let serial = engine_with_threads(1);
    let parallel = engine_with_threads(3);
    let a = run_steps(&serial, 3);
    let b = run_steps(&parallel, 3);
    assert_eq!(serial.pool_stats().threads_spawned, 0, "threads=1 must stay spawn-free");
    assert_eq!(parallel.pool_stats().threads_spawned, 2);
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert!(
            (x - y).abs() <= 1e-4 * (1.0 + x.abs()),
            "step {i}: serial loss {x} vs parallel {y}"
        );
    }
}

#[test]
fn scalar_reference_engine_stays_spawn_free() {
    use hadapt::runtime::Pool;
    let engine = Engine::with_backend(
        Manifest::builtin("artifacts"),
        Box::new(NativeBackend::with_pool(Pool::scalar_reference())),
    );
    let losses = run_steps(&engine, 2);
    assert!(losses.iter().all(|l| l.is_finite()));
    let st = engine.pool_stats();
    assert_eq!(st.threads_spawned, 0);
    assert_eq!(st.jobs_dispatched, 0, "scalar dispatch never forks");
}
