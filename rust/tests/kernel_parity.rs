//! Property-style parity suite: the blocked/parallel kernels against the
//! retained PR 1 scalar reference (`runtime::kernels::scalar`) across odd
//! shapes — non-multiples of the register tile, single rows/columns, and
//! `threads = 1` vs `N` — plus NaN-propagation regressions. Hand-rolled
//! generator loop over `util::Rng` (proptest is unavailable offline);
//! seeds are fixed so failures reproduce.

use hadapt::runtime::kernels::{self as k, scalar};
use hadapt::runtime::Pool;
use hadapt::util::Rng;

const TOL: f32 = 1e-5;

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal()).collect()
}

fn assert_close(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= TOL * (1.0 + w.abs()),
            "{what}[{i}]: got {g}, reference {w}"
        );
    }
}

/// Shape set chosen to cross every code path: rows below/at/above the
/// MR=4 tile, dot lengths below/at/above the 8-lane width, and sizes
/// around the shard grain.
const DIMS: [usize; 7] = [1, 2, 3, 4, 5, 8, 17];

fn pools() -> [Pool; 2] {
    [Pool::serial(), Pool::with_threads(4)]
}

#[test]
fn matmul_nn_parity_across_odd_shapes() {
    let mut rng = Rng::new(0x90_01);
    for &m in &DIMS {
        for &kk in &DIMS {
            for &n in &DIMS {
                let a = randv(&mut rng, m * kk);
                let b = randv(&mut rng, kk * n);
                let want = scalar::matmul(&a, &b, m, kk, n);
                for pool in pools() {
                    let got = k::matmul(&pool, &a, &b, m, kk, n);
                    assert_close(&got, &want, &format!("nn {m}x{kk}x{n}"));
                }
            }
        }
    }
    // a large non-multiple-of-everything shape
    let (m, kk, n) = (33, 65, 129);
    let a = randv(&mut rng, m * kk);
    let b = randv(&mut rng, kk * n);
    let want = scalar::matmul(&a, &b, m, kk, n);
    for pool in pools() {
        assert_close(&k::matmul(&pool, &a, &b, m, kk, n), &want, "nn 33x65x129");
    }
}

#[test]
fn matmul_nt_parity_across_odd_shapes() {
    let mut rng = Rng::new(0x90_02);
    for &m in &DIMS {
        for &kk in &DIMS {
            for &n in &DIMS {
                let a = randv(&mut rng, m * kk);
                let b = randv(&mut rng, n * kk);
                let want = scalar::matmul_nt(&a, &b, m, kk, n);
                for pool in pools() {
                    let got = k::matmul_nt(&pool, &a, &b, m, kk, n);
                    assert_close(&got, &want, &format!("nt {m}x{kk}x{n}"));
                }
            }
        }
    }
}

#[test]
fn matmul_tn_acc_parity_and_accumulation() {
    let mut rng = Rng::new(0x90_03);
    for &m in &DIMS {
        for &kk in &DIMS {
            for &n in &DIMS {
                let a = randv(&mut rng, kk * m);
                let b = randv(&mut rng, kk * n);
                // non-zero initial accumulator: += semantics must hold
                let init = randv(&mut rng, m * n);
                let mut want = init.clone();
                scalar::matmul_tn_acc(&a, &b, &mut want, kk, m, n);
                for pool in pools() {
                    let mut got = init.clone();
                    k::matmul_tn_acc(&pool, &a, &b, &mut got, kk, m, n);
                    assert_close(&got, &want, &format!("tn {kk}x{m}x{n}"));
                }
            }
        }
    }
}

#[test]
fn attention_parity_odd_shapes_and_masks() {
    let mut rng = Rng::new(0x90_04);
    for &(b, nh, l, d) in &[(1, 1, 1, 1), (1, 2, 3, 5), (2, 3, 7, 4), (3, 1, 9, 8), (1, 1, 17, 3)]
    {
        let q = randv(&mut rng, b * nh * l * d);
        let kk = randv(&mut rng, b * nh * l * d);
        let v = randv(&mut rng, b * nh * l * d);
        // random partial masks; position 0 always kept
        let mut mask = vec![0.0f32; b * l];
        for bi in 0..b {
            for j in 1..l {
                if rng.chance(0.3) {
                    mask[bi * l + j] = -1e9;
                }
            }
        }
        let (wo, wp) = scalar::attention_fwd(&q, &kk, &v, &mask, b, nh, l, d);
        let dy = randv(&mut rng, b * nh * l * d);
        let (sdq, sdk, sdv) = scalar::attention_vjp(&dy, &q, &kk, &v, &wp, b, nh, l, d);
        for pool in pools() {
            let tag = format!("att {b}/{nh}/{l}/{d} t{}", pool.threads());
            let (o, p) = k::attention_fwd(&pool, &q, &kk, &v, &mask, b, nh, l, d);
            assert_close(&o, &wo, &format!("{tag} out"));
            assert_close(&p, &wp, &format!("{tag} probs"));
            // same probs into both VJPs isolates the backward comparison
            let (dq, dk, dv) = k::attention_vjp(&pool, &dy, &q, &kk, &v, &wp, b, nh, l, d);
            assert_close(&dq, &sdq, &format!("{tag} dq"));
            assert_close(&dk, &sdk, &format!("{tag} dk"));
            assert_close(&dv, &sdv, &format!("{tag} dv"));
        }
    }
}

#[test]
fn layernorm_and_hadamard_threads_agree_on_odd_row_counts() {
    let mut rng = Rng::new(0x90_05);
    for &(t, h) in &[(1, 4), (3, 7), (33, 5), (65, 9)] {
        let x = randv(&mut rng, t * h);
        let g = randv(&mut rng, h);
        let bias = randv(&mut rng, h);
        let (y1, c1) = k::layernorm_fwd(&Pool::serial(), &x, &g, &bias);
        let (y4, c4) = k::layernorm_fwd(&Pool::with_threads(4), &x, &g, &bias);
        assert_eq!(y1, y4, "ln fwd rows are order-independent ({t}x{h})");
        assert_eq!(c1.xhat, c4.xhat);
        assert_eq!(c1.inv, c4.inv);
        let dy = randv(&mut rng, t * h);
        let dx1 = k::layernorm_vjp(&Pool::serial(), &dy, &g, &c1, None, None);
        let dx4 = k::layernorm_vjp(&Pool::with_threads(4), &dy, &g, &c4, None, None);
        assert_eq!(dx1, dx4, "ln vjp dx ({t}x{h})");

        let w = randv(&mut rng, h);
        let w2 = randv(&mut rng, h);
        let w3 = randv(&mut rng, h);
        let a = k::hadamard_vjp(&Pool::serial(), &x, &w, Some(&w2), Some(&w3), &dy);
        let b = k::hadamard_vjp(&Pool::with_threads(4), &x, &w, Some(&w2), Some(&w3), &dy);
        assert_eq!(a.dx, b.dx, "hadamard dx ({t}x{h})");
        assert_close(&a.dw, &b.dw, "hadamard dw");
        assert_close(&a.db, &b.db, "hadamard db");
        assert_close(a.dw2.as_ref().unwrap(), b.dw2.as_ref().unwrap(), "hadamard dw2");
        assert_close(a.dw3.as_ref().unwrap(), b.dw3.as_ref().unwrap(), "hadamard dw3");
    }
}

#[test]
fn gelu_vec_parity_with_f64_reference() {
    let mut rng = Rng::new(0x90_06);
    let x = randv(&mut rng, 9001); // odd length: exercises the tail shard
    let want: Vec<f32> = x.iter().map(|&v| k::gelu(v)).collect();
    for pool in pools() {
        let got = k::gelu_vec(&pool, &x);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() <= 1e-5, "gelu[{i}]: {g} vs {w}");
        }
    }
    let dy = randv(&mut rng, 9001);
    let want: Vec<f32> = dy.iter().zip(&x).map(|(g, &v)| g * k::dgelu(v)).collect();
    for pool in pools() {
        let got = k::dgelu_mul(&pool, &dy, &x);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() <= 1e-4, "dgelu_mul[{i}]: {g} vs {w}");
        }
    }
}

#[test]
fn rows_equal_one_and_single_thread_match_many_threads() {
    // m = 1 exercises the no-tile remainder path end to end
    let mut rng = Rng::new(0x90_07);
    let (kk, n) = (130, 67);
    let a = randv(&mut rng, kk);
    let b = randv(&mut rng, kk * n);
    let want = scalar::matmul(&a, &b, 1, kk, n);
    for threads in [1, 2, 8] {
        let pool = Pool::with_threads(threads);
        assert_close(&k::matmul(&pool, &a, &b, 1, kk, n), &want, "nn m=1");
    }
}

// ------------------------------------------------- packed + fused kernels

#[test]
fn packed_nn_parity_across_odd_shapes() {
    let mut rng = Rng::new(0x90_10);
    for &m in &DIMS {
        for &kk in &DIMS {
            for &n in &DIMS {
                let a = randv(&mut rng, m * kk);
                let b = randv(&mut rng, kk * n);
                let want = scalar::matmul(&a, &b, m, kk, n);
                let pb = k::PackedMat::pack_nn(&b, kk, n);
                assert_eq!(pb.unpack(), b, "roundtrip {kk}x{n}");
                for pool in pools() {
                    let mut got = vec![-3.0f32; m * n];
                    k::gemm_fused_into(
                        &pool,
                        &a,
                        k::BMat::Packed(&pb),
                        &mut got,
                        m,
                        kk,
                        n,
                        k::Epilogue::none(),
                        None,
                    );
                    assert_close(&got, &want, &format!("packed nn {m}x{kk}x{n}"));
                }
            }
        }
    }
}

#[test]
fn packed_nt_parity_across_odd_shapes() {
    let mut rng = Rng::new(0x90_11);
    for &m in &DIMS {
        for &kk in &DIMS {
            for &n in &DIMS {
                let a = randv(&mut rng, m * kk);
                let bt = randv(&mut rng, n * kk);
                let want = scalar::matmul_nt(&a, &bt, m, kk, n);
                let pb = k::PackedMat::pack_nt(&bt, n, kk);
                for pool in pools() {
                    let mut got = vec![5.0f32; m * n];
                    k::matmul_nt_into(&pool, &a, k::NtMat::Packed(&pb), &mut got, m, kk, n, false);
                    assert_close(&got, &want, &format!("packed nt {m}x{kk}x{n}"));
                    // accumulate semantics on both operand forms
                    let init = randv(&mut rng, m * n);
                    let expect: Vec<f32> = init.iter().zip(&want).map(|(i, w)| i + w).collect();
                    let mut acc = init.clone();
                    k::matmul_nt_into(&pool, &a, k::NtMat::Packed(&pb), &mut acc, m, kk, n, true);
                    assert_close(&acc, &expect, &format!("packed nt acc {m}x{kk}x{n}"));
                    let mut acc = init.clone();
                    k::matmul_nt_into(&pool, &a, k::NtMat::Plain(&bt), &mut acc, m, kk, n, true);
                    assert_close(&acc, &expect, &format!("plain nt acc {m}x{kk}x{n}"));
                }
            }
        }
    }
}

#[test]
fn fused_bias_gelu_epilogue_matches_separate_kernels() {
    let mut rng = Rng::new(0x90_12);
    for &(m, kk, n) in &[(1, 3, 5), (7, 16, 9), (33, 20, 24)] {
        let a = randv(&mut rng, m * kk);
        let b = randv(&mut rng, kk * n);
        let bias = randv(&mut rng, n);
        let res = randv(&mut rng, m * n);
        // reference: separate GEMM, bias add, residual add, gelu
        let mut pre_want = scalar::matmul(&a, &b, m, kk, n);
        for (w, r) in pre_want.iter_mut().zip(&res) {
            *w = r + *w;
        }
        k::add_bias(&mut pre_want, &bias);
        let want: Vec<f32> = pre_want.iter().map(|&v| k::gelu(v)).collect();
        let pb = k::PackedMat::pack_nn(&b, kk, n);
        for pool in pools() {
            for bm in [k::BMat::Plain(&b), k::BMat::Packed(&pb)] {
                let mut got = vec![0.0f32; m * n];
                let mut pre = vec![0.0f32; m * n];
                let epi =
                    k::Epilogue { add1: Some(&res), bias: Some(&bias), add2: None, gelu: true };
                k::gemm_fused_into(&pool, &a, bm, &mut got, m, kk, n, epi, Some(&mut pre));
                assert_close(&got, &want, &format!("fused {m}x{kk}x{n}"));
                assert_close(&pre, &pre_want, &format!("pre tap {m}x{kk}x{n}"));
            }
        }
    }
}

#[test]
fn packed_kernels_thread_counts_agree() {
    let mut rng = Rng::new(0x90_13);
    let (m, kk, n) = (37, 49, 27);
    let a = randv(&mut rng, m * kk);
    let b = randv(&mut rng, kk * n);
    let pb = k::PackedMat::pack_nn(&b, kk, n);
    let mut c1 = vec![0.0f32; m * n];
    let mut c8 = vec![0.0f32; m * n];
    k::gemm_fused_into(
        &Pool::serial(),
        &a,
        k::BMat::Packed(&pb),
        &mut c1,
        m,
        kk,
        n,
        k::Epilogue::none(),
        None,
    );
    k::gemm_fused_into(
        &Pool::with_threads(8),
        &a,
        k::BMat::Packed(&pb),
        &mut c8,
        m,
        kk,
        n,
        k::Epilogue::none(),
        None,
    );
    assert_eq!(c1, c8, "row sharding must be thread-count independent");
}

#[test]
fn nan_propagates_through_packed_kernels() {
    let p = Pool::serial();
    let (m, kk, n) = (3, 4, 11); // n exercises a padded final panel
    let a = vec![0.0f32; m * kk];
    let mut b = vec![1.0f32; kk * n];
    b[2] = f32::NAN; // column 2, row 0 of B
    let pb = k::PackedMat::pack_nn(&b, kk, n);
    let mut c = vec![0.0f32; m * n];
    k::gemm_fused_into(&p, &a, k::BMat::Packed(&pb), &mut c, m, kk, n, k::Epilogue::none(), None);
    assert!(c[2].is_nan(), "0 * NaN must surface through packed NN");
    assert!(!c[3].is_nan(), "padding lanes must not leak NaN into real columns");
    let mut bt = vec![1.0f32; n * kk];
    bt[(n - 1) * kk] = f32::NAN; // last b^T row: the padded panel's real lane
    let pbt = k::PackedMat::pack_nt(&bt, n, kk);
    let mut c = vec![0.0f32; m * n];
    k::matmul_nt_into(&p, &a, k::NtMat::Packed(&pbt), &mut c, m, kk, n, false);
    assert!(c[n - 1].is_nan(), "packed NT must propagate NaN in the tail panel");
    assert!(!c[0].is_nan());
}

// ------------------------------------------------------- NaN regressions

#[test]
fn nan_propagates_where_scalar_reference_masked_it() {
    // The PR 1 `av == 0.0` skip silently dropped NaN columns (0 * NaN is
    // NaN in the JAX oracle). The blocked kernels must surface it.
    let p = Pool::serial();
    let m = 3;
    let kk = 4;
    let n = 2;
    let a = vec![0.0f32; m * kk];
    let mut b = vec![1.0f32; kk * n];
    b[0] = f32::NAN;
    let c = k::matmul(&p, &a, &b, m, kk, n);
    assert!(c.iter().any(|v| v.is_nan()), "blocked NN must propagate NaN");
    let c = scalar::matmul(&a, &b, m, kk, n);
    assert!(
        c.iter().all(|v| !v.is_nan()),
        "scalar reference documents the old masking behavior"
    );

    let bt = {
        let mut bt = vec![1.0f32; n * kk];
        bt[kk] = f32::NAN; // row 1 of b^T
        bt
    };
    let c = k::matmul_nt(&p, &a, &bt, m, kk, n);
    assert!(c[1].is_nan(), "blocked NT must propagate NaN");

    let mut out = vec![0.0f32; m * n];
    let at = vec![0.0f32; kk * m];
    let mut bb = vec![1.0f32; kk * n];
    bb[1] = f32::NAN; // column 1 of b, row 0
    k::matmul_tn_acc(&p, &at, &bb, &mut out, kk, m, n);
    assert!(out[1].is_nan(), "blocked TN must propagate NaN");
}

#[test]
fn nan_in_masked_attention_value_row_surfaces() {
    let p = Pool::with_threads(2);
    let (b, nh, l, d) = (1, 2, 4, 3);
    let q = vec![0.0f32; b * nh * l * d];
    let kk = vec![0.0f32; b * nh * l * d];
    let mut v = vec![1.0f32; b * nh * l * d];
    // poison the *masked* value row of head 0
    v[(l - 1) * d] = f32::NAN;
    let mut mask = vec![0.0f32; b * l];
    mask[l - 1] = -1e9;
    let (out, probs) = k::attention_fwd(&p, &q, &kk, &v, &mask, b, nh, l, d);
    assert_eq!(probs[l - 1], 0.0, "masked prob must underflow to exactly 0");
    assert!(
        out[0].is_nan(),
        "0.0 * NaN must poison attention output (JAX parity), got {}",
        out[0]
    );
}
