//! Shared test-side HTTP client, included into integration-test crates
//! via `#[path = "common/wire_client.rs"] mod wire_client;`.
//!
//! Deliberately simple and allocating — it sits on the *client* side of
//! the socket, so test-harness allocations never pollute the server's
//! zero-alloc accounting (the alloc-tracking client in
//! `workspace_alloc.rs` is its own, stricter implementation).
#![allow(dead_code)] // each including crate uses a subset

use std::io::{Read as _, Write as _};
use std::net::{Shutdown, SocketAddr, TcpStream};

/// One parsed HTTP response.
pub struct Response {
    pub status: u16,
    pub head: String,
    pub body: String,
}

/// Raw `POST /infer` bytes for a JSON body (exact Content-Length).
pub fn post_infer(body: &str) -> Vec<u8> {
    format!(
        "POST /infer HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

/// Raw `/infer` request for a task + token ids (text_b optional).
pub fn infer_req(task: &str, seq_a: &[i32], seq_b: Option<&[i32]>) -> Vec<u8> {
    let mut body = format!("{{\"task\":\"{task}\",\"text_a\":{}", fmt_ids(seq_a));
    if let Some(b) = seq_b {
        body.push_str(&format!(",\"text_b\":{}", fmt_ids(b)));
    }
    body.push('}');
    post_infer(&body)
}

fn fmt_ids(ids: &[i32]) -> String {
    let inner = ids.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(",");
    format!("[{inner}]")
}

/// Raw bodyless GET request bytes.
pub fn get(path: &str) -> Vec<u8> {
    format!("GET {path} HTTP/1.1\r\n\r\n").into_bytes()
}

/// Raw bodyless POST request bytes.
pub fn post(path: &str) -> Vec<u8> {
    format!("POST {path} HTTP/1.1\r\nContent-Length: 0\r\n\r\n").into_bytes()
}

/// Open a fresh connection, send `req` (optionally half-closing the
/// write side, the convention for `truncated-*` fixtures), and read
/// exactly `nresp` responses.
pub fn send_and_read(
    addr: SocketAddr,
    req: &[u8],
    nresp: usize,
    half_close: bool,
) -> Vec<Response> {
    let mut s = TcpStream::connect(addr).expect("connect to wire server");
    s.write_all(req).unwrap();
    if half_close {
        s.shutdown(Shutdown::Write).unwrap();
    }
    read_responses(&mut s, nresp)
}

/// Read exactly `n` Content-Length-framed responses off `stream`.
pub fn read_responses(stream: &mut TcpStream, n: usize) -> Vec<Response> {
    let mut buf: Vec<u8> = Vec::new();
    let mut out = Vec::new();
    let mut chunk = [0u8; 8192];
    while out.len() < n {
        loop {
            let Some(head_end) = find(&buf, b"\r\n\r\n") else { break };
            let head = String::from_utf8(buf[..head_end].to_vec()).unwrap();
            let cl = content_length(&head);
            let total = head_end + 4 + cl;
            if buf.len() < total {
                break;
            }
            let body = String::from_utf8_lossy(&buf[head_end + 4..total]).to_string();
            let status: u16 = head
                .split_whitespace()
                .nth(1)
                .expect("status code in response line")
                .parse()
                .unwrap();
            out.push(Response { status, head, body });
            buf.drain(..total);
            if out.len() == n {
                return out;
            }
        }
        let nr = stream.read(&mut chunk).unwrap();
        assert!(
            nr > 0,
            "eof after {} of {n} responses; partial: {:?}",
            out.len(),
            String::from_utf8_lossy(&buf)
        );
        buf.extend_from_slice(&chunk[..nr]);
    }
    out
}

fn content_length(head: &str) -> usize {
    head.lines()
        .find_map(|l| {
            let (name, value) = l.split_once(':')?;
            name.eq_ignore_ascii_case("content-length")
                .then(|| value.trim().parse().unwrap())
        })
        .unwrap_or(0)
}

fn find(hay: &[u8], needle: &[u8]) -> Option<usize> {
    hay.windows(needle.len()).position(|w| w == needle)
}
