//! Runtime microbenchmarks: the PR 1 scalar kernels vs blocked vs
//! blocked+parallel vs packed+fused (PR 3), at every model size — forward
//! latency, the hadamard train step (the paper's hot path) with workspace
//! arena counters, warmup and upload overhead, plus GEMM microbenchmarks
//! (including packed panels and the fused bias+GELU epilogue) at
//! tiny/base/large shapes.
//!
//! PR 4 adds dispatch-latency honesty: the `pool` section measures the
//! empty-job round trip on the persistent parked-worker pool against a
//! reconstruction of PR 2's per-call `thread::scope` spawning, `matmul`
//! rows carry `scoped_ms`/`persistent_ms` for the same blocked kernel
//! under both dispatch disciplines, and `train_step` rows record the
//! pool's steady-state spawn (must be 0) and job counters next to the
//! arena counters.
//!
//! PR 5 adds the `serve` section: the multi-tenant forward-only serve
//! path (one packed backbone, per-task Hadamard adapter banks, cross-task
//! micro-batching) measured as requests/sec and p50/p99 latency at batch
//! sizes 1/8/32, plus the adapter-swap-vs-full-reupload cost comparison
//! and the serve-side zero-contract counters (steady arena misses, pool
//! spawns and repacks all pinned at 0).
//!
//! PR 6 adds the `ingress` section: the wire front door measured end to
//! end — nanoseconds for the pull parser to decode a request body
//! straight into the resident scratch, then socket-to-logits
//! requests/sec and p50/p99 latency through a real [`WireServer`]
//! (`serve-http`'s engine) at wave sizes 1/8/32, with the serve
//! zero-contract counters read back over the wire from `/stats`.
//!
//! PR 7 adds the `bank` section: the tiered adapter bank — a
//! Zipf-clustered synthetic fleet delta-encoded into the on-disk bank
//! format (compression ratio vs dense per-tenant storage), the
//! cold-fault path (page + reconstruct one tenant, p50/p99
//! microseconds), the hot-hit rate of a Zipf traffic replay through a
//! tiered [`ServeSession`], and the hot-resident steady state proven
//! allocation-free by this binary's own counting allocator.
//!
//! PR 8 adds the `overload` section: the front door deliberately offered
//! several times its admitted capacity (deep Zipf-skewed pipelined
//! bursts against a bounded queue and per-tenant token buckets), with
//! SLO-honest reporting — latency percentiles over admitted replies
//! only, goodput vs offered load, typed 429/503 counts, and an asserted
//! zero unclassified errors.
//!
//! PR 10 adds the `ingress_mc` section: the multi-connection front door
//! — eight persistent connections multiplexed into the single serve
//! thread, per-request (timestamped per socket, admitted-only) latency
//! percentiles, the accept-tier counters, the number of waves that
//! mixed rows from different connections, and the multi-connection
//! zero-alloc contract re-asserted through its observable proxies.
//!
//! Results are also recorded to `BENCH_kernels.json` at the repo root so
//! kernel-perf trajectory survives in-tree. Pass `--quick` for a short
//! smoke run (CI uses this; only the tiny model, few iterations). The
//! schema is documented in `docs/BENCH_SCHEMA.md`.
//!
//! To benchmark the PJRT path instead, build with `--features xla` and
//! swap the engine constructors for `Engine::xla("artifacts")` against a
//! real artifacts directory.

use hadapt::data::{class_mask, generate, make_batch, task_info};
use hadapt::model::{FreezeMask, ParamStore};
use hadapt::optim::LrSchedule;
use hadapt::runtime::kernels::{self as k, scalar};
use hadapt::runtime::{
    spawn_synthetic_server, synthetic_adapters, synthetic_tenant, BankBuilder, BankGeometry,
    BankReader, DeviceTensor, Engine, IntTensor, Manifest, NativeBackend, Pool, RequestScratch,
    ServeRequest, ServeSession, SpawnOpts, TaskAdapter, Tensor, WireLimits,
};
use hadapt::train::Session;
use hadapt::util::bench::{report_throughput, Bench};
use hadapt::util::json::Json;
use hadapt::util::Rng;

/// Counts heap allocations while `TRACKING` is set, so the bank rows'
/// `steady_hot_allocs` figure is a measurement from this very process,
/// not a replay of the workspace_alloc test's verdict. Pass-through to
/// the system allocator; counting is off outside the tracked window.
struct CountingAlloc;

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

static TRACKING: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if TRACKING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if TRACKING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn engine_with(pool: Pool, packing: bool) -> Engine {
    Engine::with_backend(
        Manifest::builtin("artifacts"),
        Box::new(NativeBackend::with_pool(pool).packing(packing)),
    )
}

fn ms(j: &mut Json, key: &str, v: f64) {
    j.set(key, Json::num((v * 1000.0).round() / 1000.0));
}

/// PR 2's dispatch discipline, reconstructed for the bench: shard the
/// blocked NN GEMM over row chunks with per-call scoped spawns (the
/// kernel math is identical to `k::matmul_into` on a serial pool — only
/// the fork-join mechanism differs, which is exactly what the
/// `scoped_ms` / `persistent_ms` comparison isolates).
#[allow(clippy::too_many_arguments)]
fn scoped_matmul(
    threads: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k_: usize,
    n: usize,
) {
    let serial = Pool::serial();
    let shards = threads.min(m.max(1)).max(1);
    let chunk = (m + shards - 1) / shards;
    std::thread::scope(|s| {
        let mut rest = &mut c[..];
        let mut row0 = 0usize;
        let mut parts = Vec::new();
        while !rest.is_empty() {
            let take = (chunk * n).min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            parts.push((row0, head));
            row0 += take / n;
            rest = tail;
        }
        let nch = parts.len();
        let serial = &serial;
        for (i, (r0, cc)) in parts.into_iter().enumerate() {
            let rows = cc.len() / n;
            let aslice = &a[r0 * k_..(r0 + rows) * k_];
            if i + 1 == nch {
                k::matmul_into(serial, aslice, b, cc, rows, k_, n);
            } else {
                s.spawn(move || k::matmul_into(serial, aslice, b, cc, rows, k_, n));
            }
        }
    });
}

// ---- minimal HTTP client for the ingress rows (bench-side, allocating) ----

fn wire_body(task: &str, seq_a: &[i32], seq_b: Option<&[i32]>) -> String {
    let ids = |v: &[i32]| v.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(",");
    match seq_b {
        Some(sb) => format!(
            "{{\"task\":\"{task}\",\"text_a\":[{}],\"text_b\":[{}]}}",
            ids(seq_a),
            ids(sb)
        ),
        None => format!("{{\"task\":\"{task}\",\"text_a\":[{}]}}", ids(seq_a)),
    }
}

fn wire_post(path: &str, body: &str) -> Vec<u8> {
    format!("POST {path} HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}", body.len()).into_bytes()
}

/// Read `n` Content-Length-framed responses off `s`, returning bodies.
fn wire_read(s: &mut std::net::TcpStream, n: usize) -> Vec<String> {
    use std::io::Read as _;
    let mut buf: Vec<u8> = Vec::new();
    let mut out = Vec::new();
    let mut chunk = [0u8; 8192];
    while out.len() < n {
        loop {
            let Some(he) = buf.windows(4).position(|w| w == b"\r\n\r\n") else { break };
            let head = String::from_utf8_lossy(&buf[..he]).to_string();
            let cl: usize = head
                .lines()
                .find_map(|l| {
                    let (k, v) = l.split_once(':')?;
                    k.eq_ignore_ascii_case("content-length").then(|| v.trim().parse().unwrap())
                })
                .unwrap_or(0);
            let total = he + 4 + cl;
            if buf.len() < total {
                break;
            }
            out.push(String::from_utf8_lossy(&buf[he + 4..total]).to_string());
            buf.drain(..total);
            if out.len() == n {
                return out;
            }
        }
        let r = s.read(&mut chunk).unwrap();
        assert!(r > 0, "wire bench: server closed early");
        buf.extend_from_slice(&chunk[..r]);
    }
    out
}

/// Read `n` framed responses off `s`, returning each status code (the
/// overload rows classify 200/429/503 rather than reading bodies).
fn wire_read_statuses(s: &mut std::net::TcpStream, n: usize) -> Vec<u16> {
    use std::io::Read as _;
    let mut buf: Vec<u8> = Vec::new();
    let mut out = Vec::new();
    let mut chunk = [0u8; 8192];
    while out.len() < n {
        loop {
            let Some(he) = buf.windows(4).position(|w| w == b"\r\n\r\n") else { break };
            let head = String::from_utf8_lossy(&buf[..he]).to_string();
            let status: u16 = head.split_whitespace().nth(1).unwrap().parse().unwrap();
            let cl: usize = head
                .lines()
                .find_map(|l| {
                    let (k, v) = l.split_once(':')?;
                    k.eq_ignore_ascii_case("content-length").then(|| v.trim().parse().unwrap())
                })
                .unwrap_or(0);
            let total = he + 4 + cl;
            if buf.len() < total {
                break;
            }
            out.push(status);
            buf.drain(..total);
            if out.len() == n {
                return out;
            }
        }
        let r = s.read(&mut chunk).unwrap();
        assert!(r > 0, "wire bench: server closed early");
        buf.extend_from_slice(&chunk[..r]);
    }
    out
}

/// `/stats` over an open connection: (arena misses, pool spawns, repacks).
fn wire_counters(s: &mut std::net::TcpStream) -> (u64, u64, u64) {
    use std::io::Write as _;
    s.write_all(b"GET /stats HTTP/1.1\r\n\r\n").unwrap();
    let body = wire_read(s, 1).pop().unwrap();
    let v = hadapt::util::json::parse(&body).unwrap();
    let n = |k: &str| v.get(k).unwrap().as_usize().unwrap() as u64;
    (n("arena_misses"), n("pool_threads_spawned"), n("repacks"))
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick { Bench::new(1, 3) } else { Bench::default() };
    let models: &[&str] = if quick { &["tiny"] } else { &["tiny", "base", "large"] };
    let threads = Pool::auto().threads();
    println!(
        "backend: native — scalar (PR 1) vs blocked vs parallel vs packed+fused \
         ({threads} threads)"
    );

    // engine per kernel mode; identical manifest + weights, only kernels
    // differ. "packed" = parallel + frozen-weight panels + fused epilogues.
    let modes: [(&str, Engine); 4] = [
        ("scalar", engine_with(Pool::scalar_reference(), false)),
        ("blocked", engine_with(Pool::serial(), false)),
        ("parallel", engine_with(Pool::auto(), false)),
        ("packed", engine_with(Pool::auto(), true)),
    ];
    let batch = modes[0].1.manifest().batch;
    let seq = modes[0].1.manifest().seq_len;

    let mut fwd_json = Json::obj();
    let mut step_json = Json::obj();

    for model in models {
        let info = modes[0].1.manifest().model(model).unwrap().clone();
        let store = ParamStore::init(&info, 7);

        // warmup (compile on XLA; manifest validation natively)
        let t0 = std::time::Instant::now();
        modes[3].1.warmup(&Manifest::fwd_name(model)).unwrap();
        println!(
            "bench {:<44} once={:>10.3?}",
            format!("warmup/fwd_{model}"),
            t0.elapsed()
        );

        let ds = generate(task_info("sst2").unwrap(), 1, "dev", batch);
        let idx: Vec<usize> = (0..batch).collect();
        let bt = make_batch(&ds, &idx, batch, seq);

        // resident-parameter forward (the Session/eval hot path) per mode
        let mut mode_ms = Vec::new();
        for (tag, engine) in &modes {
            let param_bufs: Vec<DeviceTensor> = store
                .tensors
                .iter()
                .map(|t| engine.upload(t).unwrap())
                .collect();
            let tok = engine
                .upload_int_owned(IntTensor::new(vec![batch, seq], bt.tokens.clone()).unwrap())
                .unwrap();
            let typ = engine
                .upload_int_owned(IntTensor::new(vec![batch, seq], bt.type_ids.clone()).unwrap())
                .unwrap();
            let msk = engine
                .upload_owned(Tensor::new(vec![batch, seq], bt.attn_mask.clone()).unwrap())
                .unwrap();
            let s = b.run(&format!("fwd_exec/{model}/{tag}"), || {
                let mut refs: Vec<&DeviceTensor> = param_bufs.iter().collect();
                refs.push(&tok);
                refs.push(&typ);
                refs.push(&msk);
                engine.run(&Manifest::fwd_name(model), &refs).unwrap()
            });
            report_throughput(&format!("fwd_exec/{model}/{tag} (seqs)"), batch as f64, &s);
            mode_ms.push(s.mean_ms());
        }
        let (sc, bl, pa, pk) = (mode_ms[0], mode_ms[1], mode_ms[2], mode_ms[3]);
        println!(
            "bench {:<44} blocked={:.2}x parallel={:.2}x packed={:.2}x \
             packed_vs_parallel={:.2}x",
            format!("fwd_speedup/{model}"),
            sc / bl,
            sc / pa,
            sc / pk,
            pa / pk
        );
        let mut mj = Json::obj();
        ms(&mut mj, "scalar_ms", sc);
        ms(&mut mj, "blocked_ms", bl);
        ms(&mut mj, "parallel_ms", pa);
        ms(&mut mj, "packed_ms", pk);
        ms(&mut mj, "speedup_blocked", sc / bl);
        ms(&mut mj, "speedup_parallel", sc / pa);
        ms(&mut mj, "speedup_packed", sc / pk);
        ms(&mut mj, "packed_vs_parallel", pa / pk);
        fwd_json.set(model, mj);

        // train step (hadamard group, the paper's hot path): scalar vs
        // parallel vs packed, with workspace-arena counters on the packed
        // run proving the steady state stops allocating.
        let mask = FreezeMask::from_names(&info, &info.group("hadamard").unwrap().to_vec());
        let cm = class_mask(2);
        let mut step_ms = Vec::new();
        let mut arena = (0u64, 0u64, 0u64);
        let mut pool_steady = (0u64, 0.0f64);
        for (tag, engine) in
            [("scalar", &modes[0].1), ("parallel", &modes[2].1), ("packed", &modes[3].1)]
        {
            let mut session = Session::new(
                engine,
                &Manifest::train_name("cls", "hadamard", model),
                store.clone(),
                mask.clone(),
                LrSchedule::constant(1e-3),
            )
            .unwrap();
            let s = b.run(&format!("train_step/hadamard/{model}/{tag}"), || {
                session.step_cls(&bt, &cm).unwrap()
            });
            report_throughput(
                &format!("train_step/hadamard/{model}/{tag} (seqs)"),
                batch as f64,
                &s,
            );
            step_ms.push(s.mean_ms());
            if tag == "packed" {
                let (h0, m0) = engine.arena_stats();
                let p0 = engine.pool_stats();
                session.step_cls(&bt, &cm).unwrap();
                session.step_cls(&bt, &cm).unwrap();
                let (h1, m1) = engine.arena_stats();
                let p1 = engine.pool_stats();
                arena = (h1 - h0, m1 - m0, engine.pack_stats().0);
                pool_steady = (
                    p1.threads_spawned - p0.threads_spawned,
                    (p1.jobs_dispatched - p0.jobs_dispatched) as f64 / 2.0,
                );
                println!(
                    "bench {:<44} hits={} misses={} packed_weights={} \
                     pool_spawns={} pool_jobs_per_step={:.1}",
                    format!("train_step_arena/{model} (2 steady steps)"),
                    arena.0,
                    arena.1,
                    arena.2,
                    pool_steady.0,
                    pool_steady.1
                );
            }
        }
        println!(
            "bench {:<44} parallel={:.2}x packed={:.2}x (vs PR 1 scalar)",
            format!("train_step_speedup/{model}"),
            step_ms[0] / step_ms[1],
            step_ms[0] / step_ms[2]
        );
        let mut sj = Json::obj();
        ms(&mut sj, "scalar_ms", step_ms[0]);
        ms(&mut sj, "parallel_ms", step_ms[1]);
        ms(&mut sj, "packed_ms", step_ms[2]);
        ms(&mut sj, "speedup_parallel", step_ms[0] / step_ms[1]);
        ms(&mut sj, "speedup_packed", step_ms[0] / step_ms[2]);
        ms(&mut sj, "packed_vs_parallel", step_ms[1] / step_ms[2]);
        sj.set("arena_steady_hits", Json::num(arena.0 as f64));
        sj.set("arena_steady_misses", Json::num(arena.1 as f64));
        sj.set("packed_weights", Json::num(arena.2 as f64));
        sj.set("pool_steady_spawns", Json::num(pool_steady.0 as f64));
        sj.set("pool_steady_jobs", Json::num((pool_steady.1 * 10.0).round() / 10.0));
        step_json.set(model, sj);

        // upload overhead (largest tensor) on the packed engine
        let biggest = store
            .tensors
            .iter()
            .max_by_key(|t| t.numel())
            .unwrap()
            .clone();
        let bytes = biggest.numel() * 4;
        let s = b.run(&format!("upload/{model}/largest_tensor"), || {
            modes[3].1.upload(&biggest).unwrap()
        });
        report_throughput(&format!("upload/{model} (MB)"), bytes as f64 / 1e6, &s);
    }

    // GEMM microbenchmarks at forward-pass shapes: [T, H] x [H, F], plus
    // the packed panels and the fused bias+GELU epilogue against the
    // equivalent separate-kernel sequence.
    let mut mm_json = Json::obj();
    let shapes: &[(&str, usize, usize, usize)] = if quick {
        &[("tiny_t512_h64_f128", 512, 64, 128)]
    } else {
        &[
            ("tiny_t512_h64_f128", 512, 64, 128),
            ("base_t512_h128_f512", 512, 128, 512),
            ("large_t512_h192_f768", 512, 192, 768),
        ]
    };
    let mut rng = Rng::new(99);
    for &(tag, m, kk, n) in shapes {
        let a: Vec<f32> = (0..m * kk).map(|_| rng.normal()).collect();
        let bb: Vec<f32> = (0..kk * n).map(|_| rng.normal()).collect();
        let bias: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let s_sc = b.run(&format!("matmul/{tag}/scalar"), || scalar::matmul(&a, &bb, m, kk, n));
        let p1 = Pool::serial();
        let s_bl = b.run(&format!("matmul/{tag}/blocked"), || k::matmul(&p1, &a, &bb, m, kk, n));
        let pn = Pool::auto();
        let s_pa = b.run(&format!("matmul/{tag}/parallel"), || k::matmul(&pn, &a, &bb, m, kk, n));
        // same blocked kernel under both dispatch disciplines: per-call
        // scoped spawns (PR 2) vs the persistent parked workers. Both
        // sides write into a preallocated buffer via matmul_into, so the
        // two columns differ ONLY in the fork-join mechanism.
        let mut c_sc = vec![0.0f32; m * n];
        let s_sco = b.run(&format!("matmul/{tag}/scoped_dispatch"), || {
            scoped_matmul(threads, &a, &bb, &mut c_sc, m, kk, n)
        });
        let mut c_pe = vec![0.0f32; m * n];
        let s_pe = b.run(&format!("matmul/{tag}/persistent_dispatch"), || {
            k::matmul_into(&pn, &a, &bb, &mut c_pe, m, kk, n)
        });
        let t_pack = std::time::Instant::now();
        let pb = k::PackedMat::pack_nn(&bb, kk, n);
        let pack_once_ms = t_pack.elapsed().as_secs_f64() * 1e3;
        let mut c = vec![0.0f32; m * n];
        let s_pk = b.run(&format!("matmul/{tag}/packed"), || {
            let epi = k::Epilogue::none();
            k::gemm_fused_into(&pn, &a, k::BMat::Packed(&pb), &mut c, m, kk, n, epi, None)
        });
        // fused bias+gelu in the GEMM pass vs the separate-kernel sequence
        let s_sep = b.run(&format!("matmul/{tag}/bias_gelu_separate"), || {
            let mut u = k::matmul(&pn, &a, &bb, m, kk, n);
            k::add_bias(&mut u, &bias);
            k::gelu_vec(&pn, &u)
        });
        let s_fu = b.run(&format!("matmul/{tag}/bias_gelu_fused"), || {
            k::gemm_fused_into(
                &pn,
                &a,
                k::BMat::Packed(&pb),
                &mut c,
                m,
                kk,
                n,
                k::Epilogue::bias_gelu(&bias),
                None,
            )
        });
        println!(
            "bench {:<44} blocked={:.2}x parallel={:.2}x packed={:.2}x fused={:.2}x \
             dispatch={:.2}x (pack once: {:.3}ms)",
            format!("matmul_speedup/{tag}"),
            s_sc.mean_ms() / s_bl.mean_ms(),
            s_sc.mean_ms() / s_pa.mean_ms(),
            s_sc.mean_ms() / s_pk.mean_ms(),
            s_sep.mean_ms() / s_fu.mean_ms(),
            s_sco.mean_ms() / s_pe.mean_ms(),
            pack_once_ms
        );
        let mut mj = Json::obj();
        ms(&mut mj, "scalar_ms", s_sc.mean_ms());
        ms(&mut mj, "blocked_ms", s_bl.mean_ms());
        ms(&mut mj, "parallel_ms", s_pa.mean_ms());
        ms(&mut mj, "scoped_ms", s_sco.mean_ms());
        ms(&mut mj, "persistent_ms", s_pe.mean_ms());
        ms(&mut mj, "packed_ms", s_pk.mean_ms());
        ms(&mut mj, "pack_once_ms", pack_once_ms);
        ms(&mut mj, "bias_gelu_separate_ms", s_sep.mean_ms());
        ms(&mut mj, "bias_gelu_fused_ms", s_fu.mean_ms());
        ms(&mut mj, "speedup_blocked", s_sc.mean_ms() / s_bl.mean_ms());
        ms(&mut mj, "speedup_parallel", s_sc.mean_ms() / s_pa.mean_ms());
        ms(&mut mj, "speedup_packed", s_sc.mean_ms() / s_pk.mean_ms());
        ms(&mut mj, "fused_vs_separate", s_sep.mean_ms() / s_fu.mean_ms());
        ms(&mut mj, "dispatch_speedup", s_sco.mean_ms() / s_pe.mean_ms());
        mm_json.set(tag, mj);
    }

    // Dispatch-latency micro-rows: what one fork-join costs on the
    // persistent pool (publish, condvar wake, latch) vs PR 2's per-call
    // scoped spawn/join of threads-1 OS threads, at zero kernel work —
    // plus spawn accounting across real train steps.
    let mut pool_json = Json::obj();
    {
        let pp = Pool::with_threads(threads.max(2));
        let rows = pp.threads();
        let mut out = vec![0.0f32; rows];
        // warm: first dispatch spawns the persistent workers
        pp.for_rows(&mut out, 1, 1, |_, c| {
            std::hint::black_box(c);
        });
        let s_per = b.run("pool/empty_job/persistent", || {
            pp.for_rows(&mut out, 1, 1, |_, c| {
                std::hint::black_box(c);
            })
        });
        let s_sco = b.run("pool/empty_job/scoped", || {
            std::thread::scope(|s| {
                for _ in 0..rows - 1 {
                    s.spawn(|| std::hint::black_box(0u32));
                }
            })
        });
        let per_ns = s_per.mean_ms() * 1e6;
        let sco_ns = s_sco.mean_ms() * 1e6;

        // spawn accounting on a fresh packed engine: the first tiny train
        // step spawns the workers; subsequent steps spawn nothing.
        let engine = engine_with(Pool::auto(), true);
        let info = engine.manifest().model("tiny").unwrap().clone();
        let store = ParamStore::init(&info, 7);
        let mask = FreezeMask::from_names(&info, &info.group("hadamard").unwrap().to_vec());
        let ds = generate(task_info("sst2").unwrap(), 1, "dev", batch);
        let idx: Vec<usize> = (0..batch).collect();
        let bt = make_batch(&ds, &idx, batch, seq);
        let cm = class_mask(2);
        let mut session = Session::new(
            &engine,
            &Manifest::train_name("cls", "hadamard", "tiny"),
            store,
            mask,
            LrSchedule::constant(1e-3),
        )
        .unwrap();
        session.step_cls(&bt, &cm).unwrap();
        let p0 = engine.pool_stats();
        let steady_steps = 4usize;
        for _ in 0..steady_steps {
            session.step_cls(&bt, &cm).unwrap();
        }
        let p1 = engine.pool_stats();
        let jobs_per_step =
            (p1.jobs_dispatched - p0.jobs_dispatched) as f64 / steady_steps as f64;
        let wakeups_per_step = (p1.wakeups - p0.wakeups) as f64 / steady_steps as f64;
        let steady_spawns = p1.threads_spawned - p0.threads_spawned;
        // what PR 2 paid for the same steps: one spawn per non-final
        // chunk of every dispatched job, i.e. up to threads-1 per job.
        let scoped_est = jobs_per_step * (threads.saturating_sub(1)) as f64;
        println!(
            "bench {:<44} dispatch_ns={per_ns:.0} scoped_ns={sco_ns:.0} \
             jobs/step={jobs_per_step:.1} steady_spawns={steady_spawns} \
             scoped_spawns/step(est)={scoped_est:.0}",
            "pool/steady_train (tiny)"
        );
        let r1 = |v: f64| (v * 10.0).round() / 10.0;
        pool_json.set("provenance", Json::str("measured"));
        pool_json.set("threads", Json::num(pp.threads() as f64));
        pool_json.set("empty_job_persistent_ns", Json::num(per_ns.round()));
        pool_json.set("empty_job_scoped_ns", Json::num(sco_ns.round()));
        pool_json.set("dispatch_ns", Json::num(per_ns.round()));
        pool_json.set("dispatch_speedup", Json::num(r1(sco_ns / per_ns.max(1.0))));
        pool_json.set("jobs_per_step", Json::num(r1(jobs_per_step)));
        pool_json.set("wakeups_per_step", Json::num(r1(wakeups_per_step)));
        pool_json.set("spawns_steady_per_step", Json::num(steady_spawns as f64));
        pool_json.set("scoped_spawns_per_step_est", Json::num(scoped_est.round()));
        pool_json.set("pool_spawns", Json::num(p1.threads_spawned as f64));
    }

    // Serve-path rows (PR 5): multi-tenant forward-only serving on one
    // packed backbone — requests/sec and latency percentiles at
    // micro-batch sizes 1/8/32, the adapter-economics comparison (hot
    // bank swap vs re-uploading the backbone), and the steady-state
    // zero-contract counters (arena misses, pool spawns, repacks — all
    // must stay 0 once a session is warm).
    let mut serve_json = Json::obj();
    {
        let engine = engine_with(Pool::auto(), true);
        let smodel = if quick { "tiny" } else { "base" };
        let info = engine.manifest().model(smodel).unwrap().clone();
        let store = ParamStore::init(&info, 7);
        let serve_tasks = ["sst2", "mrpc", "rte"];
        let adapters: Vec<TaskAdapter> = serve_tasks
            .iter()
            .enumerate()
            .map(|(ti, t)| {
                let classes = task_info(t).unwrap().classes;
                let mut a = TaskAdapter::from_store(&info, &store, t, classes).unwrap();
                let mut rng = Rng::new(100 + ti as u64);
                for li in 0..a.had_w.len() {
                    for v in a.had_w[li].iter_mut() {
                        *v += 0.02 * rng.normal();
                    }
                    for v in a.had_b[li].iter_mut() {
                        *v += 0.02 * rng.normal();
                    }
                }
                a
            })
            .collect();
        let streams: Vec<_> = serve_tasks
            .iter()
            .map(|t| generate(task_info(t).unwrap(), 5, "dev", 32))
            .collect();
        let reqs: Vec<ServeRequest> = (0..96)
            .map(|i| {
                let ds = &streams[i % streams.len()];
                let e = &ds.examples[i % ds.examples.len()];
                ServeRequest {
                    task: serve_tasks[i % serve_tasks.len()].to_string(),
                    seq_a: e.seq_a.clone(),
                    seq_b: e.seq_b.clone(),
                }
            })
            .collect();

        let mut rows = Json::obj();
        let (mut steady_misses, mut steady_spawns, mut steady_repacks) = (0u64, 0u64, 0u64);
        for &bsz in &[1usize, 8, 32] {
            let mut session = ServeSession::new(&engine, smodel, &store, bsz).unwrap();
            for a in &adapters {
                session.register_task(a.clone()).unwrap();
            }
            // warm-up: arena fills, workers spawn, this session's fresh
            // uploads pack once — everything after must be steady
            session.submit(reqs[0].clone()).unwrap();
            session.run_pending().unwrap();
            let (_, m0) = engine.arena_stats();
            let p0 = engine.pool_stats();
            let (_, rp0) = engine.pack_stats();
            let waves = if quick { 4 } else { 16 };
            let mut lats: Vec<f64> = Vec::new();
            let t0 = std::time::Instant::now();
            for w in 0..waves {
                for i in 0..bsz {
                    session
                        .submit(reqs[(w * bsz + i) % reqs.len()].clone())
                        .unwrap();
                }
                for reply in session.run_pending().unwrap() {
                    lats.push(reply.latency_s);
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            let (_, m1) = engine.arena_stats();
            let p1 = engine.pool_stats();
            let (_, rp1) = engine.pack_stats();
            steady_misses += m1 - m0;
            steady_spawns += p1.threads_spawned - p0.threads_spawned;
            steady_repacks += rp1 - rp0;
            lats.sort_by(|a, c| a.total_cmp(c));
            let p50 = lats[lats.len() / 2] * 1e3;
            let p99 = lats[(lats.len() * 99 / 100).min(lats.len() - 1)] * 1e3;
            let rps = lats.len() as f64 / wall.max(1e-9);
            println!(
                "bench {:<44} req/s={rps:.0} p50={p50:.3}ms p99={p99:.3}ms",
                format!("serve/{smodel}/b{bsz} ({} tasks mixed)", serve_tasks.len())
            );
            let mut rj = Json::obj();
            rj.set("batch", Json::num(bsz as f64));
            ms(&mut rj, "p50_ms", p50);
            ms(&mut rj, "p99_ms", p99);
            rj.set("req_per_s", Json::num(rps.round()));
            rows.set(&format!("b{bsz}"), rj);
        }

        // adapter economics: hot-swapping a task's bank entry (vector
        // copies) vs re-uploading the whole backbone (what task switching
        // would cost without the bank)
        let mut session = ServeSession::new(&engine, smodel, &store, 8).unwrap();
        for a in &adapters {
            session.register_task(a.clone()).unwrap();
        }
        let swap = adapters[0].clone();
        let s_swap = b.run("serve/adapter_swap", || {
            session.register_task(swap.clone()).unwrap()
        });
        let s_up = b.run("serve/full_reupload", || {
            store
                .tensors
                .iter()
                .map(|t| engine.upload(t).unwrap())
                .count()
        });
        let swap_us = s_swap.mean_ms() * 1e3;
        let reupload_ms = s_up.mean_ms();
        println!(
            "bench {:<44} swap={swap_us:.2}us reupload={reupload_ms:.3}ms \
             ratio={:.0}x ({} adapter scalars/task)",
            format!("serve_swap/{smodel}"),
            (reupload_ms * 1e3) / swap_us.max(1e-9),
            adapters[0].scalars()
        );
        serve_json.set("provenance", Json::str("measured"));
        serve_json.set("model", Json::str(smodel));
        serve_json.set("tasks", Json::num(serve_tasks.len() as f64));
        serve_json.set(
            "adapter_scalars_per_task",
            Json::num(adapters[0].scalars() as f64),
        );
        ms(&mut serve_json, "adapter_swap_us", swap_us);
        ms(&mut serve_json, "full_reupload_ms", reupload_ms);
        serve_json.set(
            "swap_vs_reupload",
            Json::num(((reupload_ms * 1e3) / swap_us.max(1e-9)).round()),
        );
        serve_json.set("steady_arena_misses", Json::num(steady_misses as f64));
        serve_json.set("steady_pool_spawns", Json::num(steady_spawns as f64));
        serve_json.set("steady_repacks", Json::num(steady_repacks as f64));
        serve_json.set("rows", rows);
    }

    // Ingress rows (PR 6): the socket front door. First the pull parser
    // alone — nanoseconds to decode a request body straight into the
    // resident scratch — then socket-to-logits throughput and latency
    // through a real `WireServer` at wave sizes 1/8/32. Per-request
    // latency is the client-observed wave round trip (wire-inclusive,
    // unlike the serve rows' in-process `latency_s`), and the serve
    // zero-contract counters come back over the wire from `/stats`.
    let mut ingress_json = Json::obj();
    {
        let smodel = if quick { "tiny" } else { "base" };
        let serve_tasks = ["sst2", "mrpc", "rte"];

        let limits = WireLimits::default();
        let mut scratch = RequestScratch::default();
        let pbody = wire_body("sst2", &(0..32).map(|i| (i * 3) % 512).collect::<Vec<_>>(), None);
        let s_parse = b.run("ingress/parse_request", || {
            hadapt::runtime::wire::decode_request(pbody.as_bytes(), &limits, &mut scratch).unwrap()
        });
        let parse_ns = s_parse.mean_ms() * 1e6;

        let streams: Vec<_> = serve_tasks
            .iter()
            .map(|t| generate(task_info(t).unwrap(), 5, "dev", 32))
            .collect();
        let req_bufs: Vec<Vec<u8>> = (0..96)
            .map(|i| {
                let ds = &streams[i % streams.len()];
                let e = &ds.examples[i % ds.examples.len()];
                let body =
                    wire_body(serve_tasks[i % serve_tasks.len()], &e.seq_a, e.seq_b.as_deref());
                wire_post("/infer", &body)
            })
            .collect();

        let mut rows = Json::obj();
        let (mut misses, mut spawns, mut repacks) = (0u64, 0u64, 0u64);
        for &bsz in &[1usize, 8, 32] {
            let mut opts = SpawnOpts::tiny(7);
            opts.model = smodel.to_string();
            opts.threads = threads;
            opts.max_batch = bsz;
            opts.tasks = serve_tasks.iter().map(|t| t.to_string()).collect();
            let (addr, handle) = spawn_synthetic_server(opts).unwrap();
            let mut conn = std::net::TcpStream::connect(addr).unwrap();
            conn.set_nodelay(true).unwrap();

            use std::io::Write as _;
            let mut wavebuf: Vec<u8> = Vec::new();
            for r in req_bufs.iter().take(bsz) {
                wavebuf.extend_from_slice(r);
            }
            conn.write_all(&wavebuf).unwrap();
            wire_read(&mut conn, bsz); // warm-up wave: arena, workers, packs

            let c0 = wire_counters(&mut conn);
            let waves = if quick { 8 } else { 32 };
            let mut lats: Vec<f64> = Vec::new();
            let t0 = std::time::Instant::now();
            for w in 0..waves {
                wavebuf.clear();
                for i in 0..bsz {
                    wavebuf.extend_from_slice(&req_bufs[(w * bsz + i) % req_bufs.len()]);
                }
                let tw = std::time::Instant::now();
                conn.write_all(&wavebuf).unwrap();
                wire_read(&mut conn, bsz);
                let rtt = tw.elapsed().as_secs_f64();
                lats.extend(std::iter::repeat(rtt).take(bsz));
            }
            let wall = t0.elapsed().as_secs_f64();
            let c1 = wire_counters(&mut conn);
            misses += c1.0 - c0.0;
            spawns += c1.1 - c0.1;
            repacks += c1.2 - c0.2;

            conn.write_all(&wire_post("/shutdown", "")).unwrap();
            wire_read(&mut conn, 1);
            handle.join().unwrap().unwrap();

            lats.sort_by(|a, c| a.total_cmp(c));
            let p50 = lats[lats.len() / 2] * 1e3;
            let p99 = lats[(lats.len() * 99 / 100).min(lats.len() - 1)] * 1e3;
            let rps = lats.len() as f64 / wall.max(1e-9);
            println!(
                "bench {:<44} req/s={rps:.0} p50={p50:.3}ms p99={p99:.3}ms",
                format!("ingress/{smodel}/b{bsz} (socket-to-logits)")
            );
            let mut rj = Json::obj();
            rj.set("batch", Json::num(bsz as f64));
            ms(&mut rj, "p50_ms", p50);
            ms(&mut rj, "p99_ms", p99);
            rj.set("req_per_s", Json::num(rps.round()));
            rows.set(&format!("b{bsz}"), rj);
        }
        println!(
            "bench {:<44} parse_ns={parse_ns:.0} steady: misses={misses} spawns={spawns} \
             repacks={repacks}",
            format!("ingress_zero_contract/{smodel}")
        );
        ingress_json.set("provenance", Json::str("measured"));
        ingress_json.set("model", Json::str(smodel));
        ingress_json.set("tasks", Json::num(serve_tasks.len() as f64));
        ingress_json.set("parse_ns_per_request", Json::num(parse_ns.round()));
        ingress_json.set("steady_arena_misses", Json::num(misses as f64));
        ingress_json.set("steady_pool_spawns", Json::num(spawns as f64));
        ingress_json.set("steady_repacks", Json::num(repacks as f64));
        ingress_json.set("rows", rows);
    }

    // Bank rows (PR 7): the tiered adapter bank. Delta-encode a
    // Zipf-clustered synthetic fleet into the on-disk bank format, time
    // the cold-fault path (page + reconstruct one tenant into a reused
    // scratch adapter), replay Zipf-skewed traffic through a tiered
    // ServeSession for the hot-hit rate, then freeze a hot-resident
    // working set and prove steady serve allocation-free with this
    // binary's counting allocator.
    let mut bank_json = Json::obj();
    let mut bank_lifecycle_json = Json::obj();
    {
        let engine = engine_with(Pool::auto(), true);
        // fleet scale, not model scale, is what the bank rows measure —
        // tiny keeps the 1k-tenant build and replay fast at full depth
        let bmodel = "tiny";
        let info = engine.manifest().model(bmodel).unwrap().clone();
        let store = ParamStore::init(&info, 7);
        let base_names: Vec<String> =
            ["sst2", "mrpc", "rte"].iter().map(|t| t.to_string()).collect();
        let bases = synthetic_adapters(&info, &store, &base_names, 1234).unwrap();
        let tenants = if quick { 200 } else { 1000 };
        let classes = info.params[info.param_index("classifier.bias").unwrap()].shape[0];
        let geom = BankGeometry { layers: info.layers, hidden: info.hidden, classes };
        let mut builder = BankBuilder::new(geom, bases.clone(), 0.0).unwrap();
        let t_build = std::time::Instant::now();
        for idx in 0..tenants {
            builder.add_tenant(&synthetic_tenant(&bases, idx, 1234)).unwrap();
        }
        let path =
            std::env::temp_dir().join(format!("hadapt_bench_{}.bank", std::process::id()));
        let summary = builder.write(&path).unwrap();
        let build_ms = t_build.elapsed().as_secs_f64() * 1e3;
        println!(
            "bench {:<44} tenants={} file={:.2}MB ratio={:.1}x build={:.1}ms",
            format!("bank_build/{bmodel}"),
            summary.tenants,
            summary.file_bytes as f64 / 1e6,
            summary.compression_ratio,
            build_ms
        );

        // cold-fault microseconds: page + reconstruct straight off the
        // reader into one reused scratch adapter (the promotion path
        // minus the hot-tier bookkeeping)
        let mut reader = BankReader::open(&path).unwrap();
        let mut scratch = reader.blank_adapter();
        let probes = if quick { 64 } else { 256 };
        let synth = tenants - bases.len();
        let mut fault_us: Vec<f64> = Vec::with_capacity(probes);
        for i in 0..probes {
            let name = format!("t{:06}", bases.len() + (i * 97) % synth);
            let t0 = std::time::Instant::now();
            reader.read_into(&name, &mut scratch).unwrap();
            fault_us.push(t0.elapsed().as_secs_f64() * 1e6);
        }
        fault_us.sort_by(|a, c| a.total_cmp(c));
        let fault_p50 = fault_us[fault_us.len() / 2];
        let fault_p99 = fault_us[(fault_us.len() * 99 / 100).min(fault_us.len() - 1)];

        // Zipf replay: traffic skewed the way the fleet itself is (the
        // product of three uniforms piles most draws on low ranks), hot
        // tier of 64 over the whole fleet
        let hot = 64usize;
        let mut session = ServeSession::new(&engine, bmodel, &store, 8).unwrap();
        session.attach_store(BankReader::open(&path).unwrap(), hot).unwrap();
        let mut rng = Rng::new(4242);
        let replays = if quick { 256 } else { 1024 };
        let names: Vec<String> = (0..replays)
            .map(|_| {
                let u = rng.next_f32() * rng.next_f32() * rng.next_f32();
                let r = ((u * tenants as f32) as usize).min(tenants - 1);
                if r < base_names.len() {
                    base_names[r].clone()
                } else {
                    format!("t{r:06}")
                }
            })
            .collect();
        let seq = [5i32, 6, 7];
        let s0 = session.bank().bank_stats();
        let mut sink = 0usize;
        for wave in names.chunks(8) {
            for name in wave {
                session.submit_borrowed(name, &seq, None).unwrap();
            }
            session.run_direct().unwrap();
            for r in session.direct_replies() {
                sink += r.label;
            }
        }
        let s1 = session.bank().bank_stats();
        let hits = s1.hot_hits - s0.hot_hits;
        let faults = s1.cold_faults - s0.cold_faults;
        let hit_rate = hits as f64 / (hits + faults).max(1) as f64;

        // hot-resident zero-alloc contract, measured: freeze an
        // 8-tenant working set, warm it into the hot tier, then count
        // every heap allocation across 16 steady waves
        let mut hotset = base_names.clone();
        for idx in bases.len()..8 {
            hotset.push(format!("t{idx:06}"));
        }
        for name in &hotset {
            session.submit_borrowed(name, &seq, None).unwrap();
        }
        session.run_direct().unwrap();
        for r in session.direct_replies() {
            sink += r.label;
        }
        ALLOCS.store(0, Ordering::SeqCst);
        TRACKING.store(true, Ordering::SeqCst);
        for _ in 0..16 {
            for name in &hotset {
                session.submit_borrowed(name, &seq, None).unwrap();
            }
            session.run_direct().unwrap();
            for r in session.direct_replies() {
                sink += r.label;
            }
        }
        TRACKING.store(false, Ordering::SeqCst);
        let steady_allocs = ALLOCS.load(Ordering::SeqCst);
        println!(
            "bench {:<44} fault_p50={fault_p50:.1}us fault_p99={fault_p99:.1}us \
             hot_hit_rate={hit_rate:.3} steady_hot_allocs={steady_allocs}",
            format!("bank_serve/{bmodel} (hot {hot} of {tenants})")
        );

        // ---- bank lifecycle rows (PR 9): open / scrub / compact ----
        // clean open (header + centroid verify + full log scan)
        let t0 = std::time::Instant::now();
        let mut life = BankReader::open(&path).unwrap();
        let clean_open_ms = t0.elapsed().as_secs_f64() * 1e3;

        // scrub throughput: every checksum re-verified plus a deep
        // decode of every live payload
        let t0 = std::time::Instant::now();
        let rep = life.scrub().unwrap();
        let scrub_ms = t0.elapsed().as_secs_f64() * 1e3;
        let scrub_mb_per_s = rep.bytes_scanned as f64 / 1e6 / (scrub_ms / 1e3).max(1e-9);

        // salvage open: one flipped byte a third of the way into the
        // tenant log (mid-log, so the scan must resync past it)
        let mut flipped = std::fs::read(&path).unwrap();
        let log_start =
            48 + u64::from_le_bytes(flipped[32..40].try_into().unwrap()) as usize;
        let flip_at = log_start + (flipped.len() - log_start) / 3;
        flipped[flip_at] ^= 0xff;
        let flip_path = std::env::temp_dir()
            .join(format!("hadapt_bench_{}_flip.bank", std::process::id()));
        std::fs::write(&flip_path, &flipped).unwrap();
        let t0 = std::time::Instant::now();
        let salvaged = BankReader::open(&flip_path).unwrap();
        let salvage_open_ms = t0.elapsed().as_secs_f64() * 1e3;
        assert_eq!(salvaged.damage().len(), 1, "the flip costs one record");
        drop(salvaged);
        let _ = std::fs::remove_file(&flip_path);

        // churn shadows into the log, then compact through the live
        // session — the real online generation swap, hot tier and all
        let churn = if quick { 50 } else { 200 };
        let churn_names: Vec<String> = life.names().map(str::to_string).collect();
        let mut churn_out = life.blank_adapter();
        for i in 0..churn {
            life.read_into(&churn_names[i % churn_names.len()], &mut churn_out).unwrap();
            churn_out.had_b[i % churn_out.had_b.len()][0] += 0.0625;
            life.upsert(&churn_out).unwrap();
        }
        drop(life);
        let t0 = std::time::Instant::now();
        let cs = session.compact_bank().unwrap();
        let compact_ms = t0.elapsed().as_secs_f64() * 1e3;

        // zero-contract: steady serve right after the generation swap
        // allocates nothing (the hot tier survived the swap resident)
        ALLOCS.store(0, Ordering::SeqCst);
        TRACKING.store(true, Ordering::SeqCst);
        for _ in 0..16 {
            for name in &hotset {
                session.submit_borrowed(name, &seq, None).unwrap();
            }
            session.run_direct().unwrap();
            for r in session.direct_replies() {
                sink += r.label;
            }
        }
        TRACKING.store(false, Ordering::SeqCst);
        let compact_steady_allocs = ALLOCS.load(Ordering::SeqCst);
        std::hint::black_box(sink);
        let _ = std::fs::remove_file(&path);
        println!(
            "bench {:<44} clean_open={clean_open_ms:.2}ms salvage_open={salvage_open_ms:.2}ms \
             scrub={scrub_mb_per_s:.0}MB/s compact={compact_ms:.1}ms \
             reclaimed={} gen={} steady_allocs={compact_steady_allocs}",
            format!("bank_lifecycle/{bmodel} ({tenants} tenants)"),
            cs.reclaimed_bytes,
            cs.generation
        );

        bank_lifecycle_json.set("provenance", Json::str("measured"));
        bank_lifecycle_json.set("model", Json::str(bmodel));
        bank_lifecycle_json.set("tenants", Json::num(tenants as f64));
        ms(&mut bank_lifecycle_json, "clean_open_ms", clean_open_ms);
        ms(&mut bank_lifecycle_json, "salvage_open_ms", salvage_open_ms);
        ms(&mut bank_lifecycle_json, "scrub_mb_per_s", scrub_mb_per_s);
        ms(&mut bank_lifecycle_json, "compact_ms", compact_ms);
        bank_lifecycle_json.set("compact_upserts", Json::num(churn as f64));
        bank_lifecycle_json
            .set("reclaimed_bytes", Json::num(cs.reclaimed_bytes as f64));
        bank_lifecycle_json.set("generation", Json::num(cs.generation as f64));
        bank_lifecycle_json
            .set("compact_steady_allocs", Json::num(compact_steady_allocs as f64));

        bank_json.set("provenance", Json::str("measured"));
        bank_json.set("model", Json::str(bmodel));
        bank_json.set("tenants", Json::num(summary.tenants as f64));
        bank_json.set("centroids", Json::num(summary.centroids as f64));
        bank_json.set("file_bytes", Json::num(summary.file_bytes as f64));
        ms(&mut bank_json, "build_ms", build_ms);
        ms(&mut bank_json, "compression_ratio", summary.compression_ratio);
        ms(&mut bank_json, "cold_fault_us_p50", fault_p50);
        ms(&mut bank_json, "cold_fault_us_p99", fault_p99);
        bank_json.set("hot", Json::num(hot as f64));
        ms(&mut bank_json, "hot_hit_rate", hit_rate);
        bank_json.set("steady_hot_allocs", Json::num(steady_allocs as f64));
    }

    // Overload rows (PR 8): deliberately offer the front door several
    // times its admitted capacity — a Zipf-skewed burst of 48 pipelined
    // requests per round against queue_cap 32 and a 50 rps/tenant bucket
    // — and report *SLO-honest* numbers: latency percentiles over
    // admitted (200) replies only, goodput next to offered load, and the
    // typed-outcome counts (429/503). `unclassified_errors` must be 0:
    // under overload every single request still gets a typed answer.
    // `tools/wire_load.py --overload` overwrites these rows with a
    // longer open-loop run against a release binary.
    let mut overload_json = Json::obj();
    {
        let policy = hadapt::runtime::ServePolicy {
            queue_cap: 32,
            window_us: 2_000,
            tenant_rps: 50,
            tenant_burst: 50,
            conn_queue_cap: 0,
        };
        let mut opts = SpawnOpts::tiny(13);
        opts.threads = threads;
        opts.max_batch = 8;
        opts.tasks = vec!["sst2".to_string(), "mrpc".to_string(), "rte".to_string()];
        opts.policy = policy;
        let (addr, handle) = spawn_synthetic_server(opts).unwrap();
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        conn.set_nodelay(true).unwrap();
        use std::io::Write as _;

        // one Zipf-skewed burst: 36 heavy-tenant requests, 6 + 6 light
        let mut burst: Vec<u8> = Vec::new();
        let mut mix: Vec<&str> = Vec::new();
        for i in 0..48usize {
            let task = match i % 8 {
                6 => "mrpc",
                7 => "rte",
                _ => "sst2",
            };
            mix.push(task);
            let body = wire_body(task, &[3 + (i % 29) as i32, 7, 11], None);
            burst.extend_from_slice(&wire_post("/infer", &body));
        }

        // warm-up: a small in-budget wave per tenant
        for task in ["sst2", "mrpc", "rte"] {
            conn.write_all(&wire_post("/infer", &wire_body(task, &[5, 6, 7], None))).unwrap();
        }
        wire_read(&mut conn, 3);

        let rounds = if quick { 10 } else { 30 };
        let (mut ok, mut throttled, mut shed, mut other) = (0u64, 0u64, 0u64, 0u64);
        let mut goodput_by_task = [0u64; 3];
        let mut lats: Vec<f64> = Vec::new();
        let t0 = std::time::Instant::now();
        for _ in 0..rounds {
            let tw = std::time::Instant::now();
            conn.write_all(&burst).unwrap();
            let statuses = wire_read_statuses(&mut conn, mix.len());
            let rtt = tw.elapsed().as_secs_f64();
            for (status, task) in statuses.iter().zip(&mix) {
                match status {
                    200 => {
                        ok += 1;
                        lats.push(rtt);
                        let ti = ["sst2", "mrpc", "rte"].iter().position(|t| t == task);
                        goodput_by_task[ti.unwrap()] += 1;
                    }
                    429 => throttled += 1,
                    503 => shed += 1,
                    _ => other += 1,
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        conn.write_all(&wire_post("/shutdown", "")).unwrap();
        wire_read(&mut conn, 1);
        handle.join().unwrap().unwrap();

        let offered_rps = (rounds * mix.len()) as f64 / wall;
        let goodput_rps = ok as f64 / wall;
        lats.sort_by(|a, c| a.total_cmp(c));
        let pct = |q: f64| lats[((lats.len() as f64 * q) as usize).min(lats.len() - 1)] * 1e3;
        let (p50, p99, p999) = (pct(0.50), pct(0.99), pct(0.999));
        // fairness over the two *equally offered* tenants (mrpc vs rte):
        // deviation of each from their mean goodput
        let (gm, gr) = (goodput_by_task[1] as f64, goodput_by_task[2] as f64);
        let fair_dev = (gm - gr).abs() / ((gm + gr) / 2.0).max(1.0);
        println!(
            "bench {:<44} offered={offered_rps:.0}/s goodput={goodput_rps:.0}/s \
             p50={p50:.3}ms p99={p99:.3}ms 429={throttled} 503={shed} other={other}",
            "overload/tiny (48-deep Zipf bursts)"
        );
        assert_eq!(other, 0, "overload must produce typed outcomes only");

        overload_json.set("provenance", Json::str("measured"));
        overload_json.set("model", Json::str("tiny"));
        overload_json.set("offered_rps", Json::num(offered_rps.round()));
        overload_json.set("goodput_rps", Json::num(goodput_rps.round()));
        ms(&mut overload_json, "p50_ms", p50);
        ms(&mut overload_json, "p99_ms", p99);
        ms(&mut overload_json, "p999_ms", p999);
        overload_json.set("throttled_429", Json::num(throttled as f64));
        overload_json.set("shed_503", Json::num(shed as f64));
        overload_json.set("unclassified_errors", Json::num(other as f64));
        ms(&mut overload_json, "fair_dev", fair_dev);
        overload_json.set("window_us", Json::num(policy.window_us as f64));
        overload_json.set("queue_cap", Json::num(policy.queue_cap as f64));
        overload_json.set("tenant_rps", Json::num(policy.tenant_rps as f64));
    }

    // PR 10: the multi-connection front door. Eight persistent
    // connections each send one timestamped request per round into the
    // single serve thread; queue_cap == fleet size flushes the instant
    // every connection's row lands, so each round batches as one
    // cross-connection wave and per-request latency is honest
    // (send-to-reply per socket, not a shared-pipeline RTT).
    // `mc_steady_allocs` is a contract, not a measurement — pinned
    // in-tree by tests/workspace_alloc.rs::steady_multi_conn_loop; the
    // bench re-asserts its observable proxies (arena/spawn/repack
    // counters frozen, nothing shed at the accept tier).
    // `tools/wire_load.py --connections N --bench-out` overwrites
    // these rows with an open-loop run against a release binary.
    let mut ingress_mc_json = Json::obj();
    {
        const MC_TASKS: [&str; 3] = ["sst2", "mrpc", "rte"];
        let n_conns = 8usize;
        let mut opts = SpawnOpts::tiny(17);
        opts.threads = threads;
        opts.max_batch = n_conns;
        opts.tasks = MC_TASKS.iter().map(|t| t.to_string()).collect();
        opts.policy = hadapt::runtime::ServePolicy {
            queue_cap: n_conns,
            window_us: 2_000,
            ..Default::default()
        };
        let (addr, handle) = spawn_synthetic_server(opts).unwrap();
        use std::io::Write as _;
        let mut conns: Vec<std::net::TcpStream> = (0..n_conns)
            .map(|_| {
                let c = std::net::TcpStream::connect(addr).unwrap();
                c.set_nodelay(true).unwrap();
                c
            })
            .collect();

        // warm every connection's slot and the engine with one
        // untracked wave before snapshotting the counters
        for (i, c) in conns.iter_mut().enumerate() {
            let body = wire_body(MC_TASKS[i % 3], &[5 + i as i32, 6, 7], None);
            c.write_all(&wire_post("/infer", &body)).unwrap();
        }
        for c in conns.iter_mut() {
            wire_read(c, 1);
        }
        let mc_stats = |c: &mut std::net::TcpStream| -> (u64, u64, u64) {
            c.write_all(b"GET /stats HTTP/1.1\r\n\r\n").unwrap();
            let body = wire_read(c, 1).pop().unwrap();
            let v = hadapt::util::json::parse(&body).unwrap();
            let n = |k: &str| v.get(k).unwrap().as_usize().unwrap() as u64;
            (n("cross_conn_waves"), n("conns_accepted"), n("conns_rejected"))
        };
        let (waves0, _, _) = mc_stats(&mut conns[0]);
        let (m0, s0, r0) = wire_counters(&mut conns[0]);

        let rounds = if quick { 4 } else { 16 };
        let mut lats: Vec<f64> = Vec::new();
        let mut sent_at: Vec<std::time::Instant> = Vec::with_capacity(n_conns);
        let t0 = std::time::Instant::now();
        for r in 0..rounds {
            sent_at.clear();
            for (i, c) in conns.iter_mut().enumerate() {
                let body =
                    wire_body(MC_TASKS[(r + i) % 3], &[3 + ((r * 7 + i) % 500) as i32, 11, 13], None);
                sent_at.push(std::time::Instant::now());
                c.write_all(&wire_post("/infer", &body)).unwrap();
            }
            for (i, c) in conns.iter_mut().enumerate() {
                let reply = wire_read(c, 1).pop().unwrap();
                lats.push(sent_at[i].elapsed().as_secs_f64());
                let task = MC_TASKS[(r + i) % 3];
                assert!(
                    reply.contains(&format!("\"task\":\"{task}\"")),
                    "cross-connection reply bleed: conn {i} round {r} got {reply}"
                );
            }
        }
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        let (waves1, accepted, rejected) = mc_stats(&mut conns[0]);
        let (m1, s1, r1) = wire_counters(&mut conns[0]);
        conns[0].write_all(&wire_post("/shutdown", "")).unwrap();
        wire_read(&mut conns[0], 1);
        drop(conns);
        handle.join().unwrap().unwrap();

        let waves = waves1 - waves0;
        assert!(waves >= 1, "waves never mixed rows from different connections");
        assert_eq!(accepted, n_conns as u64, "accept counter must cover the fleet");
        assert_eq!(rejected, 0, "nothing may be shed under the accept limit");
        assert_eq!((m1 - m0, s1 - s0, r1 - r0), (0, 0, 0), "multi-conn steady contracts");

        let req_per_s = lats.len() as f64 / wall;
        lats.sort_by(|a, c| a.total_cmp(c));
        let pct = |q: f64| lats[((lats.len() as f64 * q) as usize).min(lats.len() - 1)] * 1e3;
        let (p50, p99, p999) = (pct(0.50), pct(0.99), pct(0.999));
        println!(
            "bench {:<44} req/s={req_per_s:.0} p50={p50:.3}ms p99={p99:.3}ms \
             cross_conn_waves={waves} accepted={accepted} rejected={rejected}",
            format!("ingress_mc/tiny ({n_conns} connections)")
        );

        ingress_mc_json.set("provenance", Json::str("measured"));
        ingress_mc_json.set("model", Json::str("tiny"));
        ingress_mc_json.set("connections", Json::num(n_conns as f64));
        ingress_mc_json.set("req_per_s", Json::num(req_per_s.round()));
        ms(&mut ingress_mc_json, "p50_ms", p50);
        ms(&mut ingress_mc_json, "p99_ms", p99);
        ms(&mut ingress_mc_json, "p999_ms", p999);
        ingress_mc_json.set("conns_accepted", Json::num(accepted as f64));
        ingress_mc_json.set("conns_rejected", Json::num(rejected as f64));
        ingress_mc_json.set("cross_conn_waves", Json::num(waves as f64));
        // contract pinned by steady_multi_conn_loop; re-asserted above
        // through its observable proxies
        ingress_mc_json.set("mc_steady_allocs", Json::num(0.0));
    }

    // record the comparison next to the repo root for the perf trajectory
    let mut out = Json::obj();
    out.set(
        "note",
        Json::str(
            "generated by `cargo bench --bench bench_runtime` — PR 1 scalar kernels \
             vs blocked vs blocked+parallel vs packed+fused (native backend), plus \
             persistent-pool vs scoped dispatch latency (PR 4), multi-tenant \
             serve-path rows (PR 5), wire-ingress rows (PR 6), tiered \
             adapter-bank rows (PR 7), overload rows (PR 8), bank \
             lifecycle rows (PR 9) and multi-connection ingress rows \
             (PR 10); schema in docs/BENCH_SCHEMA.md",
        ),
    );
    out.set("provenance", Json::str("measured"));
    out.set("threads", Json::num(threads as f64));
    out.set("quick", Json::Bool(quick));
    out.set("batch", Json::num(batch as f64));
    out.set("seq_len", Json::num(seq as f64));
    out.set("forward", fwd_json);
    out.set("train_step", step_json);
    out.set("matmul", mm_json);
    out.set("pool", pool_json);
    out.set("serve", serve_json);
    out.set("ingress", ingress_json);
    out.set("bank", bank_json);
    out.set("bank_lifecycle", bank_lifecycle_json);
    out.set("overload", overload_json);
    out.set("ingress_mc", ingress_mc_json);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_kernels.json");
    match std::fs::write(path, out.render_pretty()) {
        Ok(()) => println!("bench results recorded to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
