//! Runtime microbenchmarks: artifact warmup, forward execution latency per
//! model size, train-step latency, and parameter-upload overhead — on the
//! native backend (`Engine::new` always builds it; to benchmark the PJRT
//! path instead, build with `--features xla` and swap the constructor below
//! for `Engine::xla("artifacts")` against a real artifacts directory).

use hadapt::data::{class_mask, generate, make_batch, task_info};
use hadapt::model::{FreezeMask, ParamStore};
use hadapt::optim::LrSchedule;
use hadapt::runtime::{DeviceTensor, Engine, IntTensor, Manifest, Tensor};
use hadapt::train::Session;
use hadapt::util::bench::{report_throughput, Bench};

fn main() {
    let engine = Engine::new("artifacts").expect("engine");
    println!("backend: {}", engine.backend_name());
    let b = Bench::default();
    let batch = engine.manifest().batch;
    let seq = engine.manifest().seq_len;

    for model in ["tiny", "base", "large"] {
        if engine.manifest().model(model).is_err() {
            continue;
        }
        let info = engine.manifest().model(model).unwrap().clone();
        let store = ParamStore::init(&info, 7);

        // warmup (compile on XLA; manifest validation natively)
        let t0 = std::time::Instant::now();
        engine.warmup(&Manifest::fwd_name(model)).unwrap();
        println!(
            "bench {:<44} once={:>10.3?}",
            format!("warmup/fwd_{model}"),
            t0.elapsed()
        );

        let ds = generate(task_info("sst2").unwrap(), 1, "dev", batch);
        let idx: Vec<usize> = (0..batch).collect();
        let bt = make_batch(&ds, &idx, batch, seq);

        // forward with parameters re-uploaded on every call (cold path)
        let s_cold = b.run(&format!("fwd_exec_upload/{model}"), || {
            let param_bufs: Vec<DeviceTensor> = store
                .tensors
                .iter()
                .map(|t| engine.upload(t).unwrap())
                .collect();
            let tok = engine
                .upload_int(&IntTensor::new(vec![batch, seq], bt.tokens.clone()).unwrap())
                .unwrap();
            let typ = engine
                .upload_int(&IntTensor::new(vec![batch, seq], bt.type_ids.clone()).unwrap())
                .unwrap();
            let msk = engine
                .upload(&Tensor::new(vec![batch, seq], bt.attn_mask.clone()).unwrap())
                .unwrap();
            let mut refs: Vec<&DeviceTensor> = param_bufs.iter().collect();
            refs.push(&tok);
            refs.push(&typ);
            refs.push(&msk);
            engine.run(&Manifest::fwd_name(model), &refs).unwrap()
        });
        report_throughput(&format!("fwd_exec_upload/{model} (seqs)"), batch as f64, &s_cold);

        // resident parameters (the Session/eval hot path): uploaded once,
        // only the batch staged per call — the §Perf L3 optimization.
        let param_bufs: Vec<DeviceTensor> = store
            .tensors
            .iter()
            .map(|t| engine.upload(t).unwrap())
            .collect();
        let tok = engine
            .upload_int(&IntTensor::new(vec![batch, seq], bt.tokens.clone()).unwrap())
            .unwrap();
        let typ = engine
            .upload_int(&IntTensor::new(vec![batch, seq], bt.type_ids.clone()).unwrap())
            .unwrap();
        let msk = engine
            .upload(&Tensor::new(vec![batch, seq], bt.attn_mask.clone()).unwrap())
            .unwrap();
        let s_hot = b.run(&format!("fwd_exec_resident/{model}"), || {
            let mut refs: Vec<&DeviceTensor> = param_bufs.iter().collect();
            refs.push(&tok);
            refs.push(&typ);
            refs.push(&msk);
            engine.run(&Manifest::fwd_name(model), &refs).unwrap()
        });
        report_throughput(&format!("fwd_exec_resident/{model} (seqs)"), batch as f64, &s_hot);
        println!(
            "bench {:<44} upload_vs_resident_speedup={:.2}x",
            format!("fwd_exec/{model}"),
            s_cold.mean_ms() / s_hot.mean_ms()
        );

        // train step (hadamard group, the paper's hot path)
        let mask = FreezeMask::from_names(&info, &info.group("hadamard").unwrap().to_vec());
        let mut session = Session::new(
            &engine,
            &Manifest::train_name("cls", "hadamard", model),
            store.clone(),
            mask,
            LrSchedule::constant(1e-3),
        )
        .unwrap();
        let cm = class_mask(2);
        let s = b.run(&format!("train_step/hadamard/{model}"), || {
            session.step_cls(&bt, &cm).unwrap()
        });
        report_throughput(&format!("train_step/hadamard/{model} (seqs)"), batch as f64, &s);

        // upload overhead (largest tensor)
        let biggest = store
            .tensors
            .iter()
            .max_by_key(|t| t.numel())
            .unwrap()
            .clone();
        let bytes = biggest.numel() * 4;
        let s = b.run(&format!("upload/{model}/largest_tensor"), || {
            engine.upload(&biggest).unwrap()
        });
        report_throughput(&format!("upload/{model} (MB)"), bytes as f64 / 1e6, &s);
    }
}
