//! Runtime microbenchmarks: artifact compile time, forward/train-step
//! execution latency per model size, host->device upload bandwidth.

use hadapt::data::{class_mask, generate, make_batch, task_info};
use hadapt::model::{FreezeMask, ParamStore};
use hadapt::optim::LrSchedule;
use hadapt::runtime::{Engine, Manifest, Tensor};
use hadapt::train::Session;
use hadapt::util::bench::{report_throughput, Bench};

fn main() {
    let engine = Engine::new("artifacts").expect("make artifacts first");
    let b = Bench::default();
    let batch = engine.manifest().batch;
    let seq = engine.manifest().seq_len;

    for model in ["tiny", "base", "large"] {
        if engine.manifest().model(model).is_err() {
            continue;
        }
        let info = engine.manifest().model(model).unwrap().clone();
        let store = ParamStore::init(&info, 7);

        // compile (first-use) — measured once, not via Bench
        let t0 = std::time::Instant::now();
        engine.warmup(&Manifest::fwd_name(model)).unwrap();
        println!(
            "bench {:<44} once={:>10.3?}",
            format!("compile/fwd_{model}"),
            t0.elapsed()
        );

        // forward execution
        let ds = generate(task_info("sst2").unwrap(), 1, "dev", batch);
        let idx: Vec<usize> = (0..batch).collect();
        let bt = make_batch(&ds, &idx, batch, seq);
        let param_lits: Vec<xla::Literal> = store
            .tensors
            .iter()
            .map(|t| t.to_literal().unwrap())
            .collect();
        let tok = hadapt::runtime::IntTensor::new(vec![batch, seq], bt.tokens.clone())
            .unwrap()
            .to_literal()
            .unwrap();
        let typ = hadapt::runtime::IntTensor::new(vec![batch, seq], bt.type_ids.clone())
            .unwrap()
            .to_literal()
            .unwrap();
        let msk = Tensor::new(vec![batch, seq], bt.attn_mask.clone())
            .unwrap()
            .to_literal()
            .unwrap();
        let mut inputs: Vec<xla::Literal> = param_lits.clone();
        inputs.push(tok);
        inputs.push(typ);
        inputs.push(msk);
        let s = b.run(&format!("fwd_exec_literals/{model}"), || {
            engine.run(&Manifest::fwd_name(model), &inputs).unwrap()
        });
        report_throughput(&format!("fwd_exec_literals/{model} (seqs)"), batch as f64, &s);

        // device-resident parameters (the Session/eval hot path): params
        // uploaded once, only the batch staged per call — the §Perf L3
        // optimization vs the literal path above.
        let param_bufs: Vec<xla::PjRtBuffer> = store
            .tensors
            .iter()
            .map(|t| engine.upload(t).unwrap())
            .collect();
        let tok_b = hadapt::runtime::IntTensor::new(vec![batch, seq], bt.tokens.clone())
            .unwrap()
            .to_buffer(engine.client())
            .unwrap();
        let typ_b = hadapt::runtime::IntTensor::new(vec![batch, seq], bt.type_ids.clone())
            .unwrap()
            .to_buffer(engine.client())
            .unwrap();
        let msk_b = Tensor::new(vec![batch, seq], bt.attn_mask.clone())
            .unwrap()
            .to_buffer(engine.client())
            .unwrap();
        let s2 = b.run(&format!("fwd_exec_buffers/{model}"), || {
            let mut refs: Vec<&xla::PjRtBuffer> = param_bufs.iter().collect();
            refs.push(&tok_b);
            refs.push(&typ_b);
            refs.push(&msk_b);
            engine
                .run_buffers(&Manifest::fwd_name(model), &refs)
                .unwrap()
        });
        report_throughput(&format!("fwd_exec_buffers/{model} (seqs)"), batch as f64, &s2);
        println!(
            "bench {:<44} literal_vs_buffer_speedup={:.2}x",
            format!("fwd_exec/{model}"),
            s.mean_ms() / s2.mean_ms()
        );

        // train step (hadamard group, the paper's hot path)
        let mask = FreezeMask::from_names(&info, &info.group("hadamard").unwrap().to_vec());
        let mut session = Session::new(
            &engine,
            &Manifest::train_name("cls", "hadamard", model),
            store.clone(),
            mask,
            LrSchedule::constant(1e-3),
        )
        .unwrap();
        let cm = class_mask(2);
        let s = b.run(&format!("train_step/hadamard/{model}"), || {
            session.step_cls(&bt, &cm).unwrap()
        });
        report_throughput(&format!("train_step/hadamard/{model} (seqs)"), batch as f64, &s);

        // upload bandwidth (largest tensor)
        let biggest = store
            .tensors
            .iter()
            .max_by_key(|t| t.numel())
            .unwrap()
            .clone();
        let bytes = biggest.numel() * 4;
        let s = b.run(&format!("upload/{model}/largest_tensor"), || {
            engine.upload(&biggest).unwrap()
        });
        report_throughput(
            &format!("upload/{model} (MB)"),
            bytes as f64 / 1e6,
            &s,
        );
    }
}
