//! Optimizer benchmarks: AdamW update throughput across tensor sizes (the
//! Hadamard method updates a handful of H-sized vectors; full FT updates
//! megabytes — the host-side cost asymmetry behind the paper's efficiency
//! claim), plus gradient clipping.

use hadapt::optim::{clip_global_norm, AdamW};
use hadapt::util::bench::{report_throughput, Bench};
use hadapt::util::Rng;

fn main() {
    let b = Bench::new(3, 12);
    let mut rng = Rng::new(9);

    for n in [128usize, 4096, 65_536, 1 << 20] {
        let mut opt = AdamW::new(0.01);
        let mut param: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let grad: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let s = b.run(&format!("optim/adamw_update_n{n}"), || {
            opt.next_step();
            opt.update("x.weight", &mut param, &grad, 1e-3);
        });
        report_throughput(&format!("optim/adamw n={n} (Mscalars)"), n as f64 / 1e6, &s);
    }

    // hadamard-sized working set: 2 vectors of 128 per layer x 4 layers
    let mut opt = AdamW::new(0.01);
    let mut vecs: Vec<Vec<f32>> = (0..8).map(|_| vec![1.0f32; 128]).collect();
    let grads: Vec<Vec<f32>> = (0..8).map(|_| vec![0.01f32; 128]).collect();
    let s = b.run("optim/hadamard_full_update(8x128)", || {
        opt.next_step();
        for (i, (p, g)) in vecs.iter_mut().zip(&grads).enumerate() {
            opt.update(&format!("l{i}.hadamard.weight"), p, g, 1e-3);
        }
    });
    report_throughput("optim/hadamard_full_update (vectors)", 8.0, &s);

    // clipping
    let mut grads: Vec<Vec<f32>> = (0..50).map(|_| {
        (0..4096).map(|_| rng.normal()).collect()
    }).collect();
    let s = b.run("optim/clip_global_norm_50x4096", || {
        clip_global_norm(&mut grads, 1.0)
    });
    report_throughput("optim/clip (Mscalars)", 50.0 * 4096.0 / 1e6, &s);
}
