//! Table 5 / Fig 4 systems axis: step latency + update bytes vs the number
//! of unfrozen adapter layers. Update cost scales linearly with k while the
//! executed graph stays constant — the systems counterpart of the paper's
//! "redundant layers" finding (0.022% params at half depth).

use hadapt::data::{class_mask, generate, make_batch, task_info};
use hadapt::methods::Method;
use hadapt::model::ParamStore;
use hadapt::optim::LrSchedule;
use hadapt::runtime::{Engine, Manifest};
use hadapt::train::Session;
use hadapt::util::bench::Bench;

fn main() {
    let engine = Engine::new("artifacts").expect("engine");
    let b = Bench::default();
    let batch = engine.manifest().batch;
    let seq = engine.manifest().seq_len;

    for model in ["base", "large"] {
        let Ok(info) = engine.manifest().model(model) else { continue };
        let info = info.clone();
        let ds = generate(task_info("qnli").unwrap(), 1, "train", batch);
        let idx: Vec<usize> = (0..batch).collect();
        let bt = make_batch(&ds, &idx, batch, seq);
        let cm = class_mask(2);

        for k in 1..=info.layers {
            if k != 1 && k != info.layers && k != info.layers / 2 {
                continue;
            }
            let method = Method::hadamard_last_k(k);
            let store = ParamStore::init(&info, 7);
            let mask = method.main_mask(&info).unwrap();
            let mut session = Session::new(
                &engine,
                &Manifest::train_name("cls", method.group, model),
                store,
                mask,
                LrSchedule::constant(1e-3),
            )
            .unwrap();
            let trainable = session.trainable_scalars();
            let s = b.run(&format!("table5/step/{model}@k{k}"), || {
                session.step_cls(&bt, &cm).unwrap()
            });
            println!(
                "bench {:<44} trainable={} update_bytes={} mean_ms={:.2}",
                format!("table5/cost/{model}@k{k}"),
                trainable,
                trainable * 4,
                s.mean_ms()
            );
        }
    }
}
