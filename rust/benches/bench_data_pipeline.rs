//! Data-substrate benchmarks: corpus generation, task generation for all
//! eight synthetic-GLUE families, batching, and MLM masking throughput.
//! The data pipeline must never be the training bottleneck (steps cost
//! milliseconds; batches must cost microseconds).

use hadapt::data::{generate, make_batch, mlm_batch, Corpus, BatchIter, TASKS};
use hadapt::util::bench::{report_throughput, Bench};
use hadapt::util::Rng;

fn main() {
    let b = Bench::new(2, 8);

    // corpus sentences
    let s = b.run("data/corpus_sentences_x1000", || {
        let mut c = Corpus::new(1);
        let mut n = 0;
        for _ in 0..1000 {
            n += c.sentence().tokens.len();
        }
        n
    });
    report_throughput("data/corpus (sentences)", 1000.0, &s);

    // task generation
    for info in TASKS {
        let s = b.run(&format!("data/gen/{}_x256", info.name), || {
            generate(info, 7, "bench", 256)
        });
        report_throughput(&format!("data/gen/{} (examples)", info.name), 256.0, &s);
    }

    // batching
    let ds = generate(TASKS[2], 7, "bench", 1024); // mnli: pair task
    let idx: Vec<usize> = (0..16).collect();
    let s = b.run("data/make_batch_16x32", || make_batch(&ds, &idx, 16, 32));
    report_throughput("data/make_batch (seqs)", 16.0, &s);

    // full epoch iteration
    let s = b.run("data/epoch_iter_1024", || {
        let mut rng = Rng::new(3);
        BatchIter::new(&ds, &mut rng, 16, 32).count()
    });
    report_throughput("data/epoch_iter (batches)", (1024 / 16) as f64, &s);

    // MLM masking
    let s = b.run("data/mlm_batch_16x32", || {
        let mut c = Corpus::new(5);
        let mut r = Rng::new(6);
        mlm_batch(&mut c, &mut r, 16, 32)
    });
    report_throughput("data/mlm_batch (seqs)", 16.0, &s);
}
