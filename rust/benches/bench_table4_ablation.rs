//! Table 4 systems axis: module-combo ablations share one gradient-group
//! artifact; the freeze mask decides what updates. This bench verifies the
//! design claim that masking is free — step cost is flat across combos
//! while update bytes scale with the unfrozen set.

use hadapt::data::{class_mask, generate, make_batch, task_info};
use hadapt::methods::Method;
use hadapt::model::ParamStore;
use hadapt::optim::LrSchedule;
use hadapt::runtime::{Engine, Manifest};
use hadapt::train::Session;
use hadapt::util::bench::Bench;

fn main() {
    let engine = Engine::new("artifacts").expect("engine");
    let b = Bench::default();
    let batch = engine.manifest().batch;
    let seq = engine.manifest().seq_len;
    let model = "base";
    let info = engine.manifest().model(model).unwrap().clone();

    let ds = generate(task_info("sst2").unwrap(), 1, "train", batch);
    let idx: Vec<usize> = (0..batch).collect();
    let bt = make_batch(&ds, &idx, batch, seq);
    let cm = class_mask(2);

    let mut times = Vec::new();
    for combo in ["W", "B", "N", "B+N", "W+B", "W+B+N", "W+B+N+A"] {
        let method = Method::hadamard_ablation(combo);
        let store = ParamStore::init(&info, 7);
        let mask = method.main_mask(&info).unwrap();
        let mut session = Session::new(
            &engine,
            &Manifest::train_name("cls", method.group, model),
            store,
            mask,
            LrSchedule::constant(1e-3),
        )
        .unwrap();
        let trainable = session.trainable_scalars();
        let s = b.run(&format!("table4/step/{combo}"), || {
            session.step_cls(&bt, &cm).unwrap()
        });
        println!(
            "bench {:<44} trainable={trainable}",
            format!("table4/params/{combo}")
        );
        times.push(s.mean_ms());
    }
    let spread = times.iter().cloned().fold(f64::MIN, f64::max)
        / times.iter().cloned().fold(f64::MAX, f64::min);
    println!(
        "bench {:<44} max/min_step_time={spread:.2}x (masking is ~free)",
        "table4/flatness"
    );
}
