//! Table 1 systems axis: gradient-probe cost. The paper's analysis reads
//! back *every* gradient (the `full` group); the tuning method only reads
//! its own group. This bench measures both, quantifying why gradient-group
//! specialization matters.

use hadapt::data::{class_mask, generate, make_batch, task_info};
use hadapt::model::{FreezeMask, ParamStore};
use hadapt::optim::LrSchedule;
use hadapt::runtime::{Engine, Manifest};
use hadapt::train::Session;
use hadapt::util::bench::Bench;

fn main() {
    let engine = Engine::new("artifacts").expect("engine");
    let b = Bench::default();
    let batch = engine.manifest().batch;
    let seq = engine.manifest().seq_len;
    let model = "base";
    let info = engine.manifest().model(model).unwrap().clone();

    let ds = generate(task_info("mrpc").unwrap(), 1, "train", batch);
    let idx: Vec<usize> = (0..batch).collect();
    let bt = make_batch(&ds, &idx, batch, seq);
    let cm = class_mask(2);

    for group in ["full", "hadamard", "head"] {
        let store = ParamStore::init(&info, 7);
        let mask = FreezeMask::from_names(&info, &info.group(group).unwrap().to_vec());
        let mut session = Session::new(
            &engine,
            &Manifest::train_name("cls", group, model),
            store,
            mask,
            LrSchedule::constant(1e-4),
        )
        .unwrap();
        let n_grads = engine
            .manifest()
            .artifact(&Manifest::train_name("cls", group, model))
            .unwrap()
            .grad_params()
            .len();
        let s = b.run(&format!("table1/grad_probe/{group}"), || {
            session.probe_gradients(&bt, &cm).unwrap()
        });
        println!(
            "bench {:<44} grads_read={} mean_ms={:.2}",
            format!("table1/probe_cost/{group}"),
            n_grads,
            s.mean_ms()
        );
    }
}
