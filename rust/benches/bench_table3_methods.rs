//! Table 3 systems axis: per-step latency + update-bytes for every PEFT
//! method under the identical harness. The paper's parameter table becomes
//! a bytes-moved table: what each method re-uploads to the device per step.

use hadapt::data::{class_mask, generate, make_batch, task_info};
use hadapt::methods::Method;
use hadapt::model::ParamStore;
use hadapt::optim::LrSchedule;
use hadapt::runtime::{Engine, Manifest};
use hadapt::train::Session;
use hadapt::util::bench::Bench;

fn main() {
    let engine = Engine::new("artifacts").expect("engine");
    let b = Bench::default();
    let batch = engine.manifest().batch;
    let seq = engine.manifest().seq_len;
    let model = "base";
    let info = engine.manifest().model(model).unwrap().clone();

    let ds = generate(task_info("sst2").unwrap(), 1, "train", batch);
    let idx: Vec<usize> = (0..batch).collect();
    let bt = make_batch(&ds, &idx, batch, seq);
    let cm = class_mask(2);

    for name in ["hadamard", "bitfit", "lora", "houlsby", "ia3", "lntuning"] {
        let method = Method::by_name(name).unwrap();
        let store = ParamStore::init(&info, 7);
        let mask = method.main_mask(&info).unwrap();
        let mut session = Session::new(
            &engine,
            &Manifest::train_name("cls", method.group, model),
            store,
            mask,
            LrSchedule::constant(1e-3),
        )
        .unwrap();
        let trainable = session.trainable_scalars();
        let s = b.run(&format!("table3/step/{name}"), || {
            session.step_cls(&bt, &cm).unwrap()
        });
        println!(
            "bench {:<44} trainable={} update_bytes/step={} mean_ms={:.2}",
            format!("table3/cost/{name}"),
            trainable,
            trainable * 4,
            s.mean_ms()
        );
    }
}
