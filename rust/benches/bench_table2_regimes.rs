//! Table 2 systems axis: training-step latency and throughput for the three
//! regimes the paper compares — classifier probe, Hadamard adapter tuning,
//! full fine-tuning. The paper's efficiency claim translates here into
//! step-cost ordering: head < hadamard << full (backward + update +
//! re-upload all scale with the trainable set).

use hadapt::data::{class_mask, generate, make_batch, task_info};
use hadapt::model::{FreezeMask, ParamStore};
use hadapt::optim::LrSchedule;
use hadapt::runtime::{Engine, Manifest};
use hadapt::train::Session;
use hadapt::util::bench::{report_throughput, Bench};

fn main() {
    let engine = Engine::new("artifacts").expect("engine");
    let b = Bench::default();
    let batch = engine.manifest().batch;
    let seq = engine.manifest().seq_len;
    let model = "base";
    let info = engine.manifest().model(model).unwrap().clone();

    let ds = generate(task_info("sst2").unwrap(), 1, "train", batch);
    let idx: Vec<usize> = (0..batch).collect();
    let bt = make_batch(&ds, &idx, batch, seq);
    let cm = class_mask(2);

    let mut results = Vec::new();
    for (regime, group) in [
        ("classifier", "head"),
        ("hadamard", "hadamard"),
        ("full", "full"),
    ] {
        let store = ParamStore::init(&info, 7);
        let mask = FreezeMask::from_names(&info, &info.group(group).unwrap().to_vec());
        let mut session = Session::new(
            &engine,
            &Manifest::train_name("cls", group, model),
            store,
            mask,
            LrSchedule::constant(1e-3),
        )
        .unwrap();
        let trainable = session.trainable_scalars();
        let s = b.run(&format!("table2/step/{regime}"), || {
            session.step_cls(&bt, &cm).unwrap()
        });
        report_throughput(&format!("table2/step/{regime} (seqs)"), batch as f64, &s);
        println!(
            "bench {:<44} trainable={trainable}",
            format!("table2/params/{regime}")
        );
        results.push((regime, s.mean_ms(), trainable));
    }
    let full_ms = results[2].1;
    for (regime, ms, _) in &results {
        println!(
            "bench {:<44} step_cost_vs_full={:.2}x",
            format!("table2/relative/{regime}"),
            ms / full_ms
        );
    }
}
