//! API-compatible stub of the published `xla` crate (0.1.6 surface subset).
//!
//! The offline build environment cannot fetch the real crate, but the
//! workspace keeps its PJRT code paths compiling behind the `xla` cargo
//! feature so they do not rot. Every operation returns
//! [`Error::Unavailable`] at runtime. To run the real PJRT backend, replace
//! this directory with the published `xla` crate (same package name) and
//! rebuild with `--features xla`.

use std::fmt;

/// Error type mirroring `xla::Error` closely enough for `?` conversions.
#[derive(Debug)]
pub enum Error {
    /// The stub is in use: no PJRT runtime is linked into this build.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} requires the real `xla` crate \
                 (replace rust/vendor/xla and rebuild with --features xla)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Element dtypes the workspace uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Host literal (stub).
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        unavailable("Literal::create_from_shape_and_untyped_data")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable("Literal::array_shape")
    }
}

/// Array shape (stub).
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Device buffer (stub).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// PJRT client (stub).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

/// Parsed HLO module proto (stub).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation (stub).
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        let e = HloModuleProto::from_text_file("x").unwrap_err();
        assert!(e.to_string().contains("stub"));
    }
}
