//! Minimal offline shim of the `anyhow` crate.
//!
//! The real crate is unavailable in the offline build environment; this shim
//! reproduces the subset of its surface the workspace uses — `Error`,
//! `Result`, `anyhow!`, `bail!`, and the `Context` extension trait — as a
//! flattened message chain (no backtraces, no downcasting). The structure
//! (blanket `From<E: std::error::Error>`, a private conversion trait
//! implemented for both std errors and `Error`) mirrors upstream so
//! swapping in the published crate is a Cargo.toml-only change.

use std::fmt::{self, Debug, Display};

/// A string-backed error value. Like `anyhow::Error` it deliberately does
/// *not* implement `std::error::Error`, which is what makes the blanket
/// `From` impl below coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Wrap with an outer context message ("context: cause").
    pub fn context<C: Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Conversion trait (implementation detail of [`Context`]): anything that
/// can become an [`Error`]. Implemented for std errors and for `Error`
/// itself (which does not implement `std::error::Error`, so the impls do
/// not overlap).
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl<E> IntoError for E
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: IntoError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_error().context(context)),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_error().context(f())),
        }
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(context)),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(f())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"))
    }

    #[test]
    fn from_std_error_and_context() {
        let e: Error = io_err().unwrap_err().into();
        assert!(e.to_string().contains("boom"));
        let e = io_err().context("reading file").unwrap_err();
        assert_eq!(e.to_string(), "reading file: boom");
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let r: Result<()> = Err(anyhow!("inner {}", 7));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 7");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing x");
    }

    #[test]
    fn bail_and_question_mark() {
        fn f() -> Result<()> {
            bail!("nope {}", 1);
        }
        fn g() -> Result<u32> {
            let n: u32 = "42".parse()?;
            Ok(n)
        }
        assert_eq!(f().unwrap_err().to_string(), "nope 1");
        assert_eq!(g().unwrap(), 42);
    }
}
