//! Training layer: sessions (device-resident hot path), the two-stage
//! tuning pipeline, MLM pre-training, and evaluation.

pub mod eval;
pub mod pretrain;
pub mod session;
pub mod tune;

pub use eval::{evaluate, EvalResult};
pub use pretrain::{checkpoint_path, load_or_pretrain, pretrain, PretrainOpts, PretrainResult};
pub use session::{Session, TrainOpts};
pub use tune::{tune, TuneOpts, TuneResult};
