//! MLM pre-training: manufactures the "pre-trained language model" that the
//! paper downloads from HuggingFace (DESIGN.md §3 substitution). Trains the
//! backbone (adapters frozen at identity, task heads untouched) on the
//! synthetic corpus and writes a checkpoint the downstream experiments
//! reload.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::data::{mlm_batch, Corpus};
use crate::model::{FreezeMask, ParamStore};
use crate::optim::LrSchedule;
use crate::runtime::{Engine, Manifest};
use crate::util::Rng;

use super::session::Session;

/// Pre-training configuration.
#[derive(Debug, Clone)]
pub struct PretrainOpts {
    /// MLM steps to run.
    pub steps: usize,
    /// Peak learning rate.
    pub lr: f32,
    /// Linear-warmup steps.
    pub warmup: u64,
    /// Data/init seed.
    pub seed: u64,
    /// Progress-print cadence (steps).
    pub log_every: usize,
}

impl Default for PretrainOpts {
    fn default() -> Self {
        PretrainOpts { steps: 600, lr: 1e-3, warmup: 50, seed: 1234, log_every: 50 }
    }
}

/// Result: final store + loss curve.
pub struct PretrainResult {
    /// The pre-trained parameters.
    pub store: ParamStore,
    /// Per-step MLM loss curve.
    pub losses: Vec<f32>,
}

/// Run MLM pre-training for `model`, returning the trained store.
pub fn pretrain(
    engine: &Engine,
    model: &str,
    opts: &PretrainOpts,
) -> Result<PretrainResult> {
    let info = engine.manifest().model(model)?;
    let store = ParamStore::init(info, opts.seed);
    let mask = FreezeMask::from_names(info, &info.mlm_group.clone());
    let sched = LrSchedule::warmup_decay(opts.lr, opts.warmup, opts.steps as u64);
    let artifact = Manifest::mlm_name(model);
    let mut session = Session::new(engine, &artifact, store, mask, sched)?;

    let b = engine.manifest().batch;
    let s = engine.manifest().seq_len;
    let mut corpus = Corpus::new(opts.seed ^ 0xC0FFEE);
    let mut rng = Rng::new(opts.seed ^ 0xBEEF);

    for step in 0..opts.steps {
        let batch = mlm_batch(&mut corpus, &mut rng, b, s);
        let loss = session.step_mlm(&batch, b, s)?;
        if opts.log_every > 0 && (step % opts.log_every == 0 || step + 1 == opts.steps) {
            println!("  mlm[{model}] step {step:>5}  loss {loss:.4}");
        }
    }
    let losses = session.losses.clone();
    Ok(PretrainResult { store: session.into_store(), losses })
}

/// Conventional checkpoint path for a pre-trained backbone.
pub fn checkpoint_path(dir: impl AsRef<Path>, model: &str, seed: u64) -> PathBuf {
    dir.as_ref().join(format!("{model}_s{seed}.ckpt"))
}

/// Load a cached backbone, or pre-train and cache it. This is what every
/// experiment driver calls — the "download the PLM" step of the paper.
pub fn load_or_pretrain(
    engine: &Engine,
    model: &str,
    dir: impl AsRef<Path>,
    opts: &PretrainOpts,
) -> Result<ParamStore> {
    let path = checkpoint_path(&dir, model, opts.seed);
    if path.exists() {
        let store = ParamStore::load(&path)?;
        store.check_against(engine.manifest().model(model)?)?;
        return Ok(store);
    }
    println!("pre-training backbone '{model}' ({} steps)...", opts.steps);
    let result = pretrain(engine, model, opts)?;
    let first = result.losses.first().copied().unwrap_or(0.0);
    let last = result.losses.last().copied().unwrap_or(0.0);
    println!("  mlm[{model}] loss {first:.3} -> {last:.3}");
    result.store.save(&path)?;
    Ok(result.store)
}
