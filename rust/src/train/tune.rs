//! The paper's adapter tuning pipeline (Sec. 3.2).
//!
//! Stage 1: unfreeze and train only the pooling + classifier modules.
//! Stage 2: reload them, inject the (already-present, identity-initialized)
//! Hadamard adapter, and fine-tune only the adapter + normalization modules.
//! Single-stage methods (full FT, BitFit, LoRA, ...) skip stage 1.

use anyhow::Result;

use crate::data::{class_mask, BatchIter, Dataset};
use crate::methods::{Method, Pipeline};
use crate::model::{FreezeMask, ParamStore};
use crate::optim::LrSchedule;
use crate::runtime::{Engine, Manifest};
use crate::util::Rng;

use super::eval::{evaluate, EvalResult};
use super::session::{Session, TrainOpts};

/// Step budgets for the two stages.
#[derive(Debug, Clone)]
pub struct TuneOpts {
    /// Stage-1 (head-only) steps.
    pub stage1_steps: usize,
    /// Main-stage steps.
    pub main_steps: usize,
    /// Fraction of steps spent in linear warmup.
    pub warmup_frac: f32,
    /// Shared loop options (batch size, clip, seed).
    pub train: TrainOpts,
    /// Override the method's default LRs (used by sweeps).
    pub lr_stage1: Option<f32>,
    /// Override the method's main-stage LR.
    pub lr_main: Option<f32>,
    /// Print per-stage progress.
    pub verbose: bool,
}

impl Default for TuneOpts {
    fn default() -> Self {
        TuneOpts {
            stage1_steps: 120,
            main_steps: 360,
            warmup_frac: 0.1,
            train: TrainOpts::default(),
            lr_stage1: None,
            lr_main: None,
            verbose: false,
        }
    }
}

impl TuneOpts {
    /// Fast settings for tests and smoke runs.
    pub fn quick() -> Self {
        TuneOpts { stage1_steps: 20, main_steps: 40, ..Default::default() }
    }
}

/// Outcome of one (model, task, method) tuning run.
#[derive(Debug, Clone)]
pub struct TuneResult {
    /// Dev-set score on the paper's 0-100 scale.
    pub score: f64,
    /// Full evaluation output (predictions, probes).
    pub eval: EvalResult,
    /// Stage-1 loss curve.
    pub stage1_losses: Vec<f32>,
    /// Main-stage loss curve.
    pub main_losses: Vec<f32>,
    /// trainable scalars in the main stage (paper accounting, incl. head
    /// when the method trains it jointly).
    pub trainable_scalars: usize,
    /// adapter-only scalars (paper's headline %, excludes the task head).
    pub adapter_scalars: usize,
    /// `adapter_scalars` over the backbone total.
    pub param_fraction: f64,
    /// final store (for the analysis module / adapter extraction).
    pub store: ParamStore,
}

fn loss_kind(ds: &Dataset) -> &'static str {
    if ds.info.regression {
        "reg"
    } else {
        "cls"
    }
}

/// Run `steps` training steps of `session` over `train` batches.
fn run_steps(
    session: &mut Session,
    ds: &Dataset,
    steps: usize,
    batch: usize,
    seq: usize,
    seed: u64,
    verbose: bool,
) -> Result<()> {
    let cmask = class_mask(ds.info.classes);
    let reg = ds.info.regression;
    let mut rng = Rng::new(seed);
    let mut done = 0;
    'outer: loop {
        let mut it = BatchIter::new(ds, &mut rng, batch, seq);
        while let Some(b) = it.next() {
            let loss = if reg {
                session.step_reg(&b)?
            } else {
                session.step_cls(&b, &cmask)?
            };
            done += 1;
            if verbose && done % 50 == 0 {
                println!("    step {done:>5}  loss {loss:.4}");
            }
            if done >= steps {
                break 'outer;
            }
        }
    }
    Ok(())
}

/// Tune a pre-trained backbone on a task with a method; returns the scored
/// result. `backbone` is the MLM checkpoint (never mutated).
pub fn tune(
    engine: &Engine,
    model: &str,
    backbone: &ParamStore,
    train_ds: &Dataset,
    dev_ds: &Dataset,
    method: &Method,
    opts: &TuneOpts,
) -> Result<TuneResult> {
    let info = engine.manifest().model(model)?;
    let batch = engine.manifest().batch;
    let seq = engine.manifest().seq_len;
    let lk = loss_kind(train_ds);
    let seed = opts.train.seed ^ crate::util::fnv1a(&format!(
        "{model}/{}/{}", train_ds.info.name, method.name
    ));

    let mut store = backbone.clone();
    let mut stage1_losses = Vec::new();

    // ---- stage 1: train the classifier module (paper Fig. 3a) ----
    if method.pipeline == Pipeline::TwoStage && opts.stage1_steps > 0 {
        let head_names = info.group("head")?.to_vec();
        let mask = FreezeMask::from_names(info, &head_names);
        let lr = opts.lr_stage1.unwrap_or(method.lr_stage1);
        let sched = LrSchedule::warmup_decay(
            lr,
            (opts.stage1_steps as f32 * opts.warmup_frac) as u64,
            opts.stage1_steps as u64,
        );
        let artifact = Manifest::train_name(lk, "head", model);
        let mut s1 = Session::new(engine, &artifact, store, mask, sched)?;
        s1.grad_clip = opts.train.grad_clip;
        run_steps(&mut s1, train_ds, opts.stage1_steps, batch, seq, seed ^ 1,
                  opts.verbose)?;
        stage1_losses = s1.losses.clone();
        store = s1.into_store();
    }

    // ---- main stage: the method's mask (paper Fig. 3b) ----
    let mask = method.main_mask(info)?;
    let lr = opts.lr_main.unwrap_or(method.lr_main);
    let sched = LrSchedule::warmup_decay(
        lr,
        (opts.main_steps as f32 * opts.warmup_frac) as u64,
        opts.main_steps as u64,
    );
    let artifact = Manifest::train_name(lk, method.group, model);
    let mut s2 = Session::new(engine, &artifact, store, mask, sched)?;
    s2.grad_clip = opts.train.grad_clip;
    let trainable_scalars = s2.trainable_scalars();
    run_steps(&mut s2, train_ds, opts.main_steps, batch, seq, seed ^ 2,
              opts.verbose)?;
    let main_losses = s2.losses.clone();
    let store = s2.into_store();

    // ---- evaluate ----
    let eval = evaluate(engine, model, &store, dev_ds)?;
    Ok(TuneResult {
        score: eval.score,
        eval: eval.clone(),
        stage1_losses,
        main_losses,
        trainable_scalars,
        adapter_scalars: method.adapter_params(info)?,
        param_fraction: method.param_fraction(info)?,
        store,
    })
}
