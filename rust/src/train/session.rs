//! TrainSession: the training hot path.
//!
//! Owns the backend-resident copy of the parameters. On each step it
//! uploads only the batch tensors (params are already resident), executes
//! the gradient-group artifact, applies masked AdamW on the host, and
//! re-uploads only the tensors that changed — for the Hadamard method that
//! is ~0.03% of the parameter bytes, which is what keeps its step cost
//! near the pure forward cost (EXPERIMENTS.md §Perf). The same contract
//! holds for both backends: device buffers for XLA, host tensors for the
//! native executor. Batch tensors go through `upload_owned`, so the
//! native backend wraps them without a second copy.

use anyhow::{bail, Context, Result};

use crate::data::{Batch, MlmBatch};
use crate::model::{FreezeMask, ParamStore};
use crate::optim::{AdamW, LrSchedule};
use crate::runtime::{ArtifactKind, DeviceTensor, Engine, IntTensor, Tensor};

/// Options shared by all training loops.
#[derive(Debug, Clone)]
pub struct TrainOpts {
    /// Examples per batch.
    pub batch: usize,
    /// Global-norm gradient clip (`<= 0` disables).
    pub grad_clip: f32,
    /// Progress-print cadence (steps).
    pub log_every: usize,
    /// Shuffling/init seed.
    pub seed: u64,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts { batch: 16, grad_clip: 1.0, log_every: 50, seed: 0 }
    }
}

/// A live training session against one artifact.
pub struct Session<'e> {
    engine: &'e Engine,
    /// Gradient-group artifact the session steps.
    pub artifact: String,
    store: ParamStore,
    /// Freeze mask selecting which gradients the optimizer applies.
    pub mask: FreezeMask,
    /// Optimizer state (masked AdamW).
    pub opt: AdamW,
    /// Learning-rate schedule.
    pub sched: LrSchedule,
    /// Global-norm gradient clip applied each step; `<= 0` disables.
    /// Defaults to [`TrainOpts::default`]'s 1.0; training pipelines wire
    /// their `TrainOpts::grad_clip` through here.
    pub grad_clip: f32,
    /// backend-resident parameters, canonical order.
    bufs: Vec<DeviceTensor>,
    /// (output index offset by 1 for loss, param index, trainable).
    grad_map: Vec<(usize, usize, bool)>,
    /// Per-step loss curve.
    pub losses: Vec<f32>,
}

impl<'e> Session<'e> {
    /// Open a session: validates the store and mask against the
    /// artifact's model, uploads all parameters once (resident for the
    /// session's lifetime) and maps gradient outputs to parameters.
    pub fn new(
        engine: &'e Engine,
        artifact: &str,
        store: ParamStore,
        mask: FreezeMask,
        sched: LrSchedule,
    ) -> Result<Self> {
        let info = engine.manifest().artifact(artifact)?.clone();
        let model = engine.manifest().model(&info.model)?;
        store
            .check_against(model)
            .context("store/manifest mismatch")?;
        if mask.trainable.len() != store.len() {
            bail!("mask length mismatch");
        }
        // map grad outputs -> param indices
        let mut grad_map = Vec::new();
        for (gi, gname) in info.grad_params().iter().enumerate() {
            let pi = model.param_index(gname)?;
            grad_map.push((gi + 1, pi, mask.is_trainable(pi)));
        }
        // Every trainable param must receive a gradient from this artifact.
        for (pi, &t) in mask.trainable.iter().enumerate() {
            if t && !grad_map.iter().any(|&(_, p, _)| p == pi) {
                bail!(
                    "trainable parameter '{}' gets no gradient from artifact '{artifact}'",
                    store.names[pi]
                );
            }
        }
        let bufs = store
            .tensors
            .iter()
            .map(|t| engine.upload(t))
            .collect::<Result<Vec<_>>>()?;
        Ok(Session {
            engine,
            artifact: artifact.to_string(),
            store,
            mask,
            opt: AdamW::paper_defaults(),
            sched,
            grad_clip: TrainOpts::default().grad_clip,
            bufs,
            grad_map,
            losses: Vec::new(),
        })
    }

    /// The session's current (host-side) parameters.
    pub fn store(&self) -> &ParamStore {
        &self.store
    }

    /// Consume the session, keeping the tuned parameters.
    pub fn into_store(self) -> ParamStore {
        self.store
    }

    /// Number of trainable scalars (perf + paper accounting).
    pub fn trainable_scalars(&self) -> usize {
        self.store
            .tensors
            .iter()
            .zip(&self.mask.trainable)
            .filter(|(_, &t)| t)
            .map(|(t, _)| t.numel())
            .sum()
    }

    /// Execute one step given pre-built batch tensors, then update + resync.
    fn step_inner(&mut self, batch_bufs: Vec<DeviceTensor>) -> Result<f32> {
        let mut inputs: Vec<&DeviceTensor> =
            Vec::with_capacity(self.bufs.len() + batch_bufs.len());
        inputs.extend(self.bufs.iter());
        inputs.extend(batch_bufs.iter());
        let mut outs = self.engine.run(&self.artifact, &inputs)?;
        drop(inputs);
        let loss = outs[0].data[0];

        // gather trainable grads (moved out of the dead output list — no
        // copies on the hot path even for backbone-sized groups)
        let mut grads: Vec<(usize, Vec<f32>)> = Vec::new();
        for &(oi, pi, trainable) in &self.grad_map {
            if trainable {
                grads.push((pi, std::mem::take(&mut outs[oi].data)));
            }
        }
        // global-norm clip
        let clip = self.grad_clip;
        let scale = if clip > 0.0 {
            let sq: f32 = grads
                .iter()
                .flat_map(|(_, g)| g.iter())
                .map(|x| x * x)
                .sum();
            let norm = sq.sqrt();
            if norm > clip && norm > 0.0 { clip / norm } else { 1.0 }
        } else {
            1.0
        };

        self.opt.next_step();
        let lr = self.sched.at(self.opt.step_count() - 1);
        for (pi, mut g) in grads {
            if scale != 1.0 {
                for x in g.iter_mut() {
                    *x *= scale;
                }
            }
            let name = self.store.names[pi].clone();
            self.opt
                .update(&name, &mut self.store.tensors[pi].data, &g, lr);
            // re-upload only what changed
            self.bufs[pi] = self.engine.upload(&self.store.tensors[pi])?;
        }
        self.losses.push(loss);
        Ok(loss)
    }

    /// One classification step.
    pub fn step_cls(&mut self, batch: &Batch, class_mask: &[f32]) -> Result<f32> {
        let kind = self.engine.manifest().artifact(&self.artifact)?.kind;
        if kind != ArtifactKind::Train {
            bail!("artifact '{}' is not a train artifact", self.artifact);
        }
        let b = batch.size;
        let s = batch.seq;
        let bufs = vec![
            self.engine
                .upload_int_owned(IntTensor::new(vec![b, s], batch.tokens.clone())?)?,
            self.engine
                .upload_int_owned(IntTensor::new(vec![b, s], batch.type_ids.clone())?)?,
            self.engine
                .upload_owned(Tensor::new(vec![b, s], batch.attn_mask.clone())?)?,
            self.engine
                .upload_owned(Tensor::new(vec![b, 3], batch.labels_onehot.clone())?)?,
            self.engine
                .upload_owned(Tensor::new(vec![3], class_mask.to_vec())?)?,
        ];
        self.step_inner(bufs)
    }

    /// One regression step (STS-B).
    pub fn step_reg(&mut self, batch: &Batch) -> Result<f32> {
        let b = batch.size;
        let s = batch.seq;
        let bufs = vec![
            self.engine
                .upload_int_owned(IntTensor::new(vec![b, s], batch.tokens.clone())?)?,
            self.engine
                .upload_int_owned(IntTensor::new(vec![b, s], batch.type_ids.clone())?)?,
            self.engine
                .upload_owned(Tensor::new(vec![b, s], batch.attn_mask.clone())?)?,
            self.engine
                .upload_owned(Tensor::new(vec![b], batch.labels_f32.clone())?)?,
        ];
        self.step_inner(bufs)
    }

    /// One MLM pre-training step.
    pub fn step_mlm(&mut self, batch: &MlmBatch, b: usize, s: usize) -> Result<f32> {
        let bufs = vec![
            self.engine
                .upload_int_owned(IntTensor::new(vec![b, s], batch.tokens.clone())?)?,
            self.engine
                .upload_int_owned(IntTensor::new(vec![b, s], batch.type_ids.clone())?)?,
            self.engine
                .upload_owned(Tensor::new(vec![b, s], batch.attn_mask.clone())?)?,
            self.engine
                .upload_int_owned(IntTensor::new(vec![b, s], batch.labels.clone())?)?,
            self.engine
                .upload_owned(Tensor::new(vec![b, s], batch.loss_mask.clone())?)?,
        ];
        self.step_inner(bufs)
    }

    /// Raw gradient read-back for the analysis module (Table 1): executes
    /// one step *without* updating, returning (loss, per-grad-param L1
    /// norms in artifact output order).
    pub fn probe_gradients(
        &mut self,
        batch: &Batch,
        class_mask: &[f32],
    ) -> Result<(f32, Vec<(String, f64)>)> {
        let b = batch.size;
        let s = batch.seq;
        let batch_bufs = vec![
            self.engine
                .upload_int_owned(IntTensor::new(vec![b, s], batch.tokens.clone())?)?,
            self.engine
                .upload_int_owned(IntTensor::new(vec![b, s], batch.type_ids.clone())?)?,
            self.engine
                .upload_owned(Tensor::new(vec![b, s], batch.attn_mask.clone())?)?,
            self.engine
                .upload_owned(Tensor::new(vec![b, 3], batch.labels_onehot.clone())?)?,
            self.engine
                .upload_owned(Tensor::new(vec![3], class_mask.to_vec())?)?,
        ];
        let mut inputs: Vec<&DeviceTensor> = Vec::new();
        inputs.extend(self.bufs.iter());
        inputs.extend(batch_bufs.iter());
        let outs = self.engine.run(&self.artifact, &inputs)?;
        let loss = outs[0].data[0];
        let mut norms = Vec::new();
        let info = self.engine.manifest().artifact(&self.artifact)?.clone();
        for (gi, gname) in info.grad_params().iter().enumerate() {
            let l1: f64 = outs[gi + 1].data.iter().map(|x| x.abs() as f64).sum();
            norms.push((gname.to_string(), l1));
        }
        Ok((loss, norms))
    }
}
