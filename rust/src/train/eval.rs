//! Evaluation: run the forward artifact over a dev split and score the
//! task's headline metric. Also returns the Fig. 1/2 probe statistics
//! (per-layer attention-output norms and adapter-output means).

use anyhow::Result;

use crate::data::{class_mask, BatchIter, Dataset, Label};
use crate::metrics::task_score;
use crate::model::ParamStore;
use crate::runtime::{DeviceTensor, Engine, IntTensor, Manifest, Tensor};

/// Aggregated evaluation output.
#[derive(Debug, Clone)]
pub struct EvalResult {
    /// headline metric on the paper's 0-100 scale.
    pub score: f64,
    /// Predicted class per classification example.
    pub preds: Vec<usize>,
    /// Gold class per classification example.
    pub golds: Vec<usize>,
    /// Predicted score per regression example.
    pub pred_scores: Vec<f32>,
    /// Gold score per regression example.
    pub gold_scores: Vec<f32>,
    /// per-layer attention-output spectral norms, all examples ([layer][i]).
    pub attn_norms: Vec<Vec<f32>>,
    /// per-layer adapter-output means (the Fig. 2 characteristic values).
    pub attn_means: Vec<Vec<f32>>,
    /// Real examples evaluated (batch padding excluded).
    pub examples: usize,
}

/// Evaluate `store` on a dataset with the model's forward artifact.
pub fn evaluate(
    engine: &Engine,
    model: &str,
    store: &ParamStore,
    ds: &Dataset,
) -> Result<EvalResult> {
    let m = engine.manifest().model(model)?;
    let layers = m.layers;
    let artifact = Manifest::fwd_name(model);
    let batch = engine.manifest().batch;
    let seq = engine.manifest().seq_len;
    let cmask = class_mask(ds.info.classes);

    // params uploaded once for the whole eval
    let param_bufs: Vec<DeviceTensor> = store
        .tensors
        .iter()
        .map(|t| engine.upload(t))
        .collect::<Result<Vec<_>>>()?;

    let mut out = EvalResult {
        score: 0.0,
        preds: Vec::new(),
        golds: Vec::new(),
        pred_scores: Vec::new(),
        gold_scores: Vec::new(),
        attn_norms: vec![Vec::new(); layers],
        attn_means: vec![Vec::new(); layers],
        examples: 0,
    };

    for b in BatchIter::sequential(ds, batch, seq) {
        let batch_bufs = vec![
            engine.upload_int_owned(IntTensor::new(vec![batch, seq], b.tokens.clone())?)?,
            engine.upload_int_owned(IntTensor::new(vec![batch, seq], b.type_ids.clone())?)?,
            engine.upload_owned(Tensor::new(vec![batch, seq], b.attn_mask.clone())?)?,
        ];
        let mut inputs: Vec<&DeviceTensor> = Vec::new();
        inputs.extend(param_bufs.iter());
        inputs.extend(batch_bufs.iter());
        let outs = engine.run(&artifact, &inputs)?;
        let logits = &outs[0].data; // [B, 3]
        let regression = &outs[1].data; // [B]
        let norms = &outs[2].data; // [B, layers]
        let means = &outs[3].data; // [B, layers]

        for i in 0..b.real {
            let e = &ds.examples[out.examples + i];
            match e.label {
                Label::Class(c) => {
                    let row = &logits[i * 3..i * 3 + 3];
                    let mut best = 0;
                    let mut bestv = f32::MIN;
                    for (c2, (&l, &m2)) in row.iter().zip(cmask.iter()).enumerate() {
                        if m2 > 0.5 && l > bestv {
                            bestv = l;
                            best = c2;
                        }
                    }
                    out.preds.push(best);
                    out.golds.push(c);
                }
                Label::Score(s) => {
                    out.pred_scores.push(regression[i]);
                    out.gold_scores.push(s);
                }
            }
            for l in 0..layers {
                out.attn_norms[l].push(norms[i * layers + l]);
                out.attn_means[l].push(means[i * layers + l]);
            }
        }
        out.examples += b.real;
    }

    out.score = task_score(
        ds.info.metric,
        &out.preds,
        &out.golds,
        &out.pred_scores,
        &out.gold_scores,
    );
    Ok(out)
}
