//! Coordinator: the experiment orchestration layer.
//!
//! An experiment is a set of (model, task, method) runs. The coordinator
//! owns the engine + pre-trained backbones, schedules the runs, persists
//! every completed run to a JSON cache under `results/runs/`, and resumes
//! by skipping cached runs — re-running a table after an interruption only
//! costs the missing cells.

use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::Config;
use crate::data::{generate, task_info, Dataset};
use crate::methods::Method;
use crate::model::ParamStore;
use crate::runtime::{Engine, TaskAdapter};
use crate::train::{load_or_pretrain, tune, TuneOpts, TuneResult};
use crate::util::json::Json;

/// One scheduled run.
#[derive(Debug, Clone)]
pub struct RunSpec {
    /// Model size to run.
    pub model: String,
    /// Task to tune on.
    pub task: String,
    /// Method registry name (may carry ablation decorations).
    pub method: String,
    /// Seed for data and initialization.
    pub seed: u64,
}

impl RunSpec {
    /// Stable cache id, injective in the method string.
    ///
    /// The readable slug flattens punctuation to `-`, which is not
    /// injective (`had+ln` and `had^ln` used to collide on the same
    /// `results/runs/` file and silently resume the wrong run), so a
    /// stable FNV-1a hash of the *raw* method string disambiguates the
    /// file name while keeping it filesystem-safe and human-scannable.
    pub fn id(&self, opts: &TuneOpts) -> String {
        let slug: String = self
            .method
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '-' })
            .collect();
        format!(
            "{}_{}_{}-{:016x}_s{}_t{}x{}",
            self.model,
            self.task,
            slug,
            crate::util::fnv1a(&self.method),
            self.seed,
            opts.stage1_steps,
            opts.main_steps
        )
    }
}

/// A completed run's persisted summary.
#[derive(Debug, Clone)]
pub struct RunRecord {
    /// The run's specification.
    pub spec: RunSpec,
    /// Dev-set score (paper scale).
    pub score: f64,
    /// Scalars trained in the main stage.
    pub trainable_scalars: usize,
    /// Adapter-only scalars (paper's headline numerator).
    pub adapter_scalars: usize,
    /// `adapter_scalars` over the backbone total.
    pub param_fraction: f64,
    /// Wall-clock seconds the run took.
    pub wall_secs: f64,
    /// Final stage-1 loss, when stage 1 ran.
    pub stage1_final_loss: Option<f64>,
    /// Final main-stage loss.
    pub main_final_loss: Option<f64>,
}

impl RunRecord {
    /// Serialize for the run cache.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("model", Json::str(&self.spec.model))
            .set("task", Json::str(&self.spec.task))
            .set("method", Json::str(&self.spec.method))
            .set("seed", Json::num(self.spec.seed as f64))
            .set("score", Json::num(self.score))
            .set("trainable_scalars", Json::num(self.trainable_scalars as f64))
            .set("adapter_scalars", Json::num(self.adapter_scalars as f64))
            .set("param_fraction", Json::num(self.param_fraction))
            .set("wall_secs", Json::num(self.wall_secs));
        if let Some(l) = self.stage1_final_loss {
            j.set("stage1_final_loss", Json::num(l));
        }
        if let Some(l) = self.main_final_loss {
            j.set("main_final_loss", Json::num(l));
        }
        j
    }

    /// Deserialize a cached run record.
    pub fn from_json(j: &Json) -> Result<RunRecord> {
        Ok(RunRecord {
            spec: RunSpec {
                model: j.get("model")?.as_str()?.into(),
                task: j.get("task")?.as_str()?.into(),
                method: j.get("method")?.as_str()?.into(),
                seed: j.get("seed")?.as_f64()? as u64,
            },
            score: j.get("score")?.as_f64()?,
            trainable_scalars: j.get("trainable_scalars")?.as_usize()?,
            adapter_scalars: j.get("adapter_scalars")?.as_usize()?,
            param_fraction: j.get("param_fraction")?.as_f64()?,
            wall_secs: j.get("wall_secs")?.as_f64()?,
            stage1_final_loss: j
                .opt("stage1_final_loss")
                .and_then(|v| v.as_f64().ok()),
            main_final_loss: j.opt("main_final_loss").and_then(|v| v.as_f64().ok()),
        })
    }
}

/// The coordinator.
pub struct Coordinator {
    /// The engine all runs share.
    pub engine: Engine,
    /// Effective configuration.
    pub config: Config,
    backbones: HashMap<(String, u64), ParamStore>,
    datasets: HashMap<(String, String), Dataset>,
}

impl Coordinator {
    /// A coordinator over the config's engine.
    pub fn new(config: Config) -> Result<Self> {
        let engine = config.engine()?;
        Ok(Coordinator {
            engine,
            config,
            backbones: HashMap::new(),
            datasets: HashMap::new(),
        })
    }

    fn runs_dir(&self) -> PathBuf {
        self.config.results_dir.join("runs")
    }

    /// Pre-trained backbone for a model (cached in memory + on disk).
    pub fn backbone(&mut self, model: &str) -> Result<&ParamStore> {
        let key = (model.to_string(), self.config.seed);
        if !self.backbones.contains_key(&key) {
            let opts = self.config.pretrain_opts();
            let store = load_or_pretrain(
                &self.engine,
                model,
                &self.config.checkpoints_dir,
                &opts,
            )?;
            self.backbones.insert(key.clone(), store);
        }
        Ok(&self.backbones[&key])
    }

    /// Dataset split (cached).
    pub fn dataset(&mut self, task: &str, split: &str) -> Result<&Dataset> {
        let key = (task.to_string(), split.to_string());
        if !self.datasets.contains_key(&key) {
            let info = task_info(task)
                .with_context(|| format!("unknown task '{task}'"))?;
            let size = if split == "train" {
                if self.config.quick { 256 } else { info.train_size }
            } else if self.config.quick {
                128
            } else {
                info.dev_size
            };
            let ds = generate(info, self.config.seed, split, size);
            self.datasets.insert(key.clone(), ds);
        }
        Ok(&self.datasets[&key])
    }

    /// Fetch an already-cached backbone without triggering pre-training.
    pub fn backbones_get(&self, model: &str) -> Option<&ParamStore> {
        self.backbones.get(&(model.to_string(), self.config.seed))
    }

    /// Fetch an already-cached dataset split.
    pub fn datasets_get(&self, task: &str, split: &str) -> Option<&Dataset> {
        self.datasets.get(&(task.to_string(), split.to_string()))
    }

    /// Run (or fetch from cache) one (model, task, method) cell.
    pub fn run(&mut self, spec: &RunSpec) -> Result<RunRecord> {
        let opts = {
            let mut t = self.config.tune_opts();
            t.train.seed = spec.seed;
            t
        };
        let id = spec.id(&opts);
        let cache_path = self.runs_dir().join(format!("{id}.json"));
        if cache_path.exists() {
            let j = crate::util::json::parse(&std::fs::read_to_string(&cache_path)?)?;
            return RunRecord::from_json(&j);
        }
        let (rec, result) = self.run_uncached(spec, &opts)?;
        std::fs::create_dir_all(self.runs_dir())?;
        result.store.save(self.runs_dir().join(format!("{id}.ckpt")))?;
        std::fs::write(&cache_path, rec.to_json().render_pretty())?;
        Ok(rec)
    }

    /// Like [`Coordinator::run`], but also returns the tuned parameter
    /// store (loaded from the run cache when available) — what the
    /// analysis drivers (Fig 1/2/5) need.
    pub fn run_with_store(&mut self, spec: &RunSpec) -> Result<(RunRecord, ParamStore)> {
        let opts = {
            let mut t = self.config.tune_opts();
            t.train.seed = spec.seed;
            t
        };
        let id = spec.id(&opts);
        let ckpt_path = self.runs_dir().join(format!("{id}.ckpt"));
        let rec = self.run(spec)?;
        if ckpt_path.exists() {
            let store = ParamStore::load(&ckpt_path)?;
            store.check_against(self.engine.manifest().model(&spec.model)?)?;
            return Ok((rec, store));
        }
        // cache predates store persistence: re-run once to materialize it
        let (rec, result) = self.run_uncached(spec, &opts)?;
        result.store.save(&ckpt_path)?;
        Ok((rec, result.store))
    }

    /// Run without the cache, returning the full TuneResult (analysis
    /// drivers need the tuned store).
    pub fn run_uncached(
        &mut self,
        spec: &RunSpec,
        opts: &TuneOpts,
    ) -> Result<(RunRecord, TuneResult)> {
        let method = Method::by_name(&spec.method)?;
        self.backbone(&spec.model)?;
        self.dataset(&spec.task, "train")?;
        self.dataset(&spec.task, "dev")?;
        let backbone = &self.backbones[&(spec.model.clone(), self.config.seed)];
        let train_ds = &self.datasets[&(spec.task.clone(), "train".to_string())];
        let dev_ds = &self.datasets[&(spec.task.clone(), "dev".to_string())];

        let t0 = Instant::now();
        let result = tune(
            &self.engine,
            &spec.model,
            backbone,
            train_ds,
            dev_ds,
            &method,
            opts,
        )?;
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "  run {}/{}/{}: score {:.1} ({:.1}s, {} trainable)",
            spec.model, spec.task, spec.method, result.score, wall,
            result.trainable_scalars
        );
        let rec = RunRecord {
            spec: spec.clone(),
            score: result.score,
            trainable_scalars: result.trainable_scalars,
            adapter_scalars: result.adapter_scalars,
            param_fraction: result.param_fraction,
            wall_secs: wall,
            stage1_final_loss: result.stage1_losses.last().map(|&x| x as f64),
            main_final_loss: result.main_losses.last().map(|&x| x as f64),
        };
        Ok((rec, result))
    }

    /// Train (or fetch from the run cache) one `(model, task, method)`
    /// cell and distill its tuned store into a serve-ready adapter-bank
    /// entry — the bridge from the experiment harness to the multi-tenant
    /// serve path (`runtime::serve`): a few-KB [`TaskAdapter`] that a
    /// [`crate::runtime::ServeSession`] hot-registers against the shared
    /// frozen backbone.
    pub fn export_adapter(&mut self, spec: &RunSpec) -> Result<TaskAdapter> {
        let (_rec, store) = self.run_with_store(spec)?;
        let classes = task_info(&spec.task)
            .with_context(|| format!("unknown task '{}'", spec.task))?
            .classes;
        let info = self.engine.manifest().model(&spec.model)?;
        TaskAdapter::from_store(info, &store, &spec.task, classes)
    }

    /// Run a whole grid, returning records keyed (model, task, method).
    pub fn run_grid(
        &mut self,
        models: &[String],
        tasks: &[&str],
        methods: &[&str],
    ) -> Result<Vec<RunRecord>> {
        let mut out = Vec::new();
        let total = models.len() * tasks.len() * methods.len();
        let mut done = 0;
        for model in models {
            for task in tasks {
                for method in methods {
                    done += 1;
                    println!("[{done}/{total}] {model}/{task}/{method}");
                    out.push(self.run(&RunSpec {
                        model: model.clone(),
                        task: task.to_string(),
                        method: method.to_string(),
                        seed: self.config.seed,
                    })?);
                }
            }
        }
        Ok(out)
    }
}

/// Index run records for table assembly.
pub fn index_records<'a>(
    recs: &'a [RunRecord],
) -> HashMap<(String, String, String), &'a RunRecord> {
    recs.iter()
        .map(|r| {
            (
                (
                    r.spec.model.clone(),
                    r.spec.task.clone(),
                    r.spec.method.clone(),
                ),
                r,
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_json_roundtrip() {
        let rec = RunRecord {
            spec: RunSpec {
                model: "base".into(),
                task: "sst2".into(),
                method: "hadamard".into(),
                seed: 7,
            },
            score: 91.25,
            trainable_scalars: 1234,
            adapter_scalars: 1000,
            param_fraction: 0.00033,
            wall_secs: 12.5,
            stage1_final_loss: Some(0.4),
            main_final_loss: Some(0.2),
        };
        let j = rec.to_json();
        let back = RunRecord::from_json(&j).unwrap();
        assert_eq!(back.spec.model, "base");
        assert_eq!(back.score, 91.25);
        assert_eq!(back.adapter_scalars, 1000);
        assert_eq!(back.stage1_final_loss, Some(0.4));
    }

    #[test]
    fn run_id_stable_and_distinct() {
        let opts = TuneOpts::default();
        let a = RunSpec {
            model: "base".into(),
            task: "sst2".into(),
            method: "hadamard".into(),
            seed: 1,
        };
        let b = RunSpec { method: "hadamard:B+N".into(), ..a.clone() };
        assert_eq!(a.id(&opts), a.id(&opts));
        assert_ne!(a.id(&opts), b.id(&opts));
        // ids are filesystem-safe
        assert!(!b.id(&opts).contains('+'));
        assert!(!b.id(&opts).contains(':'));
    }

    #[test]
    fn run_id_does_not_collide_on_flattened_punctuation() {
        // regression: '[',']','+','^','@' all flattened to '-', so methods
        // that differ only in punctuation shared one cache file
        let opts = TuneOpts::default();
        let base = RunSpec {
            model: "base".into(),
            task: "sst2".into(),
            method: String::new(),
            seed: 1,
        };
        let methods = [
            "had+ln", "had^ln", "had@ln", "had[ln]", "had-ln", "had_ln", "had.ln",
        ];
        let mut ids = std::collections::HashSet::new();
        for m in methods {
            let spec = RunSpec { method: m.into(), ..base.clone() };
            let id = spec.id(&opts);
            assert!(
                ids.insert(id.clone()),
                "method '{m}' collided on cache id {id}"
            );
            // filesystem-safe: alphanumerics, '-', '_', 'x' separators only
            assert!(
                id.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_'),
                "unsafe char in id {id}"
            );
        }
    }
}
