//! Reporting substrate: markdown tables, CSV, and text box-plot summaries
//! (the figures are emitted as five-number summaries + CSV series since the
//! harness is terminal-only).

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

/// A simple column-aligned markdown table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    /// Table caption.
    pub title: String,
    /// Column headers.
    pub header: Vec<String>,
    /// Rows of rendered cells.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Start a table with a caption and column headers.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row of cells.
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
        self
    }

    /// Render as column-aligned markdown.
    pub fn render(&self) -> String {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "### {}\n", self.title);
        }
        let line = |cells: &[String], w: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, &width) in cells.iter().zip(w) {
                let _ = write!(s, " {c:<width$} |");
            }
            s
        };
        let _ = writeln!(out, "{}", line(&self.header, &w));
        let mut sep = String::from("|");
        for &width in &w {
            let _ = write!(sep, "{:-<1$}|", "", width + 2);
        }
        let _ = writeln!(out, "{sep}");
        for r in &self.rows {
            let _ = writeln!(out, "{}", line(r, &w));
        }
        out
    }

    /// Render as CSV (quotes cells containing commas).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Write both .md and .csv under `dir/name.{md,csv}`.
    pub fn save(&self, dir: impl AsRef<Path>, name: &str) -> Result<()> {
        std::fs::create_dir_all(dir.as_ref())?;
        std::fs::write(dir.as_ref().join(format!("{name}.md")), self.render())?;
        std::fs::write(dir.as_ref().join(format!("{name}.csv")), self.to_csv())?;
        Ok(())
    }
}

/// Five-number summary of a sample (box-plot rendering for the figures).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BoxStats {
    /// Sample minimum.
    pub min: f64,
    /// First quartile.
    pub q1: f64,
    /// Median.
    pub median: f64,
    /// Third quartile.
    pub q3: f64,
    /// Sample maximum.
    pub max: f64,
    /// Arithmetic mean.
    pub mean: f64,
}

impl BoxStats {
    /// Five-number summary (plus mean) of a sample.
    pub fn from(values: &[f32]) -> BoxStats {
        assert!(!values.is_empty());
        let mut v: Vec<f64> = values.iter().map(|&x| x as f64).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| -> f64 {
            let idx = p * (v.len() - 1) as f64;
            let lo = idx.floor() as usize;
            let hi = idx.ceil() as usize;
            if lo == hi {
                v[lo]
            } else {
                v[lo] + (v[hi] - v[lo]) * (idx - lo as f64)
            }
        };
        BoxStats {
            min: v[0],
            q1: q(0.25),
            median: q(0.5),
            q3: q(0.75),
            max: v[v.len() - 1],
            mean: v.iter().sum::<f64>() / v.len() as f64,
        }
    }

    /// Render the six statistics as table cells.
    pub fn cells(&self) -> Vec<String> {
        vec![
            format!("{:.3}", self.min),
            format!("{:.3}", self.q1),
            format!("{:.3}", self.median),
            format!("{:.3}", self.q3),
            format!("{:.3}", self.max),
            format!("{:.3}", self.mean),
        ]
    }

    /// Column headers matching [`BoxStats::cells`].
    pub const HEADER: [&'static str; 6] = ["min", "q1", "median", "q3", "max", "mean"];
}

/// Format a parameter count as the paper does ("0.033%").
pub fn pct(frac: f64) -> String {
    format!("{:.3}%", frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["task", "score"]);
        t.row(vec!["mrpc".into(), "90.2".into()]);
        t.row(vec!["cola-long-name".into(), "58.4".into()]);
        let md = t.render();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| mrpc"));
        let lines: Vec<&str> = md.lines().filter(|l| l.starts_with('|')).collect();
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn row_arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn boxstats_quartiles() {
        let s = BoxStats::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.q1, 2.0);
        assert_eq!(s.q3, 4.0);
        assert_eq!(s.mean, 3.0);
    }

    #[test]
    fn pct_formats_like_paper() {
        assert_eq!(pct(0.00033), "0.033%");
    }
}
