//! Optimizer layer: masked AdamW + learning-rate schedules + clipping.
//!
//! Gradients come back from the HLO artifacts; the optimizer runs on the
//! host over exactly the *trainable* tensors (the freeze mask). Moments are
//! allocated lazily per trainable tensor, so the Hadamard method's optimizer
//! state is as tiny as its parameter set — the systems half of the paper's
//! efficiency claim.

pub mod adamw;
pub mod schedule;

pub use adamw::AdamW;
pub use schedule::{LrSchedule, Schedule};

/// Global-norm gradient clipping. Returns the pre-clip norm.
pub fn clip_global_norm(grads: &mut [Vec<f32>], max_norm: f32) -> f32 {
    let sq: f32 = grads
        .iter()
        .flat_map(|g| g.iter())
        .map(|x| x * x)
        .sum();
    let norm = sq.sqrt();
    if norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        for g in grads.iter_mut() {
            for x in g.iter_mut() {
                *x *= scale;
            }
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clip_scales_to_max() {
        let mut g = vec![vec![3.0, 0.0], vec![0.0, 4.0]];
        let norm = clip_global_norm(&mut g, 1.0);
        assert!((norm - 5.0).abs() < 1e-6);
        let new_sq: f32 = g.iter().flatten().map(|x| x * x).sum();
        assert!((new_sq.sqrt() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn clip_noop_below_max() {
        let mut g = vec![vec![0.3, 0.4]];
        clip_global_norm(&mut g, 1.0);
        assert_eq!(g[0], vec![0.3, 0.4]);
    }
}
