//! AdamW with decoupled weight decay (the paper's optimizer settings:
//! beta1=0.9, beta2=0.999, weight decay 0.01).

use std::collections::HashMap;

/// AdamW state for a set of named tensors.
#[derive(Debug)]
pub struct AdamW {
    /// First-moment decay rate.
    pub beta1: f32,
    /// Second-moment decay rate.
    pub beta2: f32,
    /// Denominator epsilon.
    pub eps: f32,
    /// Decoupled weight-decay coefficient.
    pub weight_decay: f32,
    step: u64,
    /// name -> (m, v); allocated on first update of each tensor.
    moments: HashMap<String, (Vec<f32>, Vec<f32>)>,
}

impl AdamW {
    /// AdamW with standard betas/eps and the given weight decay.
    pub fn new(weight_decay: f32) -> Self {
        AdamW {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay,
            step: 0,
            moments: HashMap::new(),
        }
    }

    /// Paper defaults (Sec. 4.1).
    pub fn paper_defaults() -> Self {
        Self::new(0.01)
    }

    /// Steps taken so far (see [`AdamW::next_step`]).
    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// Bytes of optimizer state currently held (perf accounting).
    pub fn state_bytes(&self) -> usize {
        self.moments
            .values()
            .map(|(m, v)| (m.len() + v.len()) * 4)
            .sum()
    }

    /// Advance the shared step counter (call once per batch, before
    /// `update` calls for that batch).
    pub fn next_step(&mut self) {
        self.step += 1;
    }

    /// Apply one AdamW update to a tensor.
    /// Decay is decoupled and not applied to 1-D tensors (biases, norms,
    /// adapter vectors) — standard BERT practice.
    pub fn update(&mut self, name: &str, param: &mut [f32], grad: &[f32], lr: f32) {
        debug_assert_eq!(param.len(), grad.len());
        let (m, v) = self
            .moments
            .entry(name.to_string())
            .or_insert_with(|| (vec![0.0; param.len()], vec![0.0; param.len()]));
        let t = self.step.max(1) as i32;
        let bc1 = 1.0 - self.beta1.powi(t);
        let bc2 = 1.0 - self.beta2.powi(t);
        let decay = if name.ends_with(".weight") && !name.contains("LayerNorm")
            && !name.contains("hadamard")
        {
            self.weight_decay
        } else {
            0.0
        };
        for i in 0..param.len() {
            m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * grad[i];
            v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * grad[i] * grad[i];
            let mh = m[i] / bc1;
            let vh = v[i] / bc2;
            param[i] -= lr * (mh / (vh.sqrt() + self.eps) + decay * param[i]);
        }
    }

    /// Drop all moments (used when switching stages).
    pub fn reset(&mut self) {
        self.step = 0;
        self.moments.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_on_quadratic() {
        // minimize f(x) = (x - 3)^2 => grad = 2(x - 3)
        let mut opt = AdamW::new(0.0);
        let mut x = vec![0.0f32];
        for _ in 0..800 {
            opt.next_step();
            let g = vec![2.0 * (x[0] - 3.0)];
            opt.update("x.bias", &mut x, &g, 0.05);
        }
        assert!((x[0] - 3.0).abs() < 0.05, "x={}", x[0]);
    }

    #[test]
    fn bias_correction_first_step() {
        // After one step with unit gradient, update ≈ lr regardless of betas.
        let mut opt = AdamW::new(0.0);
        let mut x = vec![1.0f32];
        opt.next_step();
        opt.update("x.bias", &mut x, &[1.0], 0.1);
        assert!((x[0] - 0.9).abs() < 1e-4, "x={}", x[0]);
    }

    #[test]
    fn decay_applies_only_to_2d_weights() {
        let mut opt = AdamW::new(0.1);
        let mut w = vec![1.0f32];
        let mut b = vec![1.0f32];
        let mut ln = vec![1.0f32];
        let mut had = vec![1.0f32];
        opt.next_step();
        opt.update("enc.dense.weight", &mut w, &[0.0], 0.1);
        opt.update("enc.dense.bias", &mut b, &[0.0], 0.1);
        opt.update("enc.LayerNorm.weight", &mut ln, &[0.0], 0.1);
        opt.update("enc.hadamard.weight", &mut had, &[0.0], 0.1);
        assert!(w[0] < 1.0);
        assert_eq!(b[0], 1.0);
        assert_eq!(ln[0], 1.0);
        assert_eq!(had[0], 1.0);
    }

    #[test]
    fn state_allocated_lazily() {
        let mut opt = AdamW::new(0.0);
        assert_eq!(opt.state_bytes(), 0);
        let mut x = vec![0.0f32; 10];
        opt.next_step();
        opt.update("a.bias", &mut x, &[1.0; 10], 0.1);
        assert_eq!(opt.state_bytes(), 10 * 2 * 4);
    }

    #[test]
    fn reset_clears() {
        let mut opt = AdamW::new(0.0);
        let mut x = vec![0.0f32; 4];
        opt.next_step();
        opt.update("a.bias", &mut x, &[1.0; 4], 0.1);
        opt.reset();
        assert_eq!(opt.state_bytes(), 0);
        assert_eq!(opt.step_count(), 0);
    }
}
