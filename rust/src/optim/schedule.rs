//! Learning-rate schedules (linear warmup + linear decay, constant).

/// Schedule kind.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    /// Constant learning rate.
    Constant,
    /// Linear warmup for `warmup` steps then linear decay to zero at
    /// `total` steps (BERT fine-tuning standard).
    LinearWarmupDecay { warmup: u64, total: u64 },
}

/// A schedule bound to a base learning rate.
#[derive(Debug, Clone, Copy)]
pub struct LrSchedule {
    /// Base (peak) learning rate.
    pub base: f32,
    /// Shape of the schedule.
    pub kind: Schedule,
}

impl LrSchedule {
    /// Constant schedule at `base`.
    pub fn constant(base: f32) -> Self {
        LrSchedule { base, kind: Schedule::Constant }
    }

    /// Linear warmup to `base` over `warmup` steps, then linear decay
    /// to zero at `total`.
    pub fn warmup_decay(base: f32, warmup: u64, total: u64) -> Self {
        LrSchedule {
            base,
            kind: Schedule::LinearWarmupDecay { warmup, total },
        }
    }

    /// Learning rate at a zero-indexed step.
    pub fn at(&self, step: u64) -> f32 {
        match self.kind {
            Schedule::Constant => self.base,
            Schedule::LinearWarmupDecay { warmup, total } => {
                if warmup > 0 && step < warmup {
                    self.base * (step as f32 + 1.0) / warmup as f32
                } else if step >= total {
                    0.0
                } else {
                    let rest = (total - warmup).max(1) as f32;
                    self.base * (total - step) as f32 / rest
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::constant(0.01);
        assert_eq!(s.at(0), 0.01);
        assert_eq!(s.at(10_000), 0.01);
    }

    #[test]
    fn warmup_ramps_then_decays() {
        let s = LrSchedule::warmup_decay(1.0, 10, 110);
        assert!(s.at(0) < 0.2);
        assert!((s.at(9) - 1.0).abs() < 1e-6);
        assert!(s.at(10) <= 1.0);
        assert!(s.at(60) < s.at(10));
        assert_eq!(s.at(110), 0.0);
        assert_eq!(s.at(200), 0.0);
    }

    #[test]
    fn monotone_decay_after_warmup() {
        let s = LrSchedule::warmup_decay(3e-3, 20, 200);
        let mut prev = f32::MAX;
        for step in 20..200 {
            let lr = s.at(step);
            assert!(lr <= prev + 1e-9);
            prev = lr;
        }
    }
}
