//! Configuration system: workspace paths + experiment budgets, loadable
//! from a JSON file with CLI `key=value` overrides.
//!
//! All experiment drivers consume a `Config`, so one `--quick` flag or one
//! `hadapt.json` swaps the whole suite between smoke-scale and full-scale.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::runtime::Engine;
use crate::train::{PretrainOpts, TuneOpts};
use crate::util::json::{self, Json};

/// Global workspace configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// artifact executor: "native" (pure Rust, default) or "xla" (PJRT,
    /// requires `--features xla` and `make artifacts`).
    pub backend: String,
    /// AOT artifacts directory (manifest + HLO files).
    pub artifacts_dir: PathBuf,
    /// Pre-trained backbone checkpoint directory.
    pub checkpoints_dir: PathBuf,
    /// Experiment output directory (run cache, tables, figures).
    pub results_dir: PathBuf,
    /// models to sweep in experiments ("base", "large").
    pub models: Vec<String>,
    /// native-kernel worker threads: 0 = auto-detect (one per core; the
    /// `HADAPT_THREADS` env var overrides auto-detection, which is how CI
    /// forces a serial second test run), 1 = single-threaded
    /// (bit-reproducible across machines). The pool keeps `threads - 1`
    /// persistent parked workers; they spawn once on first use and join
    /// when the engine drops.
    pub threads: usize,
    /// pack frozen backbone GEMM weights into SIMD-aligned panels once at
    /// first use (native backend; on by default — turn off to A/B the
    /// plain blocked kernels).
    pub packing: bool,
    /// master seed.
    pub seed: u64,
    /// pre-training steps per backbone.
    pub pretrain_steps: usize,
    /// Pre-training peak learning rate.
    pub pretrain_lr: f32,
    /// two-stage budgets.
    pub stage1_steps: usize,
    /// Main-stage steps.
    pub main_steps: usize,
    /// quick mode: tiny budgets for smoke-testing the whole suite.
    pub quick: bool,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            backend: "native".into(),
            artifacts_dir: "artifacts".into(),
            checkpoints_dir: "checkpoints".into(),
            results_dir: "results".into(),
            models: vec!["base".into()],
            threads: 0,
            packing: true,
            seed: 1234,
            pretrain_steps: 1500,
            pretrain_lr: 1e-3,
            stage1_steps: 120,
            main_steps: 140,
            quick: false,
        }
    }
}

impl Config {
    /// Load from JSON file if it exists, else defaults.
    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        let mut cfg = Config::default();
        if path.as_ref().exists() {
            let text = std::fs::read_to_string(path)?;
            cfg.apply_json(&json::parse(&text)?)?;
        }
        Ok(cfg)
    }

    /// Apply a parsed JSON config on top of the current values.
    pub fn apply_json(&mut self, j: &Json) -> Result<()> {
        if let Some(v) = j.opt("backend") {
            self.backend = v.as_str()?.into();
        }
        if let Some(v) = j.opt("artifacts_dir") {
            self.artifacts_dir = v.as_str()?.into();
        }
        if let Some(v) = j.opt("checkpoints_dir") {
            self.checkpoints_dir = v.as_str()?.into();
        }
        if let Some(v) = j.opt("results_dir") {
            self.results_dir = v.as_str()?.into();
        }
        if let Some(v) = j.opt("models") {
            self.models = v.str_vec()?;
        }
        if let Some(v) = j.opt("threads") {
            self.threads = v.as_usize()?;
        }
        if let Some(v) = j.opt("packing") {
            self.packing = v.as_bool()?;
        }
        if let Some(v) = j.opt("seed") {
            self.seed = v.as_f64()? as u64;
        }
        if let Some(v) = j.opt("pretrain_steps") {
            self.pretrain_steps = v.as_usize()?;
        }
        if let Some(v) = j.opt("pretrain_lr") {
            self.pretrain_lr = v.as_f64()? as f32;
        }
        if let Some(v) = j.opt("stage1_steps") {
            self.stage1_steps = v.as_usize()?;
        }
        if let Some(v) = j.opt("main_steps") {
            self.main_steps = v.as_usize()?;
        }
        if let Some(v) = j.opt("quick") {
            self.quick = v.as_bool()?;
        }
        Ok(())
    }

    /// Apply a CLI `key=value` override.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "backend" => self.backend = value.into(),
            "artifacts_dir" => self.artifacts_dir = value.into(),
            "checkpoints_dir" => self.checkpoints_dir = value.into(),
            "results_dir" => self.results_dir = value.into(),
            "models" => {
                self.models = value.split(',').map(String::from).collect()
            }
            "threads" => self.threads = value.parse()?,
            "packing" => self.packing = value.parse()?,
            "seed" => self.seed = value.parse()?,
            "pretrain_steps" => self.pretrain_steps = value.parse()?,
            "pretrain_lr" => self.pretrain_lr = value.parse()?,
            "stage1_steps" => self.stage1_steps = value.parse()?,
            "main_steps" => self.main_steps = value.parse()?,
            "quick" => self.quick = value.parse()?,
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Build the engine this config selects (`backend` + `artifacts_dir`).
    /// The single constructor every entry point (CLI commands, the
    /// coordinator) goes through, so `--set backend=...` behaves the same
    /// everywhere.
    pub fn engine(&self) -> Result<Engine> {
        match self.backend.as_str() {
            "native" => {
                Engine::new_with_opts(&self.artifacts_dir, self.threads, self.packing)
            }
            #[cfg(feature = "xla")]
            "xla" => Engine::xla(&self.artifacts_dir),
            #[cfg(not(feature = "xla"))]
            "xla" => bail!(
                "backend 'xla' requires building with `--features xla` \
                 (and `make artifacts`)"
            ),
            other => bail!("unknown backend '{other}' (have: native, xla)"),
        }
    }

    /// Effective pre-training options.
    pub fn pretrain_opts(&self) -> PretrainOpts {
        PretrainOpts {
            steps: if self.quick { 60 } else { self.pretrain_steps },
            lr: self.pretrain_lr,
            warmup: 50,
            seed: self.seed,
            log_every: 100,
        }
    }

    /// Effective tuning options.
    pub fn tune_opts(&self) -> TuneOpts {
        let mut t = TuneOpts {
            stage1_steps: self.stage1_steps,
            main_steps: self.main_steps,
            ..Default::default()
        };
        if self.quick {
            t.stage1_steps = 20;
            t.main_steps = 40;
        }
        t.train.seed = self.seed;
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = Config::default();
        assert_eq!(c.models, vec!["base"]);
        assert!(!c.quick);
        assert_eq!(c.threads, 0, "kernel workers default to auto");
        assert_eq!(c.tune_opts().main_steps, 140);
    }

    #[test]
    fn threads_key_parses_and_builds() {
        let mut c = Config::default();
        c.set("threads", "2").unwrap();
        assert_eq!(c.threads, 2);
        assert!(c.engine().is_ok(), "threaded native engine must build");
        let mut c = Config::default();
        c.apply_json(&json::parse(r#"{"threads": 1}"#).unwrap()).unwrap();
        assert_eq!(c.threads, 1);
    }

    #[test]
    fn packing_key_parses_and_builds() {
        let c = Config::default();
        assert!(c.packing, "packing defaults on");
        let mut c = Config::default();
        c.set("packing", "false").unwrap();
        assert!(!c.packing);
        assert!(c.engine().is_ok(), "unpacked native engine must build");
        let mut c = Config::default();
        c.apply_json(&json::parse(r#"{"packing": false}"#).unwrap()).unwrap();
        assert!(!c.packing);
    }

    #[test]
    fn backend_defaults_native_and_overrides() {
        let c = Config::default();
        assert_eq!(c.backend, "native");
        let mut c = Config::default();
        c.set("backend", "xla").unwrap();
        assert_eq!(c.backend, "xla");
        let mut c = Config::default();
        c.apply_json(&json::parse(r#"{"backend": "native"}"#).unwrap())
            .unwrap();
        assert_eq!(c.backend, "native");
    }

    #[test]
    fn engine_selection_respects_backend() {
        let mut c = Config::default();
        assert!(c.engine().is_ok(), "native engine must build");
        c.set("backend", "bogus").unwrap();
        assert!(c.engine().is_err(), "unknown backend must be rejected");
        #[cfg(not(feature = "xla"))]
        {
            c.set("backend", "xla").unwrap();
            let err = c.engine().unwrap_err().to_string();
            assert!(err.contains("--features xla"), "{err}");
        }
    }

    #[test]
    fn set_overrides() {
        let mut c = Config::default();
        c.set("seed", "9").unwrap();
        c.set("models", "tiny,base").unwrap();
        c.set("quick", "true").unwrap();
        assert_eq!(c.seed, 9);
        assert_eq!(c.models, vec!["tiny", "base"]);
        assert_eq!(c.tune_opts().main_steps, 40);
        assert!(c.set("bogus", "1").is_err());
    }

    #[test]
    fn json_roundtrip() {
        let mut c = Config::default();
        let j = json::parse(r#"{"seed": 5, "main_steps": 77, "models": ["base"]}"#).unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.seed, 5);
        assert_eq!(c.main_steps, 77);
        assert_eq!(c.models, vec!["base"]);
    }
}
