//! Evaluation metrics (paper Sec. 4.1): accuracy, Matthews correlation
//! (CoLA), Pearson correlation (STS-B), plus F1 for completeness.

use crate::data::Metric;

/// Accuracy over (pred, gold) pairs.
pub fn accuracy(preds: &[usize], golds: &[usize]) -> f64 {
    assert_eq!(preds.len(), golds.len());
    if preds.is_empty() {
        return 0.0;
    }
    let hit = preds.iter().zip(golds).filter(|(p, g)| p == g).count();
    hit as f64 / preds.len() as f64
}

/// Binary Matthews correlation coefficient (phi coefficient).
pub fn matthews(preds: &[usize], golds: &[usize]) -> f64 {
    assert_eq!(preds.len(), golds.len());
    let (mut tp, mut tn, mut fp, mut r#fn) = (0f64, 0f64, 0f64, 0f64);
    for (&p, &g) in preds.iter().zip(golds) {
        match (p, g) {
            (1, 1) => tp += 1.0,
            (0, 0) => tn += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => r#fn += 1.0,
            _ => {}
        }
    }
    let denom = ((tp + fp) * (tp + r#fn) * (tn + fp) * (tn + r#fn)).sqrt();
    if denom == 0.0 {
        0.0
    } else {
        (tp * tn - fp * r#fn) / denom
    }
}

/// Pearson correlation between two real vectors.
pub fn pearson(xs: &[f32], ys: &[f32]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = xs.iter().map(|&x| x as f64).sum::<f64>() / n;
    let my = ys.iter().map(|&y| y as f64).sum::<f64>() / n;
    let (mut sxy, mut sxx, mut syy) = (0.0, 0.0, 0.0);
    for (&x, &y) in xs.iter().zip(ys) {
        let dx = x as f64 - mx;
        let dy = y as f64 - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        0.0
    } else {
        sxy / (sxx * syy).sqrt()
    }
}

/// Binary F1 (positive class = 1).
pub fn f1(preds: &[usize], golds: &[usize]) -> f64 {
    let (mut tp, mut fp, mut r#fn) = (0f64, 0f64, 0f64);
    for (&p, &g) in preds.iter().zip(golds) {
        match (p, g) {
            (1, 1) => tp += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => r#fn += 1.0,
            _ => {}
        }
    }
    if tp == 0.0 {
        return 0.0;
    }
    let prec = tp / (tp + fp);
    let rec = tp / (tp + r#fn);
    2.0 * prec * rec / (prec + rec)
}

/// Compute a task's headline metric. Classification tasks pass integer
/// preds/golds; regression passes raw scores. Values are scaled to the
/// paper's 0-100 convention.
pub fn task_score(
    metric: Metric,
    preds: &[usize],
    golds: &[usize],
    pred_scores: &[f32],
    gold_scores: &[f32],
) -> f64 {
    100.0
        * match metric {
            Metric::Accuracy => accuracy(preds, golds),
            Metric::Matthews => matthews(preds, golds),
            Metric::Pearson => pearson(pred_scores, gold_scores),
        }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[1, 0, 1], &[1, 1, 1]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn matthews_perfect_and_inverse() {
        let g = [0, 1, 0, 1, 1, 0];
        assert!((matthews(&g, &g) - 1.0).abs() < 1e-9);
        let inv: Vec<usize> = g.iter().map(|&x| 1 - x).collect();
        assert!((matthews(&inv, &g) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn matthews_constant_pred_is_zero() {
        assert_eq!(matthews(&[1, 1, 1, 1], &[0, 1, 0, 1]), 0.0);
    }

    #[test]
    fn matthews_matches_phi_formula() {
        // hand-computed example: tp=3 tn=2 fp=1 fn=2
        let preds = [1, 1, 1, 1, 0, 0, 0, 0];
        let golds = [1, 1, 1, 0, 1, 1, 0, 0];
        let phi = (3.0 * 2.0 - 1.0 * 2.0)
            / ((3.0f64 + 1.0) * (3.0 + 2.0) * (2.0 + 1.0) * (2.0 + 2.0)).sqrt();
        assert!((matthews(&preds, &golds) - phi).abs() < 1e-9);
    }

    #[test]
    fn pearson_linear_relationship() {
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let y: Vec<f32> = x.iter().map(|v| 2.0 * v + 1.0).collect();
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-9);
        let yneg: Vec<f32> = x.iter().map(|v| -v).collect();
        assert!((pearson(&x, &yneg) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn pearson_constant_is_zero() {
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn f1_basic() {
        // tp=1 fp=1 fn=1 => p=r=0.5 => f1=0.5
        assert!((f1(&[1, 1, 0], &[1, 0, 1]) - 0.5).abs() < 1e-9);
        assert_eq!(f1(&[0, 0], &[1, 1]), 0.0);
    }

    #[test]
    fn task_score_scaling() {
        let s = task_score(Metric::Accuracy, &[1, 1], &[1, 0], &[], &[]);
        assert_eq!(s, 50.0);
    }
}
