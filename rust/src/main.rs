//! `hadapt` — the L3 coordinator CLI.
//!
//! ```text
//! hadapt info                         # manifest + parameter accounting
//! hadapt pretrain --model base        # MLM pre-train a backbone
//! hadapt train --model base --task sst2 --method hadamard
//! hadapt eval --model base --task sst2 --ckpt path.ckpt
//! hadapt serve-demo --model tiny      # multi-tenant adapter serving demo
//! hadapt serve-http --model tiny      # HTTP front door (zero-alloc ingress)
//! hadapt bank-build --tenants 100000 --out fleet.bank   # tiered bank file
//! hadapt serve-http --bank fleet.bank --hot 64          # serve it
//! hadapt bank-scrub --bank fleet.bank   # verify every checksum on disk
//! hadapt bank-churn --bank fleet.bank --upserts 500     # shadow-heavy log
//! hadapt bank-compact --bank fleet.bank # drop shadowed/quarantined records
//! hadapt experiment table2            # regenerate a paper table/figure
//! hadapt experiment all               # the whole evaluation section
//! ```
//!
//! Global flags: `--set key=value` (config overrides), `--quick`,
//! `--config path.json`. `serve-demo` adds `--requests N`, `--batch B`,
//! `--tasks a,b,c` and `--trained` (export adapters from real tuning runs
//! through the coordinator instead of synthesizing them). `serve-http`
//! adds `--addr host:port`, `--max-batch B` (wave size) and either
//! `--tenants a,b,c` (synthetic adapters, same path as the demo) or
//! `--bank path` + `--hot N` (page tenants from a prebuilt on-disk bank
//! through an N-row LRU hot tier); it serves `POST /infer`, `GET /stats`,
//! `GET /healthz` and `POST /shutdown` until shut down. Its overload
//! policy is set by `--queue-cap N` (bounded admission queue, default
//! `4*max_batch`), `--window-us T` (deadline batching: flush a partial
//! wave once its oldest row has waited T µs; 0 = flush as soon as the
//! pipe drains) and `--tenant-rps R` / `--tenant-burst B` (per-tenant
//! token buckets; 0 = no throttle) and `--compact-at F` (self-compact the
//! attached bank between waves once the shadowed fraction of its log
//! reaches F; needs `--bank`). Ingress concurrency is set by
//! `--max-conns N` (connection-slot table size, default 64 — an accept
//! past the table sheds with a typed 503 `too-many-connections`) and
//! `--conn-queue-cap N` (per-connection queued-row quota, 0 = off, so
//! one pipelining client cannot fill the global queue). `bank-build` adds
//! `--tenants N` (fleet size), `--bases a,b,c` (base tasks, reused as the
//! bank's shared centroids) and `--out path`. The lifecycle commands all
//! take `--bank path`: `bank-scrub` re-verifies every checksum (exit
//! nonzero iff quarantined damage is found — a torn tail alone is
//! benign), `bank-compact` rewrites the log dropping shadowed and
//! quarantined records into a generation-bumped image, and `bank-churn`
//! (`--upserts N`) round-robins nudged upserts over the bank's own
//! tenants to create shadowed records for compaction drills.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use hadapt::config::Config;
use hadapt::coordinator::{Coordinator, RunSpec};
use hadapt::data::{generate, task_info};
use hadapt::methods::Method;
use hadapt::model::ParamStore;
use hadapt::report::pct;
use hadapt::runtime::{
    synthetic_adapters, synthetic_tenant, BankBuilder, BankGeometry, BankReader, Engine,
    ServePolicy, ServeRequest, ServeSession, TaskAdapter, WireLimits, WireServer,
};
use hadapt::train::{evaluate, load_or_pretrain};

struct Cli {
    command: String,
    positional: Vec<String>,
    flags: Vec<(String, String)>,
}

fn parse_args() -> Result<Cli> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        bail!(
            "usage: hadapt <info|pretrain|train|eval|serve-demo|serve-http|bank-build|\
             bank-compact|bank-scrub|bank-churn|experiment> [args] [--model M] [--task T] \
             [--method X] [--quick] [--set k=v]"
        );
    }
    let command = args[0].clone();
    let mut positional = Vec::new();
    let mut flags = Vec::new();
    let mut i = 1;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if name == "quick" || name == "trained" {
                flags.push((name.to_string(), "true".into()));
            } else {
                i += 1;
                let v = args
                    .get(i)
                    .with_context(|| format!("flag --{name} needs a value"))?;
                flags.push((name.to_string(), v.clone()));
            }
        } else {
            positional.push(a.clone());
        }
        i += 1;
    }
    Ok(Cli { command, positional, flags })
}

impl Cli {
    fn flag(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }
}

fn build_config(cli: &Cli) -> Result<Config> {
    let path = cli.flag("config").unwrap_or("hadapt.json");
    let mut cfg = Config::load(path)?;
    // serve-demo's/serve-http's own flags are only accepted for their
    // command — on any other command they fall through to cfg.set and
    // fail loudly, so e.g. `train --batch 32` cannot silently no-op.
    let serve_demo = cli.command == "serve-demo";
    let serve_http = cli.command == "serve-http";
    let bank_build = cli.command == "bank-build";
    let bank_lifecycle =
        matches!(cli.command.as_str(), "bank-compact" | "bank-scrub" | "bank-churn");
    for (k, v) in &cli.flags {
        match k.as_str() {
            "config" | "model" | "task" | "method" | "ckpt" | "out" => {}
            "requests" | "batch" | "tasks" | "trained" if serve_demo => {}
            "addr" | "max-batch" | "tenants" | "bank" | "hot" if serve_http => {}
            "window-us" | "queue-cap" | "tenant-rps" | "tenant-burst" if serve_http => {}
            "compact-at" | "max-conns" | "conn-queue-cap" if serve_http => {}
            "tenants" | "bases" if bank_build => {}
            "bank" if bank_lifecycle => {}
            "upserts" if cli.command == "bank-churn" => {}
            "set" => {
                let (kk, vv) = v
                    .split_once('=')
                    .with_context(|| format!("--set wants k=v, got '{v}'"))?;
                cfg.set(kk, vv)?;
            }
            other => cfg.set(other, v)?,
        }
    }
    Ok(cfg)
}

fn cmd_info(cfg: &Config) -> Result<()> {
    let engine = cfg.engine()?;
    let m = engine.manifest();
    println!("backend: {}", engine.backend_name());
    println!("artifacts: {} (batch={}, seq={})",
             m.artifacts.len(), m.batch, m.seq_len);
    let mut names: Vec<&String> = m.models.keys().collect();
    names.sort();
    for name in names {
        let info = m.model(name)?;
        println!(
            "model {name}: layers={} hidden={} heads={} ffn={} | {} tensors, \
             {} backbone scalars",
            info.layers, info.hidden, info.heads, info.ffn,
            info.params.len(), info.backbone_params()
        );
        for method in ["hadamard", "bitfit", "lora", "houlsby", "ia3", "lntuning"] {
            let meth = Method::by_name(method)?;
            println!(
                "  {method:<10} adapter params {:>8}  ({})",
                meth.adapter_params(info)?,
                pct(meth.param_fraction(info)?)
            );
        }
    }
    Ok(())
}

fn cmd_pretrain(cfg: &Config, cli: &Cli) -> Result<()> {
    let model = cli.flag("model").unwrap_or("base");
    let engine = cfg.engine()?;
    let store = load_or_pretrain(
        &engine,
        model,
        &cfg.checkpoints_dir,
        &cfg.pretrain_opts(),
    )?;
    println!(
        "backbone '{model}' ready ({} scalars) -> {}",
        store.total_scalars(),
        hadapt::train::checkpoint_path(&cfg.checkpoints_dir, model, cfg.seed)
            .display()
    );
    Ok(())
}

fn cmd_train(cfg: Config, cli: &Cli) -> Result<()> {
    let model = cli.flag("model").unwrap_or("base").to_string();
    let task = cli.flag("task").unwrap_or("sst2").to_string();
    let method = cli.flag("method").unwrap_or("hadamard").to_string();
    let mut coord = Coordinator::new(cfg)?;
    let seed = coord.config.seed;
    let rec = coord.run(&RunSpec {
        model: model.clone(),
        task: task.clone(),
        method: method.clone(),
        seed,
    })?;
    println!(
        "score {:.1} | trainable {} | adapter {} ({}) | {:.1}s",
        rec.score,
        rec.trainable_scalars,
        rec.adapter_scalars,
        pct(rec.param_fraction),
        rec.wall_secs
    );
    if let Some(out) = cli.flag("out") {
        // re-run uncached to materialize the tuned checkpoint
        let opts = coord.config.tune_opts();
        let spec = RunSpec { model, task, method, seed };
        let (_, result) = coord.run_uncached(&spec, &opts)?;
        result.store.save(out)?;
        println!("tuned checkpoint -> {out}");
    }
    Ok(())
}

fn cmd_eval(cfg: Config, cli: &Cli) -> Result<()> {
    let model = cli.flag("model").unwrap_or("base").to_string();
    let task = cli.flag("task").unwrap_or("sst2").to_string();
    let mut coord = Coordinator::new(cfg)?;
    let store = match cli.flag("ckpt") {
        Some(path) => {
            let s = ParamStore::load(path)?;
            s.check_against(coord.engine.manifest().model(&model)?)?;
            s
        }
        None => {
            coord.backbone(&model)?;
            coord.backbones_get(&model).unwrap().clone()
        }
    };
    coord.dataset(&task, "dev")?;
    let ds = coord.datasets_get(&task, "dev").unwrap().clone();
    let r = evaluate(&coord.engine, &model, &store, &ds)?;
    println!(
        "{model}/{task}: score {:.2} over {} examples",
        r.score, r.examples
    );
    Ok(())
}

/// `hadapt serve-demo`: drive N mixed-task requests through a
/// [`ServeSession`] — one packed frozen backbone, per-task Hadamard
/// adapter banks, cross-task micro-batching — and verify the serve-path
/// zero-contracts (no repacks, no steady-state spawns, no steady-state
/// arena misses) with live counters. Fails loudly if any contract breaks,
/// which is what makes it a usable CI smoke test.
fn cmd_serve_demo(cfg: Config, cli: &Cli) -> Result<()> {
    let model = cli.flag("model").unwrap_or("tiny").to_string();
    let requests: usize = cli
        .flag("requests")
        .unwrap_or("48")
        .parse()
        .context("--requests wants a number")?;
    let max_batch: usize = cli
        .flag("batch")
        .unwrap_or("8")
        .parse()
        .context("--batch wants a number")?;
    let tasks: Vec<String> = cli
        .flag("tasks")
        .unwrap_or("sst2,mrpc,rte")
        .split(',')
        .map(str::to_string)
        .collect();
    let trained = cli.flag("trained").is_some();
    let seed = cfg.seed;

    if trained {
        // Real pipeline: tune each task with the coordinator (run-cache
        // aware), export the tuned vectors into bank entries.
        let mut coord = Coordinator::new(cfg)?;
        let mut adapters = Vec::new();
        for task in &tasks {
            adapters.push(coord.export_adapter(&RunSpec {
                model: model.clone(),
                task: task.clone(),
                method: "hadamard".into(),
                seed,
            })?);
        }
        coord.backbone(&model)?;
        let store = coord.backbones_get(&model).unwrap().clone();
        run_serve_demo(&coord.engine, &model, &store, adapters, &tasks, requests, max_batch, seed)
    } else {
        // Synthetic pipeline (default; fast enough for CI): a fresh
        // deterministic backbone and per-task adapters derived from it by
        // seeded perturbation, so tasks genuinely disagree.
        let engine = cfg.engine()?;
        let info = engine.manifest().model(&model)?.clone();
        let store = ParamStore::init(&info, seed);
        let adapters = synthetic_adapters(&info, &store, &tasks, seed)?;
        run_serve_demo(&engine, &model, &store, adapters, &tasks, requests, max_batch, seed)
    }
}

/// The serve-demo body: register the bank, pump mixed-task traffic,
/// hot-swap an adapter mid-stream, report throughput/latency and check
/// the zero-contract counters.
#[allow(clippy::too_many_arguments)]
fn run_serve_demo(
    engine: &Engine,
    model: &str,
    store: &ParamStore,
    adapters: Vec<TaskAdapter>,
    tasks: &[String],
    requests: usize,
    max_batch: usize,
    seed: u64,
) -> Result<()> {
    let mut session = ServeSession::new(engine, model, store, max_batch)?;
    for a in adapters {
        println!(
            "bank: task '{:<6}' registered ({} adapter scalars, {} classes)",
            a.task,
            a.scalars(),
            a.classes
        );
        session.register_task(a)?;
    }

    // Request stream: real encoded examples, round-robin across tasks so
    // every micro-batch mixes tenants.
    let streams: Vec<_> = tasks
        .iter()
        .map(|task| {
            task_info(task)
                .with_context(|| format!("unknown task '{task}'"))
                .map(|info| generate(info, seed, "dev", 32))
        })
        .collect::<Result<Vec<_>>>()?;
    let mut reqs = Vec::with_capacity(requests.max(1));
    for i in 0..requests.max(1) {
        let (task, ds) = (&tasks[i % tasks.len()], &streams[i % streams.len()]);
        let e = &ds.examples[i % ds.examples.len()];
        reqs.push(ServeRequest {
            task: task.clone(),
            seq_a: e.seq_a.clone(),
            seq_b: e.seq_b.clone(),
        });
    }

    // Warm-up batch: populates the workspace arena, spawns the persistent
    // workers, packs the frozen backbone — everything after this must be
    // steady state.
    session.submit(reqs[0].clone())?;
    session.run_pending()?;
    let (_, arena_misses_0) = engine.arena_stats();
    let pool_0 = engine.pool_stats();
    let (packs_live_0, repacks_0) = engine.pack_stats();

    let t0 = Instant::now();
    let mut latencies = Vec::new();
    for wave in reqs.chunks(max_batch) {
        for r in wave {
            session.submit(r.clone())?;
        }
        for reply in session.run_pending()? {
            latencies.push(reply.latency_s);
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    // Hot adapter swap mid-traffic: redeploy task 0 with nudged vectors,
    // then serve one more wave — the swap must cost vector copies only.
    let mut swapped = TaskAdapter::from_store(
        engine.manifest().model(model)?,
        store,
        &tasks[0],
        session.bank().get(&tasks[0]).unwrap().classes,
    )?;
    for v in swapped.had_b[0].iter_mut() {
        *v += 0.125;
    }
    session.register_task(swapped)?;
    for r in reqs.iter().take(max_batch) {
        session.submit(r.clone())?;
    }
    session.run_pending()?;

    let (_, arena_misses_1) = engine.arena_stats();
    let pool_1 = engine.pool_stats();
    let (packs_live_1, repacks_1) = engine.pack_stats();

    latencies.sort_by(|a, b| a.total_cmp(b));
    let stats = session.stats();
    let p50 = latencies[latencies.len() / 2] * 1e3;
    let p99 = latencies[(latencies.len() * 99 / 100).min(latencies.len() - 1)] * 1e3;
    println!(
        "served {} requests over {} tasks in {:.3}s — {:.0} req/s (batch {}, {} batches, \
         {} padded rows)",
        stats.requests,
        tasks.len(),
        wall,
        latencies.len() as f64 / wall.max(1e-9),
        max_batch,
        stats.batches,
        stats.padded_rows
    );
    println!("latency: p50 {p50:.3}ms  p99 {p99:.3}ms (queue wait included)");

    if arena_misses_1 != arena_misses_0 {
        bail!("serve steady state missed the arena ({arena_misses_0} -> {arena_misses_1})");
    }
    if pool_1.threads_spawned != pool_0.threads_spawned {
        bail!(
            "serve steady state spawned threads ({} -> {})",
            pool_0.threads_spawned,
            pool_1.threads_spawned
        );
    }
    if repacks_1 != repacks_0 || packs_live_1 != packs_live_0 {
        bail!(
            "adapter traffic touched the pack cache (live {packs_live_0} -> {packs_live_1}, \
             repacks {repacks_0} -> {repacks_1})"
        );
    }
    println!(
        "zero-contracts OK: arena misses frozen at {arena_misses_0}, spawns frozen at {}, \
         repacks {repacks_0}, adapter swap = vector copy",
        pool_0.threads_spawned
    );
    Ok(())
}

/// `hadapt bank-build`: synthesize a Zipf-clustered tenant fleet around
/// the base tasks, delta-encode every tenant against its base centroid,
/// and write the crash-safe on-disk bank file that `serve-http --bank`
/// pages at serve time. Prints the per-tier scalar accounting and the
/// compression ratio versus storing every tenant densely.
fn cmd_bank_build(cfg: Config, cli: &Cli) -> Result<()> {
    let model = cli.flag("model").unwrap_or("tiny").to_string();
    let tenants: usize = cli
        .flag("tenants")
        .unwrap_or("1000")
        .parse()
        .context("--tenants wants a fleet size")?;
    let bases: Vec<String> = cli
        .flag("bases")
        .unwrap_or("sst2,mrpc,rte")
        .split(',')
        .map(str::to_string)
        .collect();
    let out = cli.flag("out").unwrap_or("fleet.bank").to_string();
    let seed = cfg.seed;

    let engine = cfg.engine()?;
    let info = engine.manifest().model(&model)?.clone();
    let store = ParamStore::init(&info, seed);
    let base_adapters = synthetic_adapters(&info, &store, &bases, seed)?;
    if tenants < base_adapters.len() {
        bail!(
            "--tenants {tenants} is smaller than the {} base tasks",
            base_adapters.len()
        );
    }
    let classes = info.params[info.param_index("classifier.bias")?].shape[0];
    let geom = BankGeometry { layers: info.layers, hidden: info.hidden, classes };
    // The bases double as the shared centroids: every synthetic tenant is
    // a (possibly empty) perturbation of one of them, so ε=0 bitwise
    // delta-encoding stores only the layers a tenant actually changed —
    // the paper's redundant-layer finding, applied as storage.
    let mut builder = BankBuilder::new(geom, base_adapters.clone(), 0.0)?;
    for idx in 0..tenants {
        builder.add_tenant(&synthetic_tenant(&base_adapters, idx, seed))?;
    }
    let summary = builder.write(&out)?;
    println!(
        "bank-build: {} tenants over {} centroids -> {out} ({} bytes)",
        summary.tenants, summary.centroids, summary.file_bytes
    );
    println!("  naive dense storage : {} scalars", summary.naive_scalars);
    println!("  centroid tier       : {} scalars (shared, paid once)", summary.centroid_scalars);
    println!(
        "  delta tier          : {} scalars (only rows that differ from the centroid)",
        summary.delta_scalars
    );
    println!("  compression ratio   : {:.1}x vs dense", summary.compression_ratio);
    Ok(())
}

/// `hadapt bank-compact`: rewrite a bank's tenant log dropping shadowed
/// and quarantined records into a generation-bumped image, committed by
/// write-temp + fsync + rename — a crash at any point leaves the
/// previous generation loadable. Prints one machine-parseable
/// `key=value` summary line (the crash-loop smoke reads it).
fn cmd_bank_compact(cli: &Cli) -> Result<()> {
    let path = cli.flag("bank").context("bank-compact needs --bank <path>")?;
    let mut reader =
        BankReader::open(path).with_context(|| format!("cannot open bank file {path}"))?;
    let live_before = reader.live_fraction();
    let s = reader.compact()?;
    println!(
        "bank-compact: generation={} tenants={} dropped_shadowed={} dropped_quarantined={} \
         bytes_before={} bytes_after={} reclaimed_bytes={} live_frac_before={:.4}",
        s.generation,
        s.tenants,
        s.dropped_shadowed,
        s.dropped_quarantined,
        s.bytes_before,
        s.bytes_after,
        s.reclaimed_bytes,
        live_before
    );
    Ok(())
}

/// `hadapt bank-scrub`: re-verify every checksum in a bank file from
/// disk — header, centroid table, a salvage scan of the tenant log, and
/// a decode of every live payload. Prints one machine-parseable
/// `key=value` report line plus one line per damage region, and exits
/// nonzero iff quarantined damage was found (a torn tail alone is a
/// benign crash artifact and does not fail the scrub).
fn cmd_bank_scrub(cli: &Cli) -> Result<()> {
    let path = cli.flag("bank").context("bank-scrub needs --bank <path>")?;
    let mut reader =
        BankReader::open(path).with_context(|| format!("cannot open bank file {path}"))?;
    let rep = reader.scrub()?;
    println!(
        "bank-scrub: generation={} tenants={} records={} shadowed={} quarantined={} \
         torn_bytes={} bytes_scanned={} live_frac={:.4}",
        rep.generation,
        rep.tenants,
        rep.records,
        rep.shadowed,
        rep.quarantined,
        rep.torn_bytes,
        rep.bytes_scanned,
        rep.live_fraction
    );
    for d in &rep.damage {
        println!(
            "  damage offset={} kind={} tenant={}",
            d.offset,
            d.kind,
            d.tenant.as_deref().unwrap_or("?")
        );
    }
    if rep.quarantined > 0 {
        bail!(
            "bank {path} carries {} quarantined damage region(s) — bank-compact drops them",
            rep.quarantined
        );
    }
    println!("bank-scrub: clean");
    Ok(())
}

/// `hadapt bank-churn`: round-robin nudged upserts over a bank's own
/// tenants, shadowing their previous records — the fastest way to grow
/// the shadowed fraction that `bank-compact` (or `serve-http
/// --compact-at`) reclaims. Used by the crash-loop smoke to exercise
/// upsert-time crash safety.
fn cmd_bank_churn(cli: &Cli) -> Result<()> {
    let path = cli.flag("bank").context("bank-churn needs --bank <path>")?;
    let upserts: usize = cli
        .flag("upserts")
        .unwrap_or("100")
        .parse()
        .context("--upserts wants a count")?;
    let mut reader =
        BankReader::open(path).with_context(|| format!("cannot open bank file {path}"))?;
    let mut names: Vec<String> = reader.names().map(str::to_string).collect();
    names.sort();
    if names.is_empty() {
        bail!("bank {path} holds no tenants to churn");
    }
    let mut out = reader.blank_adapter();
    for i in 0..upserts {
        reader.read_into(&names[i % names.len()], &mut out)?;
        let layer = i % out.had_b.len();
        out.had_b[layer][0] += 0.0625;
        reader.upsert(&out)?;
    }
    println!(
        "bank-churn: upserts={} tenants={} live_frac={:.4} log_bytes={} generation={}",
        upserts,
        names.len(),
        reader.live_fraction(),
        reader.log_bytes(),
        reader.generation()
    );
    Ok(())
}

/// `hadapt serve-http`: the wire front door — bind a socket, stand up a
/// [`ServeSession`] with synthetic tenants (same deterministic path as
/// `serve-demo`), and serve `POST /infer` / `GET /stats` /
/// `GET /healthz` until `POST /shutdown`. On exit, prints the wire
/// counters next to the engine's zero-contract counters so a load run
/// (`tools/wire_load.py`) can be read end to end.
fn cmd_serve_http(cfg: Config, cli: &Cli) -> Result<()> {
    let model = cli.flag("model").unwrap_or("tiny").to_string();
    let addr = cli.flag("addr").unwrap_or("127.0.0.1:8471");
    let max_batch: usize = cli
        .flag("max-batch")
        .unwrap_or("8")
        .parse()
        .context("--max-batch wants a number")?;
    let bank_path = cli.flag("bank").map(str::to_string);
    let hot: usize = cli
        .flag("hot")
        .unwrap_or("64")
        .parse()
        .context("--hot wants a number of hot-tier rows")?;
    if bank_path.is_some() && cli.flag("tenants").is_some() {
        bail!("--bank and --tenants are mutually exclusive: the bank file already names its tenants");
    }
    let compact_at: Option<f64> = cli
        .flag("compact-at")
        .map(str::parse)
        .transpose()
        .context("--compact-at wants a shadowed fraction in (0, 1]")?;
    if let Some(f) = compact_at {
        if !(f > 0.0 && f <= 1.0) {
            bail!("--compact-at wants a shadowed fraction in (0, 1], got {f}");
        }
        if bank_path.is_none() {
            bail!("--compact-at needs --bank: only an on-disk bank can be compacted");
        }
    }
    // Overload policy: 0 keeps the legacy behavior for each axis
    // (drain-on-demand flush, no per-tenant throttle); the queue default
    // gives the front door two waves of headroom beyond the one in flight.
    let window_us: u64 = cli
        .flag("window-us")
        .unwrap_or("0")
        .parse()
        .context("--window-us wants a batching deadline in microseconds")?;
    let queue_cap: usize = cli
        .flag("queue-cap")
        .map(str::parse)
        .transpose()
        .context("--queue-cap wants a number of queued rows")?
        .unwrap_or(4 * max_batch);
    let tenant_rps: u32 = cli
        .flag("tenant-rps")
        .unwrap_or("0")
        .parse()
        .context("--tenant-rps wants a per-tenant admission rate")?;
    let tenant_burst: u32 = cli
        .flag("tenant-burst")
        .map(str::parse)
        .transpose()
        .context("--tenant-burst wants a bucket depth in requests")?
        .unwrap_or(tenant_rps.max(1));
    let max_conns: usize = cli
        .flag("max-conns")
        .unwrap_or("64")
        .parse()
        .context("--max-conns wants a connection-slot count")?;
    if max_conns == 0 {
        bail!("--max-conns wants at least 1 connection slot");
    }
    let conn_queue_cap: usize = cli
        .flag("conn-queue-cap")
        .unwrap_or("0")
        .parse()
        .context("--conn-queue-cap wants a per-connection queued-row quota (0 = off)")?;
    let tenants: Vec<String> = cli
        .flag("tenants")
        .unwrap_or("sst2,mrpc,rte")
        .split(',')
        .map(str::to_string)
        .collect();
    let seed = cfg.seed;

    let engine = cfg.engine()?;
    let info = engine.manifest().model(&model)?.clone();
    let store = ParamStore::init(&info, seed);
    let mut session = ServeSession::new(&engine, &model, &store, max_batch)?;
    match &bank_path {
        Some(path) => {
            let reader = BankReader::open(path)
                .with_context(|| format!("cannot open bank file {path}"))?;
            println!(
                "bank: {} tenants on disk over {} centroids, hot tier {hot} rows",
                reader.len(),
                reader.centroids().len()
            );
            session.attach_store(reader, hot)?;
        }
        None => {
            for a in synthetic_adapters(&info, &store, &tenants, seed)? {
                println!(
                    "bank: task '{:<6}' registered ({} adapter scalars, {} classes)",
                    a.task,
                    a.scalars(),
                    a.classes
                );
                session.register_task(a)?;
            }
        }
    }
    session.set_policy(ServePolicy {
        queue_cap,
        window_us,
        tenant_rps,
        tenant_burst,
        conn_queue_cap,
    })?;
    let listener =
        std::net::TcpListener::bind(addr).with_context(|| format!("cannot bind {addr}"))?;
    let bound = listener.local_addr()?;
    println!(
        "serve-http: model '{model}', {} tenants, wave size {max_batch}, listening on {bound} \
         (up to {max_conns} concurrent connections)",
        session.bank().tenant_count()
    );
    println!(
        "admission: queue cap {} rows, batching window {}us, tenant rate {}/s (burst {})",
        session.queue_cap(),
        window_us,
        tenant_rps,
        tenant_burst
    );
    // the load script waits for this line before sending traffic
    use std::io::Write as _;
    std::io::stdout().flush().ok();

    let mut server = WireServer::new(session, listener, WireLimits::default());
    server.set_compact_at(compact_at);
    server.set_max_conns(max_conns);
    let stats = server.run()?;

    let (_, arena_misses) = engine.arena_stats();
    let pool = engine.pool_stats();
    let (_, repacks) = engine.pack_stats();
    println!(
        "serve-http done: {} connections ({} shed at accept), {} requests, {} replies, \
         {} batches, rejects http/parse/submit {}/{}/{}, throttled {} shed {} \
         window flushes {}",
        stats.connections,
        stats.conns_rejected,
        stats.requests,
        stats.replies,
        stats.batches,
        stats.rejects_http,
        stats.rejects_parse,
        stats.rejects_submit,
        stats.rejects_throttle,
        stats.rejects_shed,
        stats.window_flushes
    );
    if stats.compactions + stats.compact_failures > 0 {
        println!(
            "bank lifecycle at exit: {} self-compactions, {} failed (previous generation \
             kept serving)",
            stats.compactions, stats.compact_failures
        );
    }
    println!(
        "engine counters at exit: arena misses {arena_misses}, threads spawned {}, \
         repacks {repacks}",
        pool.threads_spawned
    );
    Ok(())
}

fn cmd_experiment(cfg: Config, cli: &Cli) -> Result<()> {
    let id = cli
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let mut coord = Coordinator::new(cfg)?;
    hadapt::experiments::run(&mut coord, id)?;
    let stats = coord.engine.stats();
    println!(
        "engine: {} compiles ({:.1}s), {} executions ({:.1}s)",
        stats.compiles, stats.compile_secs, stats.executions, stats.execute_secs
    );
    Ok(())
}

fn main() -> Result<()> {
    let cli = parse_args()?;
    let cfg = build_config(&cli)?;
    match cli.command.as_str() {
        "info" => cmd_info(&cfg),
        "pretrain" => cmd_pretrain(&cfg, &cli),
        "train" => cmd_train(cfg, &cli),
        "eval" => cmd_eval(cfg, &cli),
        "serve-demo" => cmd_serve_demo(cfg, &cli),
        "serve-http" => cmd_serve_http(cfg, &cli),
        "bank-build" => cmd_bank_build(cfg, &cli),
        "bank-compact" => cmd_bank_compact(&cli),
        "bank-scrub" => cmd_bank_scrub(&cli),
        "bank-churn" => cmd_bank_churn(&cli),
        "experiment" => cmd_experiment(cfg, &cli),
        other => bail!("unknown command '{other}'"),
    }
}
