//! `hadapt` — the L3 coordinator CLI.
//!
//! ```text
//! hadapt info                         # manifest + parameter accounting
//! hadapt pretrain --model base        # MLM pre-train a backbone
//! hadapt train --model base --task sst2 --method hadamard
//! hadapt eval --model base --task sst2 --ckpt path.ckpt
//! hadapt experiment table2            # regenerate a paper table/figure
//! hadapt experiment all               # the whole evaluation section
//! ```
//!
//! Global flags: `--set key=value` (config overrides), `--quick`,
//! `--config path.json`.

use anyhow::{bail, Context, Result};

use hadapt::config::Config;
use hadapt::coordinator::{Coordinator, RunSpec};
use hadapt::methods::Method;
use hadapt::model::ParamStore;
use hadapt::report::pct;
use hadapt::train::{evaluate, load_or_pretrain};

struct Cli {
    command: String,
    positional: Vec<String>,
    flags: Vec<(String, String)>,
}

fn parse_args() -> Result<Cli> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        bail!(
            "usage: hadapt <info|pretrain|train|eval|experiment> [args] \
             [--model M] [--task T] [--method X] [--quick] [--set k=v]"
        );
    }
    let command = args[0].clone();
    let mut positional = Vec::new();
    let mut flags = Vec::new();
    let mut i = 1;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if name == "quick" {
                flags.push(("quick".into(), "true".into()));
            } else {
                i += 1;
                let v = args
                    .get(i)
                    .with_context(|| format!("flag --{name} needs a value"))?;
                flags.push((name.to_string(), v.clone()));
            }
        } else {
            positional.push(a.clone());
        }
        i += 1;
    }
    Ok(Cli { command, positional, flags })
}

impl Cli {
    fn flag(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }
}

fn build_config(cli: &Cli) -> Result<Config> {
    let path = cli.flag("config").unwrap_or("hadapt.json");
    let mut cfg = Config::load(path)?;
    for (k, v) in &cli.flags {
        match k.as_str() {
            "config" | "model" | "task" | "method" | "ckpt" | "out" => {}
            "set" => {
                let (kk, vv) = v
                    .split_once('=')
                    .with_context(|| format!("--set wants k=v, got '{v}'"))?;
                cfg.set(kk, vv)?;
            }
            other => cfg.set(other, v)?,
        }
    }
    Ok(cfg)
}

fn cmd_info(cfg: &Config) -> Result<()> {
    let engine = cfg.engine()?;
    let m = engine.manifest();
    println!("backend: {}", engine.backend_name());
    println!("artifacts: {} (batch={}, seq={})",
             m.artifacts.len(), m.batch, m.seq_len);
    let mut names: Vec<&String> = m.models.keys().collect();
    names.sort();
    for name in names {
        let info = m.model(name)?;
        println!(
            "model {name}: layers={} hidden={} heads={} ffn={} | {} tensors, \
             {} backbone scalars",
            info.layers, info.hidden, info.heads, info.ffn,
            info.params.len(), info.backbone_params()
        );
        for method in ["hadamard", "bitfit", "lora", "houlsby", "ia3", "lntuning"] {
            let meth = Method::by_name(method)?;
            println!(
                "  {method:<10} adapter params {:>8}  ({})",
                meth.adapter_params(info)?,
                pct(meth.param_fraction(info)?)
            );
        }
    }
    Ok(())
}

fn cmd_pretrain(cfg: &Config, cli: &Cli) -> Result<()> {
    let model = cli.flag("model").unwrap_or("base");
    let engine = cfg.engine()?;
    let store = load_or_pretrain(
        &engine,
        model,
        &cfg.checkpoints_dir,
        &cfg.pretrain_opts(),
    )?;
    println!(
        "backbone '{model}' ready ({} scalars) -> {}",
        store.total_scalars(),
        hadapt::train::checkpoint_path(&cfg.checkpoints_dir, model, cfg.seed)
            .display()
    );
    Ok(())
}

fn cmd_train(cfg: Config, cli: &Cli) -> Result<()> {
    let model = cli.flag("model").unwrap_or("base").to_string();
    let task = cli.flag("task").unwrap_or("sst2").to_string();
    let method = cli.flag("method").unwrap_or("hadamard").to_string();
    let mut coord = Coordinator::new(cfg)?;
    let seed = coord.config.seed;
    let rec = coord.run(&RunSpec {
        model: model.clone(),
        task: task.clone(),
        method: method.clone(),
        seed,
    })?;
    println!(
        "score {:.1} | trainable {} | adapter {} ({}) | {:.1}s",
        rec.score,
        rec.trainable_scalars,
        rec.adapter_scalars,
        pct(rec.param_fraction),
        rec.wall_secs
    );
    if let Some(out) = cli.flag("out") {
        // re-run uncached to materialize the tuned checkpoint
        let opts = coord.config.tune_opts();
        let spec = RunSpec { model, task, method, seed };
        let (_, result) = coord.run_uncached(&spec, &opts)?;
        result.store.save(out)?;
        println!("tuned checkpoint -> {out}");
    }
    Ok(())
}

fn cmd_eval(cfg: Config, cli: &Cli) -> Result<()> {
    let model = cli.flag("model").unwrap_or("base").to_string();
    let task = cli.flag("task").unwrap_or("sst2").to_string();
    let mut coord = Coordinator::new(cfg)?;
    let store = match cli.flag("ckpt") {
        Some(path) => {
            let s = ParamStore::load(path)?;
            s.check_against(coord.engine.manifest().model(&model)?)?;
            s
        }
        None => {
            coord.backbone(&model)?;
            coord.backbones_get(&model).unwrap().clone()
        }
    };
    coord.dataset(&task, "dev")?;
    let ds = coord.datasets_get(&task, "dev").unwrap().clone();
    let r = evaluate(&coord.engine, &model, &store, &ds)?;
    println!(
        "{model}/{task}: score {:.2} over {} examples",
        r.score, r.examples
    );
    Ok(())
}

fn cmd_experiment(cfg: Config, cli: &Cli) -> Result<()> {
    let id = cli
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let mut coord = Coordinator::new(cfg)?;
    hadapt::experiments::run(&mut coord, id)?;
    let stats = coord.engine.stats();
    println!(
        "engine: {} compiles ({:.1}s), {} executions ({:.1}s)",
        stats.compiles, stats.compile_secs, stats.executions, stats.execute_secs
    );
    Ok(())
}

fn main() -> Result<()> {
    let cli = parse_args()?;
    let cfg = build_config(&cli)?;
    match cli.command.as_str() {
        "info" => cmd_info(&cfg),
        "pretrain" => cmd_pretrain(&cfg, &cli),
        "train" => cmd_train(cfg, &cli),
        "eval" => cmd_eval(cfg, &cli),
        "experiment" => cmd_experiment(cfg, &cli),
        other => bail!("unknown command '{other}'"),
    }
}
