//! Table 5 / Fig 4: effect of the number of unfrozen Hadamard-adapter
//! layers. The paper unfreezes the last k layers (k = 4..12 for base,
//! 4..24 for large) and finds monotone improvement that saturates past
//! half the depth — the basis for the 0.022% "redundant layers" claim.
//!
//! Our depths are scaled (base = 4 encoder layers ~ paper's 12; large = 8
//! ~ paper's 24); k sweeps the same fractions of depth.

use anyhow::Result;

use crate::coordinator::{index_records, Coordinator};
use crate::methods::Method;
use crate::report::{pct, Table};

use super::TABLE5_TASKS;

/// k values per model depth (fractions 1/4, 1/2, 3/4, 1 of the depth).
pub fn layer_sweep(depth: usize) -> Vec<usize> {
    // shallow models sweep every quarter; deeper ones skip 3/4 to bound the
    // run-grid (the paper's saturation shows up by half depth already)
    let fracs: &[usize] = if depth <= 4 {
        &[depth / 4, depth / 2, 3 * depth / 4, depth]
    } else {
        &[depth / 4, depth / 2, depth]
    };
    let mut ks: Vec<usize> = fracs.iter().map(|&k| k.max(1)).collect();
    ks.dedup();
    ks
}

/// Regenerate Table 5 (layer-range unfreezing).
pub fn run(coord: &mut Coordinator) -> Result<()> {
    let models = coord.config.models.clone();
    let mut t = Table::new(
        "Table 5 / Fig 4: unfreezing the last k adapter layers",
        &["PLM", "task", "k", "k/depth", "score", "adapter params %"],
    );
    let mut fig4 = Table::new(
        "Fig 4 series: average score vs unfrozen fraction",
        &["PLM", "k", "fraction", "avg score"],
    );

    for model in &models {
        let info = coord.engine.manifest().model(model)?.clone();
        let depth = info.layers;
        let ks = layer_sweep(depth);
        let methods: Vec<String> =
            ks.iter().map(|k| format!("hadamard@{k}L")).collect();
        let method_refs: Vec<&str> = methods.iter().map(|s| s.as_str()).collect();
        let recs = coord.run_grid(
            std::slice::from_ref(model),
            &TABLE5_TASKS,
            &method_refs,
        )?;
        let idx = index_records(&recs);

        for (&k, mname) in ks.iter().zip(&methods) {
            let m = Method::by_name(mname)?;
            let frac_params = m.param_fraction(&info)?;
            let mut sum = 0.0;
            for task in TABLE5_TASKS {
                let r = idx[&(model.clone(), task.to_string(), mname.clone())];
                t.row(vec![
                    model.clone(),
                    task.to_string(),
                    k.to_string(),
                    format!("{:.2}", k as f64 / depth as f64),
                    format!("{:.1}", r.score),
                    pct(frac_params),
                ]);
                sum += r.score;
            }
            fig4.row(vec![
                model.clone(),
                k.to_string(),
                format!("{:.2}", k as f64 / depth as f64),
                format!("{:.1}", sum / TABLE5_TASKS.len() as f64),
            ]);
        }
    }
    println!("{}", t.render());
    println!("{}", fig4.render());
    t.save(&coord.config.results_dir, "table5")?;
    fig4.save(&coord.config.results_dir, "fig4")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_fractions() {
        assert_eq!(layer_sweep(4), vec![1, 2, 3, 4]);
        assert_eq!(layer_sweep(8), vec![2, 4, 8]);
        assert_eq!(layer_sweep(2), vec![1, 2]);
    }
}
