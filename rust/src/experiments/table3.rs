//! Table 3: the Hadamard adapter vs the other parameter-efficient methods
//! (BitFit, LoRA, Houlsby adapters, IA3, LN-tuning), all *natively
//! implemented* and run under the identical harness, with the paper's
//! parameter accounting. Headline: Hadamard has the fewest parameters with
//! competitive scores.

use anyhow::Result;

use crate::coordinator::{index_records, Coordinator};
use crate::methods::Method;
use crate::report::{pct, Table};

use super::TASK_ORDER;

/// All parameter-efficient methods in the comparison.
pub const METHODS: [&str; 6] =
    ["hadamard", "bitfit", "lora", "houlsby", "ia3", "lntuning"];

/// Regenerate Table 3 (methods comparison under one harness).
pub fn run(coord: &mut Coordinator) -> Result<()> {
    // Time budget: Table 3 runs on the first configured model (the paper's
    // BERT-base block); the hadamard rows are shared with Table 2's cache.
    let models: Vec<String> =
        coord.config.models.first().cloned().into_iter().collect();
    let recs = coord.run_grid(&models, &TASK_ORDER, &METHODS)?;
    let idx = index_records(&recs);

    let mut header = vec!["PLM", "Adapter", "Params"];
    header.extend(TASK_ORDER);
    header.push("Average");
    let mut t = Table::new(
        "Table 3: Hadamard adapter vs parameter-efficient baselines (identical harness)",
        &header,
    );

    for model in &models {
        let info = coord.engine.manifest().model(model)?.clone();
        for method in METHODS {
            let m = Method::by_name(method)?;
            let mut cells = vec![
                model.clone(),
                method.to_string(),
                pct(m.param_fraction(&info)?),
            ];
            let mut sum = 0.0;
            for task in TASK_ORDER {
                let r = idx[&(model.clone(), task.to_string(), method.to_string())];
                cells.push(format!("{:.1}", r.score));
                sum += r.score;
            }
            cells.push(format!("{:.1}", sum / TASK_ORDER.len() as f64));
            t.row(cells);
        }
    }
    println!("{}", t.render());
    t.save(&coord.config.results_dir, "table3")?;

    // Parameter accounting detail (adapter scalars, paper's headline claim
    // that Hadamard is the smallest).
    let mut pt = Table::new(
        "Table 3 parameter accounting",
        &["PLM", "Adapter", "adapter scalars", "% of backbone"],
    );
    for model in &models {
        let info = coord.engine.manifest().model(model)?.clone();
        let mut rows: Vec<(String, usize, f64)> = METHODS
            .iter()
            .map(|name| {
                let m = Method::by_name(name).unwrap();
                (
                    name.to_string(),
                    m.adapter_params(&info).unwrap(),
                    m.param_fraction(&info).unwrap(),
                )
            })
            .collect();
        rows.sort_by_key(|r| r.1);
        let smallest = rows[0].0.clone();
        for (name, scalars, frac) in rows {
            pt.row(vec![model.clone(), name, scalars.to_string(), pct(frac)]);
        }
        println!("smallest adapter on {model}: {smallest} (paper: Hadamard)");
    }
    println!("{}", pt.render());
    pt.save(&coord.config.results_dir, "table3_params")?;
    Ok(())
}
