//! Table 2: classifier-only vs Hadamard adapter vs full fine-tuning across
//! the GLUE suite and all PLM sizes — the paper's main result. The headline
//! to reproduce: adapter ≈ full FT (the paper reports 99.4% of full-FT
//! average) while the classifier probe sits far below (77.5%).

use anyhow::Result;

use crate::coordinator::{index_records, Coordinator};
use crate::report::Table;

use super::TASK_ORDER;

/// The three training regimes compared.
pub const METHODS: [&str; 3] = ["classifier", "hadamard", "full"];

/// Regenerate Table 2 (regime comparison across tasks).
pub fn run(coord: &mut Coordinator) -> Result<()> {
    let models = coord.config.models.clone();
    let recs = coord.run_grid(&models, &TASK_ORDER, &METHODS)?;
    let idx = index_records(&recs);

    let mut header = vec!["PLM", "Training type"];
    header.extend(TASK_ORDER);
    header.push("Average");
    let mut t = Table::new(
        "Table 2: classifier / Hadamard adapter / full fine-tuning (synthetic-GLUE)",
        &header,
    );

    let mut ratios: Vec<(String, f64, f64)> = Vec::new();
    for model in &models {
        let mut averages = Vec::new();
        for method in METHODS {
            let mut cells = vec![model.clone(), method.to_string()];
            let mut sum = 0.0;
            for task in TASK_ORDER {
                let r = idx[&(model.clone(), task.to_string(), method.to_string())];
                cells.push(format!("{:.1}", r.score));
                sum += r.score;
            }
            let avg = sum / TASK_ORDER.len() as f64;
            averages.push(avg);
            cells.push(format!("{avg:.1}"));
            t.row(cells);
        }
        // paper's ratio vs full fine-tuning
        ratios.push((model.clone(), averages[0] / averages[2], averages[1] / averages[2]));
    }
    println!("{}", t.render());
    t.save(&coord.config.results_dir, "table2")?;

    let mut rt = Table::new(
        "Table 2 headline: fraction of full-FT average (paper: classifier 77.5%, adapter 99.4%)",
        &["PLM", "classifier/full", "hadamard/full"],
    );
    for (m, c, h) in &ratios {
        rt.row(vec![m.clone(), format!("{:.1}%", c * 100.0), format!("{:.1}%", h * 100.0)]);
    }
    println!("{}", rt.render());
    rt.save(&coord.config.results_dir, "table2_ratios")?;
    Ok(())
}
