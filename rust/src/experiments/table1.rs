//! Table 1: gradient and unit-gradient analysis (paper Sec. 2.3).
//!
//! Runs full-group gradient probes over the first and last training epoch
//! on MRPC-like and SST-2-like tasks, ranking the top-5 modules by raw and
//! unit gradient. The paper's findings to reproduce: classifier/embedding/
//! intermediate weights dominate *raw* gradients; classifier, embedding and
//! LayerNorm terms dominate *unit* gradients (the justification for
//! unfreezing classifier + normalization).

use std::collections::HashMap;

use anyhow::Result;

use crate::analysis::gradients::GradAccum;
use crate::coordinator::Coordinator;
use crate::data::{class_mask, BatchIter};
use crate::model::FreezeMask;
use crate::optim::LrSchedule;
use crate::report::Table;
use crate::runtime::Manifest;
use crate::train::Session;
use crate::util::Rng;

/// Tasks probed for the gradient study.
pub const TASKS: [&str; 2] = ["mrpc", "sst2"];
const TOP_K: usize = 5;

/// Regenerate Table 1 (per-group gradient magnitudes).
pub fn run(coord: &mut Coordinator) -> Result<()> {
    let model = coord
        .config
        .models
        .first()
        .cloned()
        .unwrap_or_else(|| "base".into());
    let batch = coord.engine.manifest().batch;
    let seq = coord.engine.manifest().seq_len;
    let steps = if coord.config.quick { 20 } else { 120 };
    let probe_batches = if coord.config.quick { 4 } else { 12 };

    let mut t = Table::new(
        &format!("Table 1: top-{TOP_K} gradient / unit-gradient modules ({model})"),
        &["task", "rank", "gradient (first)", "unit gradient (first)",
          "gradient (last)", "unit gradient (last)"],
    );

    for task in TASKS {
        coord.backbone(&model)?;
        coord.dataset(task, "train")?;
        let backbone =
            coord.backbones_get(&model).expect("backbone cached").clone();
        let ds = coord.datasets_get(task, "train").expect("ds cached").clone();
        let info = coord.engine.manifest().model(&model)?.clone();
        let numels: HashMap<String, usize> = info
            .params
            .iter()
            .map(|p| (p.name.clone(), p.numel()))
            .collect();
        let cmask = class_mask(ds.info.classes);

        let artifact = Manifest::train_name("cls", "full", &model);
        let mask = FreezeMask::from_names(&info, &info.group("full")?.to_vec());
        let mut session = Session::new(
            &coord.engine,
            &artifact,
            backbone,
            mask,
            LrSchedule::constant(3e-4),
        )?;

        // first-epoch probes
        let mut first = GradAccum::new();
        let mut rng = Rng::new(coord.config.seed ^ 0xF00D);
        for (i, b) in BatchIter::new(&ds, &mut rng, batch, seq).enumerate() {
            if i >= probe_batches {
                break;
            }
            let (_, norms) = session.probe_gradients(&b, &cmask)?;
            first.add(&norms, &numels);
        }

        // train to the "last epoch"
        let mut done = 0;
        'train: loop {
            let mut it = BatchIter::new(&ds, &mut rng, batch, seq);
            while let Some(b) = it.next() {
                session.step_cls(&b, &cmask)?;
                done += 1;
                if done >= steps {
                    break 'train;
                }
            }
        }

        // last-epoch probes
        let mut last = GradAccum::new();
        for (i, b) in BatchIter::new(&ds, &mut rng, batch, seq).enumerate() {
            if i >= probe_batches {
                break;
            }
            let (_, norms) = session.probe_gradients(&b, &cmask)?;
            last.add(&norms, &numels);
        }

        let g1 = first.top_by_gradient(TOP_K);
        let u1 = first.top_by_unit_gradient(TOP_K);
        let g2 = last.top_by_gradient(TOP_K);
        let u2 = last.top_by_unit_gradient(TOP_K);
        for r in 0..TOP_K {
            t.row(vec![
                if r == 0 { task.to_string() } else { String::new() },
                (r + 1).to_string(),
                g1[r].0.clone(),
                u1[r].0.clone(),
                g2[r].0.clone(),
                u2[r].0.clone(),
            ]);
        }

        // paper's qualitative claims, checked quantitatively:
        let head_frac = last.mass_fraction(|n| {
            n.starts_with("classifier.") || n.starts_with("pooler.")
                || n.starts_with("embeddings.")
                || n.contains(".intermediate.")
        });
        let unit_top: Vec<String> = u2.iter().map(|(n, _)| n.clone()).collect();
        let norm_or_head_in_unit_top = unit_top.iter().filter(|n| {
            n.contains("LayerNorm") || n.starts_with("classifier.")
                || n.starts_with("embeddings.") || n.starts_with("pooler.")
        }).count();
        println!(
            "  {task}: head+emb+intermediate raw-grad mass {:.0}%, \
             norm/head entries in unit-grad top-{TOP_K}: {}/{TOP_K}",
            head_frac * 100.0,
            norm_or_head_in_unit_top
        );
    }

    println!("{}", t.render());
    t.save(&coord.config.results_dir, "table1")?;
    Ok(())
}
