//! Fig 1: distribution of self-attention output 2-norms per layer, before
//! and after full fine-tuning, plus the relative change Δ (paper Sec. 2.1).
//!
//! Expected shape: norms grow after fine-tuning, with the change
//! concentrated in the middle/late layers and peaking at the last layer —
//! the observation that motivates injecting the adapter right after the
//! self-attention outputs.

use anyhow::Result;

use crate::analysis::norm_shift;
use crate::coordinator::{Coordinator, RunSpec};
use crate::report::{BoxStats, Table};
use crate::train::evaluate;

use super::TASK_ORDER;

/// Regenerate Fig. 1 (attention-output norm shifts).
pub fn run(coord: &mut Coordinator) -> Result<()> {
    let model = coord
        .config
        .models
        .first()
        .cloned()
        .unwrap_or_else(|| "base".into());
    let info = coord.engine.manifest().model(&model)?.clone();
    let layers = info.layers;

    // pooled per-layer samples across all tasks
    let mut before: Vec<Vec<f32>> = vec![Vec::new(); layers];
    let mut after: Vec<Vec<f32>> = vec![Vec::new(); layers];

    for task in TASK_ORDER {
        coord.backbone(&model)?;
        coord.dataset(task, "dev")?;
        // "before": the pre-trained backbone
        {
            let backbone = coord.backbones_get(&model).unwrap();
            let dev = coord.datasets_get(task, "dev").unwrap();
            let pre = evaluate(&coord.engine, &model, backbone, dev)?;
            for l in 0..layers {
                before[l].extend(&pre.attn_norms[l]);
            }
        }
        // "after": full fine-tuning on the task (cached run + stored ckpt)
        let spec = RunSpec {
            model: model.clone(),
            task: task.to_string(),
            method: "full".into(),
            seed: coord.config.seed,
        };
        let (_, store) = coord.run_with_store(&spec)?;
        let dev = coord.datasets_get(task, "dev").unwrap();
        let post = evaluate(&coord.engine, &model, &store, dev)?;
        for l in 0..layers {
            after[l].extend(&post.attn_norms[l]);
        }
    }

    let shifts = norm_shift(&before, &after);
    let mut t = Table::new(
        &format!(
            "Fig 1: ||self-attention output||_2 per layer, before/after full FT \
             ({model}, all tasks pooled)"
        ),
        &["layer", "before median", "before IQR", "after median", "after IQR",
          "delta mean", "delta median"],
    );
    for s in &shifts {
        let iqr = |b: &BoxStats| format!("[{:.1}, {:.1}]", b.q1, b.q3);
        t.row(vec![
            s.layer.to_string(),
            format!("{:.1}", s.before.median),
            iqr(&s.before),
            format!("{:.1}", s.after.median),
            iqr(&s.after),
            format!("{:+.3}", s.delta.mean),
            format!("{:+.3}", s.delta.median),
        ]);
    }
    println!("{}", t.render());
    t.save(&coord.config.results_dir, "fig1")?;

    // paper's qualitative check: late layers shift more than early ones
    let half = layers / 2;
    let early: f64 = shifts[..half].iter().map(|s| s.delta.mean).sum::<f64>()
        / half.max(1) as f64;
    let late: f64 = shifts[half..].iter().map(|s| s.delta.mean).sum::<f64>()
        / (layers - half).max(1) as f64;
    println!(
        "delta mean early layers {early:+.3} vs late layers {late:+.3} \
         (paper: changes grow with depth, peak at last layer)"
    );
    Ok(())
}
