//! Fig 5: exploratory analysis of the learned adapters across tasks
//! (paper Sec. 5) — per-layer weight/bias distributions, norm-module
//! distributions under adapter tuning vs full FT, and cross-task cosine
//! similarity heatmaps.
//!
//! Expected shape: weights hover near 1.0 and are ~identical across tasks
//! (cosine ≈ 1); biases hover near 0.0 and differ strongly across tasks —
//! the basis for the shared-adapter proposal.

use anyhow::Result;

use crate::analysis::similarity::{
    extract, identity_deviation, layer_distributions, similarity_at_layer,
    similarity_avg, AdapterVectors,
};
use crate::coordinator::{Coordinator, RunSpec};
use crate::report::{BoxStats, Table};

use super::TASK_ORDER;

/// Regenerate Fig. 5 (cross-task adapter similarity).
pub fn run(coord: &mut Coordinator) -> Result<()> {
    // Paper uses RoBERTa-large here; we use the largest configured model.
    let model = coord
        .config
        .models
        .last()
        .cloned()
        .unwrap_or_else(|| "large".into());
    let info = coord.engine.manifest().model(&model)?.clone();
    let layers = info.layers;

    let tasks: Vec<&str> = if coord.config.quick {
        vec!["sst2", "rte", "mrpc", "qnli"]
    } else {
        TASK_ORDER.to_vec()
    };

    let mut adapters: Vec<AdapterVectors> = Vec::new();
    let mut ft_norm_vectors: Vec<AdapterVectors> = Vec::new();
    for task in &tasks {
        let spec = RunSpec {
            model: model.clone(),
            task: task.to_string(),
            method: "hadamard".into(),
            seed: coord.config.seed,
        };
        let (_, store) = coord.run_with_store(&spec)?;
        adapters.push(extract(task, &store, layers)?);

        let spec_ft = RunSpec { method: "full".into(), ..spec };
        let (_, store_ft) = coord.run_with_store(&spec_ft)?;
        ft_norm_vectors.push(extract(task, &store_ft, layers)?);
    }

    // (a1)(a2): adapter weight/bias distributions per layer
    let mut t = Table::new(
        &format!(
            "Fig 5 (a): Hadamard adapter vector distributions per layer \
             ({model}, all tasks pooled)"
        ),
        &["layer", "family", "min", "q1", "median", "q3", "max", "mean"],
    );
    let push_fam = |t: &mut Table, label: &str, dists: &[BoxStats]| {
        for (l, d) in dists.iter().enumerate() {
            let mut cells = vec![l.to_string(), label.to_string()];
            cells.extend(d.cells());
            t.row(cells);
        }
    };
    push_fam(&mut t, "adapter.weight",
             &layer_distributions(&adapters, |a| &a.weights));
    push_fam(&mut t, "adapter.bias",
             &layer_distributions(&adapters, |a| &a.biases));
    // (b1..b4): norm modules under adapter tuning vs full FT
    push_fam(&mut t, "norm.weight (adapter-tuned)",
             &layer_distributions(&adapters, |a| &a.norm_weights));
    push_fam(&mut t, "norm.weight (full-FT)",
             &layer_distributions(&ft_norm_vectors, |a| &a.norm_weights));
    push_fam(&mut t, "norm.bias (adapter-tuned)",
             &layer_distributions(&adapters, |a| &a.norm_biases));
    push_fam(&mut t, "norm.bias (full-FT)",
             &layer_distributions(&ft_norm_vectors, |a| &a.norm_biases));
    println!("{}", t.render());
    t.save(&coord.config.results_dir, "fig5_distributions")?;

    // (c1)(c2): cross-task cosine similarity (first, middle, average)
    let mut sims = Table::new(
        "Fig 5 (c): cross-task cosine similarity of adapter vectors",
        &["family", "layer", "task_i", "task_j", "cosine"],
    );
    let mut record = |label: &str, layer_label: &str, m: &crate::analysis::similarity::SimMatrix| {
        for (i, ti) in m.tasks.iter().enumerate() {
            for (j, tj) in m.tasks.iter().enumerate() {
                if i < j {
                    sims.row(vec![
                        label.to_string(),
                        layer_label.to_string(),
                        ti.clone(),
                        tj.clone(),
                        format!("{:.3}", m.get(i, j)),
                    ]);
                }
            }
        }
    };
    let mid = layers / 2;
    let w_first = similarity_at_layer(&adapters, 0, |a| &a.weights);
    let w_mid = similarity_at_layer(&adapters, mid, |a| &a.weights);
    let w_avg = similarity_avg(&adapters, |a| &a.weights);
    let b_first = similarity_at_layer(&adapters, 0, |a| &a.biases);
    let b_mid = similarity_at_layer(&adapters, mid, |a| &a.biases);
    let b_avg = similarity_avg(&adapters, |a| &a.biases);
    record("weight", "first", &w_first);
    record("weight", "middle", &w_mid);
    record("weight", "avg", &w_avg);
    record("bias", "first", &b_first);
    record("bias", "middle", &b_mid);
    record("bias", "avg", &b_avg);
    println!("{}", sims.render());
    sims.save(&coord.config.results_dir, "fig5_similarity")?;

    println!(
        "weight cosine (off-diag avg) {:.3} vs bias cosine {:.3} \
         (paper: weights ~1.0 reusable across tasks; biases diverge, <=0.3)",
        w_avg.off_diagonal_mean(),
        b_avg.off_diagonal_mean()
    );
    for a in &adapters {
        let d = identity_deviation(a);
        println!(
            "  {}: weight rms-dev-from-1 {:.4}, bias rms-dev-from-0 {:.4}",
            a.task, d["weight_rms_dev_from_1"], d["bias_rms_dev_from_0"]
        );
    }
    Ok(())
}
