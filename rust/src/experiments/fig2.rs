//! Fig 2: fitting full fine-tuning with element-wise functions of different
//! order (paper Sec. 2.2). Trains the adapter with linear / quadratic /
//! cubic terms unfrozen and compares the per-layer characteristic values
//! (mean adapter outputs) against full fine-tuning.
//!
//! Expected shape: all three orders track full FT closely and track *each
//! other* almost exactly — the justification for the linear (Hadamard)
//! form.

use anyhow::Result;

use crate::analysis::characteristics;
use crate::coordinator::{Coordinator, RunSpec};
use crate::report::Table;
use crate::train::evaluate;

use super::TASK_ORDER;

const SETTINGS: [&str; 4] = ["hadamard^o1", "hadamard^o2", "hadamard^o3", "full"];

/// Regenerate Fig. 2 (adapter characteristic values).
pub fn run(coord: &mut Coordinator) -> Result<()> {
    let model = coord
        .config
        .models
        .first()
        .cloned()
        .unwrap_or_else(|| "base".into());
    let info = coord.engine.manifest().model(&model)?.clone();
    let layers = info.layers;

    // tasks to pool (paper pools all 8; quick mode uses 3)
    // pool a 4-task subset (time-bounded; the paper pools all 8)
    let tasks: Vec<&str> = if coord.config.quick {
        vec!["sst2", "rte", "mrpc"]
    } else {
        vec!["sst2", "rte", "mrpc", "qnli"]
    };
    let _ = TASK_ORDER;

    // per-setting, per-layer pooled characteristic values
    let mut pooled: Vec<Vec<Vec<f32>>> =
        vec![vec![Vec::new(); layers]; SETTINGS.len()];

    for task in &tasks {
        for (si, setting) in SETTINGS.iter().enumerate() {
            let spec = RunSpec {
                model: model.clone(),
                task: task.to_string(),
                method: setting.to_string(),
                seed: coord.config.seed,
            };
            let (_, store) = coord.run_with_store(&spec)?;
            coord.dataset(task, "dev")?;
            let dev = coord.datasets_get(task, "dev").unwrap();
            let ev = evaluate(&coord.engine, &model, &store, dev)?;
            for l in 0..layers {
                pooled[si][l].extend(&ev.attn_means[l]);
            }
        }
    }

    let mut t = Table::new(
        &format!("Fig 2: characteristic values per layer (adapter orders vs full FT, {model})"),
        &["layer", "linear mean", "quadratic mean", "cubic mean", "full-FT mean",
          "linear IQR", "full IQR"],
    );
    let mut max_gap_between_orders = 0f64;
    let mut gap_to_full = 0f64;
    for l in 0..layers {
        let chars: Vec<_> = pooled
            .iter()
            .map(|p| characteristics(&p[l..l + 1])[0].dist)
            .collect();
        let o = [chars[0].mean, chars[1].mean, chars[2].mean];
        let full = chars[3].mean;
        let spread = o.iter().cloned().fold(f64::MIN, f64::max)
            - o.iter().cloned().fold(f64::MAX, f64::min);
        max_gap_between_orders = max_gap_between_orders.max(spread);
        gap_to_full = gap_to_full.max((o[0] - full).abs());
        t.row(vec![
            l.to_string(),
            format!("{:.4}", o[0]),
            format!("{:.4}", o[1]),
            format!("{:.4}", o[2]),
            format!("{:.4}", full),
            format!("[{:.3}, {:.3}]", chars[0].q1, chars[0].q3),
            format!("[{:.3}, {:.3}]", chars[3].q1, chars[3].q3),
        ]);
    }
    println!("{}", t.render());
    t.save(&coord.config.results_dir, "fig2")?;
    println!(
        "max inter-order gap {max_gap_between_orders:.4} vs max linear-to-full gap \
         {gap_to_full:.4} (paper: orders indistinguishable; linear suffices)"
    );
    Ok(())
}
