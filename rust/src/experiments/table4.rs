//! Table 4: module ablation — unfreeze any subset of {W, B, N, A} in the
//! two-stage pipeline (base model, all tasks). The paper's findings to
//! reproduce: B and N contribute most individually, B+N is the best pair,
//! and the full method (W+B+N, "Ours") wins overall, with A adding little
//! or hurting.

use anyhow::Result;

use crate::coordinator::{index_records, Coordinator};
use crate::report::Table;

/// Task subset for the ablation grid (time-bounded; the paper uses all 8).
pub const TASKS: [&str; 2] = ["mrpc", "sst2"];

/// The paper's row order (Table 4), "Ours" = W+B+N via the plain
/// "hadamard" method name.
pub const COMBOS: [&str; 12] = [
    "hadamard:W",
    "hadamard:B",
    "hadamard:N",
    "hadamard:A",
    "hadamard:W+A",
    "hadamard:W+N",
    "hadamard:B+A",
    "hadamard:B+N",
    "hadamard:W+B",
    "hadamard:W+B+N+A",
    "hadamard:W+B+A",
    "hadamard",
];

/// Regenerate Table 4 (module-combination ablation).
pub fn run(coord: &mut Coordinator) -> Result<()> {
    // Paper runs Table 4 on BERT-base; we use our smallest experiment model.
    let model = coord
        .config
        .models
        .first()
        .cloned()
        .unwrap_or_else(|| "base".into());
    let recs = coord.run_grid(&[model.clone()], &TASKS, &COMBOS)?;
    let idx = index_records(&recs);

    let mut header = vec!["Module"];
    header.extend(TASKS);
    header.push("Average");
    let mut t = Table::new(
        &format!(
            "Table 4: module ablation on {model} (W=adapter weight, B=adapter bias, \
             N=norm, A=att-norm; Ours=W+B+N)"
        ),
        &header,
    );

    let mut best: (String, f64) = (String::new(), f64::MIN);
    for combo in COMBOS {
        let label = if combo == "hadamard" {
            "W+B+N (Ours)".to_string()
        } else {
            combo.trim_start_matches("hadamard:").to_string()
        };
        let mut cells = vec![label.clone()];
        let mut sum = 0.0;
        for task in TASKS {
            let r = idx[&(model.clone(), task.to_string(), combo.to_string())];
            cells.push(format!("{:.1}", r.score));
            sum += r.score;
        }
        let avg = sum / TASKS.len() as f64;
        if avg > best.1 {
            best = (label, avg);
        }
        cells.push(format!("{avg:.1}"));
        t.row(cells);
    }
    println!("{}", t.render());
    println!("best combo: {} ({:.1}) — paper expects the full method to win", best.0, best.1);
    t.save(&coord.config.results_dir, "table4")?;
    Ok(())
}
