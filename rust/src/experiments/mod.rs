//! Experiment drivers: one per table/figure in the paper's evaluation
//! (DESIGN.md §2 experiment index). Each driver pulls runs through the
//! coordinator (cached/resumable) and writes `results/<id>.{md,csv}`.

pub mod fig1;
pub mod fig2;
pub mod fig5;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;

use anyhow::{bail, Result};

use crate::coordinator::Coordinator;

/// All experiment ids, in paper order.
pub const ALL: [&str; 8] = [
    "table1", "table2", "table3", "table4", "table5", "fig1", "fig2", "fig5",
];

/// Dispatch an experiment by id ("all" runs the full suite).
pub fn run(coord: &mut Coordinator, id: &str) -> Result<()> {
    match id {
        "table1" => table1::run(coord),
        "table2" => table2::run(coord),
        "table3" => table3::run(coord),
        "table4" => table4::run(coord),
        "table5" | "fig4" => table5::run(coord),
        "fig1" => fig1::run(coord),
        "fig2" => fig2::run(coord),
        "fig5" => fig5::run(coord),
        "all" => {
            for id in ALL {
                println!("=== experiment {id} ===");
                run(coord, id)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment '{other}' (have {ALL:?} or 'all')"),
    }
}

/// The paper's Table 2/3 task column order.
pub const TASK_ORDER: [&str; 8] =
    ["mrpc", "cola", "mnli", "qnli", "qqp", "rte", "sst2", "stsb"];

/// Table 5's task subset (paper drops MRPC and SST-2 there).
pub const TABLE5_TASKS: [&str; 4] = ["cola", "qnli", "rte", "stsb"];
