//! # hadapt
//!
//! Reproduction of *Hadamard Adapter: An Extreme Parameter-Efficient Adapter
//! Tuning Method for Pre-trained Language Models* (CIKM 2023): the synthetic
//! GLUE data substrate, the PEFT method registry, the two-stage tuning
//! coordinator, and the experiment harness that regenerates every table and
//! figure of the paper's evaluation — all driven through a backend-agnostic
//! [`runtime::Engine`].
//!
//! ## Two backends, one harness
//!
//! * **Native** (default): [`runtime::NativeBackend`] evaluates the
//!   transformer forward pass and per-group backward passes in pure Rust,
//!   mirroring the JAX oracles in `python/compile/kernels/ref.py`
//!   (hadamard, layernorm, masked attention; gradients validated against
//!   `jax.grad`). The kernels are cache-blocked, register-tiled and
//!   sharded over a std-only pool of persistent parked workers
//!   ([`runtime::Pool`], the `threads` config key; zero spawns and zero
//!   allocations in steady state). [`runtime::Manifest::builtin`] supplies the
//!   model inventory, so `cargo build && cargo test` — and the full
//!   experiment suite — run hermetically: no Python, no artifacts, no
//!   network.
//! * **XLA** (`--features xla`): the original PJRT path. Layer 1 (Pallas
//!   kernels) and Layer 2 (the JAX transformer with every PEFT module
//!   identity-initialized) are AOT-lowered to HLO text by `make artifacts`;
//!   `runtime::XlaBackend` compiles and executes them. The in-tree
//!   `vendor/xla` crate is an offline stub — swap in the published `xla`
//!   crate to actually run this path (select it with `backend=xla` in the
//!   config).
//!
//! ## Workloads
//!
//! Besides the two-stage tuning pipeline and the experiment drivers, the
//! runtime serves: [`runtime::ServeSession`] holds one packed frozen
//! backbone plus a bank of per-task Hadamard adapters
//! ([`runtime::AdapterBank`]) and micro-batches classification requests
//! *across* tasks through the forward-only [`runtime::Engine::infer`]
//! entry — the paper's parameter-efficiency claim turned into a
//! multi-tenant throughput claim (`hadapt serve-demo` drives it from the
//! CLI).
//!
//! Python never runs on the training path in either mode.
//!
//! The repo-root `ARCHITECTURE.md` documents the runtime's five-layer
//! design, the determinism matrix and the counter-verified invariants
//! (zero-alloc / zero-spawn / zero-repack steady states).
#![warn(missing_docs)]

/// Analysis passes behind the paper's figures (gradient probes,
/// similarity matrices, characteristic distributions).
pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod methods;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod report;
pub mod runtime;
pub mod train;

pub mod util;
pub use anyhow::{anyhow, bail, Context, Result};
