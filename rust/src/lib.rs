//! # hadapt
//!
//! Reproduction of *Hadamard Adapter: An Extreme Parameter-Efficient Adapter
//! Tuning Method for Pre-trained Language Models* (CIKM 2023) as a
//! three-layer Rust + JAX + Pallas framework.
//!
//! Layer 1 (Pallas kernels) and Layer 2 (the JAX transformer with every PEFT
//! module identity-initialized) are AOT-lowered to HLO text at build time
//! (`make artifacts`); this crate is Layer 3: the PJRT runtime, the synthetic
//! GLUE data substrate, the PEFT method registry, the two-stage tuning
//! coordinator, and the experiment harness that regenerates every table and
//! figure of the paper's evaluation. Python never runs on the training path.
pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod methods;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod report;
pub mod runtime;
pub mod train;

pub mod util;
pub use anyhow::{anyhow, bail, Context, Result};
