//! The synthetic GLUE suite: eight task generators matching the paper's
//! benchmark in type and metric (DESIGN.md §3 substitutions).
//!
//! | task   | paper analogue | type                      | metric   |
//! |--------|----------------|---------------------------|----------|
//! | sst2   | SST-2          | single-sentence, 2-class  | accuracy |
//! | cola   | CoLA           | single-sentence, 2-class  | Matthews |
//! | mrpc   | MRPC           | sentence pair, 2-class    | accuracy |
//! | stsb   | STS-B          | sentence pair, regression | Pearson  |
//! | qqp    | QQP            | sentence pair, 2-class    | accuracy |
//! | mnli   | MNLI           | sentence pair, 3-class    | accuracy |
//! | qnli   | QNLI           | sentence pair, 2-class    | accuracy |
//! | rte    | RTE            | sentence pair, 2-class    | accuracy |
//!
//! Every generator is deterministic in (task, seed, split) and emits labels
//! that are *statistically* recoverable from corpus features but not
//! trivially linearly separable from raw tokens — the regime in which the
//! classifier-probe lands well below full fine-tuning, which is the paper's
//! Table 2 backdrop.

use crate::util::Rng;

use super::corpus::Corpus;
use super::vocab;

/// Task label.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Label {
    /// Classification label.
    Class(usize),
    /// Regression score (STS-B style).
    Score(f32),
}

/// One example: one or two token sequences plus a label.
#[derive(Debug, Clone)]
pub struct Example {
    /// First sentence, as token ids.
    pub seq_a: Vec<i32>,
    /// Second sentence for pair tasks.
    pub seq_b: Option<Vec<i32>>,
    /// Gold label.
    pub label: Label,
}

/// Evaluation metric (paper Sec. 4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    /// Plain accuracy.
    Accuracy,
    /// Matthews correlation (CoLA).
    Matthews,
    /// Pearson correlation (STS-B).
    Pearson,
}

/// Static description of a task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TaskInfo {
    /// Task name (GLUE-style lowercase).
    pub name: &'static str,
    /// Number of classes (regression tasks use the regressor head).
    pub classes: usize,
    /// Whether the task is scored by regression.
    pub regression: bool,
    /// Headline metric.
    pub metric: Metric,
    /// Full train-split size.
    pub train_size: usize,
    /// Full dev-split size.
    pub dev_size: usize,
}

/// All eight tasks, in the paper's Table 2 column order.
pub const TASKS: [TaskInfo; 8] = [
    TaskInfo {
        name: "mrpc",
        classes: 2,
        regression: false,
        metric: Metric::Accuracy,
        train_size: 1536,
        dev_size: 512,
    },
    TaskInfo {
        name: "cola",
        classes: 2,
        regression: false,
        metric: Metric::Matthews,
        train_size: 2048,
        dev_size: 512,
    },
    TaskInfo {
        name: "mnli",
        classes: 3,
        regression: false,
        metric: Metric::Accuracy,
        train_size: 4096,
        dev_size: 512,
    },
    TaskInfo {
        name: "qnli",
        classes: 2,
        regression: false,
        metric: Metric::Accuracy,
        train_size: 4096,
        dev_size: 512,
    },
    TaskInfo {
        name: "qqp",
        classes: 2,
        regression: false,
        metric: Metric::Accuracy,
        train_size: 4096,
        dev_size: 512,
    },
    TaskInfo {
        name: "rte",
        classes: 2,
        regression: false,
        metric: Metric::Accuracy,
        train_size: 1024,
        dev_size: 384,
    },
    TaskInfo {
        name: "sst2",
        classes: 2,
        regression: false,
        metric: Metric::Accuracy,
        train_size: 4096,
        dev_size: 512,
    },
    TaskInfo {
        name: "stsb",
        classes: 1,
        regression: true,
        metric: Metric::Pearson,
        train_size: 1536,
        dev_size: 512,
    },
];

/// Look up a task by name.
pub fn task_info(name: &str) -> Option<TaskInfo> {
    TASKS.iter().copied().find(|t| t.name == name)
}

/// A materialized dataset split.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// The task this dataset instantiates.
    pub info: TaskInfo,
    /// Generated examples.
    pub examples: Vec<Example>,
}

/// Generate a split. `split` enters the seed so train/dev never overlap.
pub fn generate(info: TaskInfo, seed: u64, split: &str, size: usize) -> Dataset {
    let tag = crate::util::fnv1a(&format!("{}:{}", info.name, split));
    let mut corpus = Corpus::new(seed ^ tag);
    let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9).wrapping_add(tag));
    let mut examples = Vec::with_capacity(size);
    for _ in 0..size {
        examples.push(match info.name {
            "sst2" => gen_sst2(&mut corpus, &mut rng),
            "cola" => gen_cola(&mut corpus, &mut rng),
            "mrpc" => gen_paraphrase(&mut corpus, &mut rng, false),
            "qqp" => gen_paraphrase(&mut corpus, &mut rng, true),
            "stsb" => gen_stsb(&mut corpus, &mut rng),
            "mnli" => gen_nli(&mut corpus, &mut rng, 3),
            "rte" => gen_nli(&mut corpus, &mut rng, 2),
            "qnli" => gen_qnli(&mut corpus, &mut rng),
            other => panic!("unknown task '{other}'"),
        });
    }
    Dataset { info, examples }
}

/// SST-2-like: inject sentiment lexicon tokens; label = dominant polarity.
/// A minority of "hard" examples mixes both polarities.
fn gen_sst2(c: &mut Corpus, rng: &mut Rng) -> Example {
    let mut s = c.sentence().tokens;
    let positive = rng.chance(0.5);
    let strong = rng.range(2, 5);
    let weak = if rng.chance(0.3) { rng.range(1, strong) } else { 0 };
    for i in 0..strong {
        let tok = if positive {
            vocab::band_start(0) + rng.below(vocab::SENT_K as usize) as i32
        } else {
            vocab::band_start(1) + rng.below(vocab::SENT_K as usize) as i32
        };
        let pos = rng.below(s.len());
        let _ = i;
        s.insert(pos, tok);
    }
    for _ in 0..weak {
        let tok = if positive {
            vocab::band_start(1) + rng.below(vocab::SENT_K as usize) as i32
        } else {
            vocab::band_start(0) + rng.below(vocab::SENT_K as usize) as i32
        };
        let pos = rng.below(s.len());
        s.insert(pos, tok);
    }
    Example { seq_a: s, seq_b: None, label: Label::Class(positive as usize) }
}

/// CoLA-like acceptability: "grammatical" sentences have locally monotone
/// token runs (the corpus's coherent order); corruption shuffles the
/// sentence and breaks a topic token, making it "unacceptable".
fn gen_cola(c: &mut Corpus, rng: &mut Rng) -> Example {
    let s = c.sentence();
    let acceptable = rng.chance(0.5);
    let mut toks = s.tokens;
    if !acceptable {
        rng.shuffle(&mut toks);
        // splice 1-2 out-of-topic tokens (agreement violation)
        for _ in 0..rng.range(1, 3) {
            let other = (s.topic + rng.range(1, vocab::TOPICS)) % vocab::TOPICS;
            let tok = vocab::band_start(other) + rng.below(vocab::BAND as usize) as i32;
            let pos = rng.below(toks.len());
            toks[pos] = tok;
        }
    } else {
        // make the local order strictly coherent: sort ascending runs of 3
        for w in toks.chunks_mut(3) {
            w.sort();
        }
    }
    Example { seq_a: toks, seq_b: None, label: Label::Class(acceptable as usize) }
}

/// MRPC/QQP-like paraphrase: positive pairs are synonym-substituted +
/// lightly reordered copies; negatives are different sentences of the same
/// topic (hard negatives).
fn gen_paraphrase(c: &mut Corpus, rng: &mut Rng, question: bool) -> Example {
    let a = c.sentence();
    let is_para = rng.chance(0.5);
    let mut b = if is_para {
        let mut t = a.tokens.clone();
        for tok in t.iter_mut() {
            if rng.chance(0.4) {
                *tok = vocab::synonym(*tok);
            }
        }
        if t.len() > 3 && rng.chance(0.5) {
            let i = rng.below(t.len() - 2);
            t.swap(i, i + 1);
        }
        t
    } else {
        c.sentence_with_topic(a.topic).tokens
    };
    let mut seq_a = a.tokens;
    if question {
        seq_a.push(vocab::QMARK);
        b.push(vocab::QMARK);
    }
    Example { seq_a, seq_b: Some(b), label: Label::Class(is_para as usize) }
}

/// STS-B-like: b shares a controlled fraction of a's tokens; the gold score
/// is 5 * overlap (graded similarity, the paper's Pearson task).
fn gen_stsb(c: &mut Corpus, rng: &mut Rng) -> Example {
    let a = c.sentence();
    let overlap = rng.next_f32();
    let n = a.tokens.len();
    let keep = ((overlap * n as f32).round() as usize).min(n);
    let kept = rng.choose_distinct(n, keep);
    let mut b: Vec<i32> = Vec::with_capacity(n);
    let fresh = c.sentence_with_topic(a.topic).tokens;
    for i in 0..n {
        if kept.contains(&i) {
            b.push(a.tokens[i]);
        } else {
            b.push(fresh[i % fresh.len()]);
        }
    }
    let score = 5.0 * keep as f32 / n as f32;
    Example { seq_a: a.tokens, seq_b: Some(b), label: Label::Score(score) }
}

/// MNLI/RTE-like NLI. entailment: b ⊂ a (sub-sequence + synonyms);
/// contradiction: antonym-mapped subset with a negation marker;
/// neutral: same-topic continuation. RTE collapses {contradiction, neutral}
/// into not-entailment.
fn gen_nli(c: &mut Corpus, rng: &mut Rng, classes: usize) -> Example {
    let a = c.sentence();
    let class = rng.below(classes);
    let n = a.tokens.len();
    let b = match class {
        // entailment
        1 => {
            let k = rng.range(n / 2, n.max(2));
            let mut idx = rng.choose_distinct(n, k);
            idx.sort();
            idx.iter()
                .map(|&i| {
                    let t = a.tokens[i];
                    if rng.chance(0.3) { vocab::synonym(t) } else { t }
                })
                .collect()
        }
        // contradiction (class 0 in MNLI; "not entailment" in RTE)
        0 => {
            let k = rng.range(n / 2, n.max(2));
            let mut idx = rng.choose_distinct(n, k);
            idx.sort();
            let mut t: Vec<i32> =
                idx.iter().map(|&i| vocab::antonym(a.tokens[i])).collect();
            let pos = rng.below(t.len().max(1));
            t.insert(pos, vocab::NEG_MARKER);
            t
        }
        // neutral
        _ => c.continuation(&a, rng.range(n / 2, n + 1)).tokens,
    };
    Example { seq_a: a.tokens, seq_b: Some(b), label: Label::Class(class) }
}

/// QNLI-like: does the sentence contain the answer to the question?
/// The answer token is a fixed learnable mapping of the question's key
/// token (vocab::answer_token).
fn gen_qnli(c: &mut Corpus, rng: &mut Rng) -> Example {
    let q = c.sentence();
    let key = q.tokens[rng.below(q.tokens.len())];
    let answer = vocab::answer_token(key);
    let mut sent = c.sentence_with_topic(vocab::TOPICS - 1).tokens;
    let has_answer = rng.chance(0.5);
    if has_answer {
        let pos = rng.below(sent.len());
        sent[pos] = answer;
    } else {
        // scrub accidental hits
        for t in sent.iter_mut() {
            if *t == answer {
                *t = vocab::synonym(*t);
                if *t == answer {
                    *t = answer - 1;
                }
            }
        }
    }
    let mut seq_a = q.tokens;
    seq_a.push(vocab::QMARK);
    Example {
        seq_a,
        seq_b: Some(sent),
        label: Label::Class(has_answer as usize),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate() {
        for info in TASKS {
            let d = generate(info, 11, "train", 32);
            assert_eq!(d.examples.len(), 32);
            for e in &d.examples {
                assert!(!e.seq_a.is_empty());
                match (info.regression, e.label) {
                    (true, Label::Score(s)) => assert!((0.0..=5.0).contains(&s)),
                    (false, Label::Class(c)) => assert!(c < info.classes),
                    other => panic!("label/type mismatch {other:?} for {}", info.name),
                }
                let pair_task = info.name != "sst2" && info.name != "cola";
                assert_eq!(e.seq_b.is_some(), pair_task, "{}", info.name);
            }
        }
    }

    #[test]
    fn deterministic_and_split_disjoint() {
        let info = task_info("sst2").unwrap();
        let a = generate(info, 5, "train", 16);
        let b = generate(info, 5, "train", 16);
        assert_eq!(a.examples[0].seq_a, b.examples[0].seq_a);
        let dev = generate(info, 5, "dev", 16);
        assert_ne!(a.examples[0].seq_a, dev.examples[0].seq_a);
    }

    #[test]
    fn labels_roughly_balanced() {
        for info in TASKS.iter().filter(|t| !t.regression) {
            let d = generate(*info, 13, "train", 400);
            let mut counts = vec![0usize; info.classes];
            for e in &d.examples {
                if let Label::Class(c) = e.label {
                    counts[c] += 1;
                }
            }
            for (c, &k) in counts.iter().enumerate() {
                assert!(
                    k as f64 > 0.5 * 400.0 / info.classes as f64,
                    "{} class {c}: {k}",
                    info.name
                );
            }
        }
    }

    #[test]
    fn sst2_signal_present() {
        // The planted lexicon should make labels recoverable by counting.
        let d = generate(task_info("sst2").unwrap(), 17, "train", 200);
        let mut correct = 0;
        for e in &d.examples {
            let pos = e.seq_a.iter().filter(|&&t| vocab::is_positive(t)).count();
            let neg = e.seq_a.iter().filter(|&&t| vocab::is_negative(t)).count();
            let guess = (pos > neg) as usize;
            if Label::Class(guess) == e.label {
                correct += 1;
            }
        }
        assert!(correct > 170, "lexicon baseline {correct}/200");
    }

    #[test]
    fn stsb_scores_span_range() {
        let d = generate(task_info("stsb").unwrap(), 19, "train", 200);
        let scores: Vec<f32> = d
            .examples
            .iter()
            .map(|e| match e.label {
                Label::Score(s) => s,
                _ => unreachable!(),
            })
            .collect();
        let lo = scores.iter().cloned().fold(f32::MAX, f32::min);
        let hi = scores.iter().cloned().fold(f32::MIN, f32::max);
        assert!(lo < 1.0 && hi > 4.0, "lo={lo} hi={hi}");
    }
}
