//! Data substrate: synthetic language corpus, the eight-task synthetic GLUE
//! suite, and batching into artifact-shaped tensors.

pub mod batcher;
pub mod corpus;
pub mod tasks;
pub mod vocab;

pub use batcher::{class_mask, encode_into, make_batch, Batch, BatchIter};
pub use corpus::{mlm_batch, Corpus, MlmBatch, Sentence};
pub use tasks::{generate, task_info, Dataset, Example, Label, Metric, TaskInfo, TASKS};
