//! Batcher: examples -> the fixed-shape host tensors the artifacts take.
//!
//! Encoding follows BERT: `[CLS] a [SEP]` or `[CLS] a [SEP] b [SEP]`,
//! truncated pair-proportionally to `seq_len`, token_type 0/1 per segment,
//! attention mask 1 on real tokens. Classification labels are one-hot over
//! the global 3-class head with a per-task class mask (see the L2 masked CE).

use crate::util::Rng;

use super::tasks::{Dataset, Label};
use super::vocab;

/// A classification/regression batch in host form.
#[derive(Debug, Clone)]
pub struct Batch {
    /// Examples in the batch (padding included).
    pub size: usize,
    /// Tokens per example.
    pub seq: usize,
    /// Token ids, `[size, seq]`.
    pub tokens: Vec<i32>,
    /// Segment ids, `[size, seq]`.
    pub type_ids: Vec<i32>,
    /// Attention mask, `[size, seq]`.
    pub attn_mask: Vec<f32>,
    /// one-hot [B, 3] for classification tasks.
    pub labels_onehot: Vec<f32>,
    /// f32 [B] for regression tasks.
    pub labels_f32: Vec<f32>,
    /// integer labels (for metrics).
    pub labels: Vec<usize>,
    /// number of real (non-repeated) examples in the batch.
    pub real: usize,
}

/// Encode one example's raw sentences into caller-provided row buffers
/// (each exactly `seq` long) — the single-example entry the serve path
/// re-encodes into its resident batch buffers, and what [`make_batch`]
/// loops over.
///
/// The sentence budget is `seq` minus the special tokens, *saturating*: a
/// degenerate `seq_len` (smaller than `[CLS] ... [SEP] ... [SEP]`) clamps
/// instead of underflowing `usize` (which used to panic), and the layout
/// is truncated to `seq` so even `seq_len < 3` never writes out of
/// bounds. Under proportional pair truncation every present segment keeps
/// at least one token whenever the budget allows.
pub fn encode_into(
    seq_a: &[i32],
    seq_b: Option<&[i32]>,
    seq: usize,
    tokens: &mut [i32],
    type_ids: &mut [i32],
    attn: &mut [f32],
) {
    let b_len = seq_b.map_or(0, |b| b.len());
    // budget: CLS + a + SEP (+ b + SEP)
    let specials = if b_len > 0 { 3 } else { 2 };
    let avail = seq.saturating_sub(specials);
    let (a_keep, b_keep) = if b_len == 0 {
        (seq_a.len().min(avail), 0)
    } else {
        // proportional truncation
        let total = seq_a.len() + b_len;
        if total <= avail {
            (seq_a.len(), b_len)
        } else if avail == 0 {
            (0, 0)
        } else {
            // keep a's share, but leave b at least one token when
            // avail >= 2 (the old `.max(1)` could drive `avail - a_k`
            // below zero and underflow)
            let a_k = (avail * seq_a.len() / total)
                .clamp(1, (avail - 1).max(1))
                .min(seq_a.len());
            (a_k, avail - a_k)
        }
    };
    let mut enc: Vec<(i32, i32)> = Vec::with_capacity(a_keep + b_keep + specials);
    enc.push((vocab::CLS, 0));
    for &t in &seq_a[..a_keep] {
        enc.push((t, 0));
    }
    enc.push((vocab::SEP, 0));
    if let Some(bseq) = seq_b {
        for &t in &bseq[..b_keep] {
            enc.push((t, 1));
        }
        enc.push((vocab::SEP, 1));
    }
    enc.truncate(seq);
    for (p, &(tok, ty)) in enc.iter().enumerate() {
        tokens[p] = tok;
        type_ids[p] = ty;
        attn[p] = 1.0;
    }
    for p in enc.len()..seq {
        tokens[p] = vocab::PAD;
        type_ids[p] = 0;
        attn[p] = 0.0;
    }
}

/// Build a batch from `examples[idx]` for the given indices; if fewer than
/// `batch` indices are given, the last example is repeated (its rows count
/// toward padding, not metrics — `real` records the cutoff).
pub fn make_batch(ds: &Dataset, idx: &[usize], batch: usize, seq: usize) -> Batch {
    assert!(!idx.is_empty());
    let mut out = Batch {
        size: batch,
        seq,
        tokens: vec![0; batch * seq],
        type_ids: vec![0; batch * seq],
        attn_mask: vec![0.0; batch * seq],
        labels_onehot: vec![0.0; batch * 3],
        labels_f32: vec![0.0; batch],
        labels: vec![0; batch],
        real: idx.len().min(batch),
    };
    for b in 0..batch {
        let e = &ds.examples[idx[b.min(idx.len() - 1)]];
        encode_into(
            &e.seq_a,
            e.seq_b.as_deref(),
            seq,
            &mut out.tokens[b * seq..(b + 1) * seq],
            &mut out.type_ids[b * seq..(b + 1) * seq],
            &mut out.attn_mask[b * seq..(b + 1) * seq],
        );
        match e.label {
            Label::Class(c) => {
                out.labels_onehot[b * 3 + c] = 1.0;
                out.labels[b] = c;
            }
            Label::Score(s) => {
                out.labels_f32[b] = s;
                // regression tasks keep onehot zero
            }
        }
    }
    out
}

/// Class mask for a task ([1,1,0] for 2-class, [1,1,1] for 3-class).
pub fn class_mask(classes: usize) -> Vec<f32> {
    (0..3).map(|c| if c < classes { 1.0 } else { 0.0 }).collect()
}

/// Epoch iterator: shuffled full batches over a dataset.
pub struct BatchIter<'a> {
    ds: &'a Dataset,
    order: Vec<usize>,
    cursor: usize,
    batch: usize,
    seq: usize,
}

impl<'a> BatchIter<'a> {
    /// Shuffled iteration for training.
    pub fn new(ds: &'a Dataset, rng: &mut Rng, batch: usize, seq: usize) -> Self {
        let mut order: Vec<usize> = (0..ds.examples.len()).collect();
        rng.shuffle(&mut order);
        BatchIter { ds, order, cursor: 0, batch, seq }
    }

    /// Sequential (unshuffled) iteration for evaluation.
    pub fn sequential(ds: &'a Dataset, batch: usize, seq: usize) -> Self {
        let order: Vec<usize> = (0..ds.examples.len()).collect();
        BatchIter { ds, order, cursor: 0, batch, seq }
    }
}

impl<'a> Iterator for BatchIter<'a> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        if self.cursor >= self.order.len() {
            return None;
        }
        let end = (self.cursor + self.batch).min(self.order.len());
        let idx = &self.order[self.cursor..end];
        self.cursor = end;
        Some(make_batch(self.ds, idx, self.batch, self.seq))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::{generate, task_info};

    #[test]
    fn single_sentence_layout() {
        let ds = generate(task_info("sst2").unwrap(), 1, "train", 8);
        let b = make_batch(&ds, &[0, 1, 2, 3], 4, 32);
        assert_eq!(b.tokens[0], vocab::CLS);
        let row = &b.tokens[0..32];
        assert!(row.contains(&vocab::SEP));
        // single sentence => all type ids 0
        assert!(b.type_ids[0..32].iter().all(|&t| t == 0));
        // attention mask matches non-pad prefix
        for p in 0..32 {
            let is_real = row[p] != vocab::PAD;
            assert_eq!(b.attn_mask[p] > 0.5, is_real, "pos {p}");
        }
    }

    #[test]
    fn pair_layout_has_segment_one() {
        let ds = generate(task_info("mnli").unwrap(), 1, "train", 8);
        let b = make_batch(&ds, &[0], 1, 32);
        assert!(b.type_ids[0..32].iter().any(|&t| t == 1));
        // after the 2nd segment only PAD with type 0 mask 0
        let seps: Vec<usize> =
            (0..32).filter(|&p| b.tokens[p] == vocab::SEP).collect();
        assert!(seps.len() >= 2);
    }

    #[test]
    fn truncation_never_overflows() {
        let ds = generate(task_info("qqp").unwrap(), 2, "train", 64);
        for i in 0..64 {
            let b = make_batch(&ds, &[i], 1, 16);
            assert_eq!(b.tokens.len(), 16);
            assert_eq!(b.attn_mask.iter().filter(|&&m| m > 0.0).count()
                       <= 16, true);
        }
    }

    #[test]
    fn degenerate_seq_len_never_panics() {
        // regression: seq < specials used to underflow `seq - specials`
        // (panic in debug, wrap in release), and the pair branch could hit
        // `avail - a_k` underflow when avail <= 1.
        for task in ["sst2", "mnli", "qqp"] {
            let ds = generate(task_info(task).unwrap(), 5, "train", 8);
            for seq in 0..6 {
                for i in 0..8 {
                    let b = make_batch(&ds, &[i], 1, seq);
                    assert_eq!(b.tokens.len(), seq, "{task} seq={seq}");
                    // row never writes past seq and mask stays a 0/1 prefix
                    let real = b.attn_mask.iter().filter(|&&m| m > 0.0).count();
                    assert!(real <= seq, "{task} seq={seq}");
                    if seq > 0 {
                        assert_eq!(b.tokens[0], vocab::CLS, "{task} seq={seq}");
                    }
                    for p in real..seq {
                        assert_eq!(b.tokens[p], vocab::PAD, "{task} seq={seq} p={p}");
                    }
                }
            }
        }
    }

    #[test]
    fn pair_truncation_keeps_both_segments_when_budget_allows() {
        // with avail = seq - 3 >= 2, both sentences must keep >= 1 token
        let ds = generate(task_info("mnli").unwrap(), 7, "train", 16);
        for seq in 5..12 {
            for i in 0..16 {
                let b = make_batch(&ds, &[i], 1, seq);
                let row = &b.tokens[..seq];
                let types = &b.type_ids[..seq];
                let n_a = (0..seq)
                    .filter(|&p| {
                        types[p] == 0 && row[p] != vocab::CLS && row[p] != vocab::SEP
                            && b.attn_mask[p] > 0.0
                    })
                    .count();
                let n_b = (0..seq)
                    .filter(|&p| types[p] == 1 && row[p] != vocab::SEP)
                    .count();
                assert!(n_a >= 1, "seq={seq} row {i}: segment a emptied");
                assert!(n_b >= 1, "seq={seq} row {i}: segment b emptied");
            }
        }
    }

    #[test]
    fn onehot_and_class_mask() {
        let ds = generate(task_info("mnli").unwrap(), 3, "train", 8);
        let b = make_batch(&ds, &[0, 1, 2, 3], 4, 32);
        for row in 0..4 {
            let one: f32 = b.labels_onehot[row * 3..row * 3 + 3].iter().sum();
            assert_eq!(one, 1.0);
        }
        assert_eq!(class_mask(2), vec![1.0, 1.0, 0.0]);
        assert_eq!(class_mask(3), vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn batch_iter_covers_dataset() {
        let ds = generate(task_info("rte").unwrap(), 4, "train", 50);
        let mut rng = crate::util::Rng::new(1);
        let n: usize = BatchIter::new(&ds, &mut rng, 16, 32)
            .map(|b| b.real)
            .sum();
        assert_eq!(n, 50);
        // last batch padded by repetition but real < batch
        let last = BatchIter::new(&ds, &mut rng, 16, 32).last().unwrap();
        assert_eq!(last.real, 50 % 16);
    }
}
