//! Batcher: examples -> the fixed-shape host tensors the artifacts take.
//!
//! Encoding follows BERT: `[CLS] a [SEP]` or `[CLS] a [SEP] b [SEP]`,
//! truncated pair-proportionally to `seq_len`, token_type 0/1 per segment,
//! attention mask 1 on real tokens. Classification labels are one-hot over
//! the global 3-class head with a per-task class mask (see the L2 masked CE).

use crate::util::Rng;

use super::tasks::{Dataset, Label};
use super::vocab;

/// A classification/regression batch in host form.
#[derive(Debug, Clone)]
pub struct Batch {
    /// Examples in the batch (padding included).
    pub size: usize,
    /// Tokens per example.
    pub seq: usize,
    /// Token ids, `[size, seq]`.
    pub tokens: Vec<i32>,
    /// Segment ids, `[size, seq]`.
    pub type_ids: Vec<i32>,
    /// Attention mask, `[size, seq]`.
    pub attn_mask: Vec<f32>,
    /// one-hot [B, 3] for classification tasks.
    pub labels_onehot: Vec<f32>,
    /// f32 [B] for regression tasks.
    pub labels_f32: Vec<f32>,
    /// integer labels (for metrics).
    pub labels: Vec<usize>,
    /// number of real (non-repeated) examples in the batch.
    pub real: usize,
}

/// Encode one example's raw sentences into caller-provided row buffers
/// (each exactly `seq` long) — the single-example entry the serve path
/// re-encodes into its resident batch buffers, and what [`make_batch`]
/// loops over.
///
/// The sentence budget is `seq` minus the special tokens, *saturating*: a
/// degenerate `seq_len` (smaller than `[CLS] ... [SEP] ... [SEP]`) clamps
/// instead of underflowing `usize` (which used to panic), and the layout
/// stops at `seq` so even `seq_len < 3` never writes out of bounds. Under
/// proportional pair truncation every present segment keeps at least one
/// token whenever the budget allows.
///
/// Since the wire front door landed this function is reachable with fully
/// attacker-controlled `seq_a`/`seq_b` lengths, so it is hardened against
/// that class: the proportional share is computed in `u128` (the old
/// `avail * |a|` product was `usize` math and could overflow for gigantic
/// sentences), row shapes are asserted up front instead of trusting the
/// caller, and the row is written in place with no temporary allocation
/// (the serve path calls this once per request on the zero-alloc hot
/// path).
pub fn encode_into(
    seq_a: &[i32],
    seq_b: Option<&[i32]>,
    seq: usize,
    tokens: &mut [i32],
    type_ids: &mut [i32],
    attn: &mut [f32],
) {
    assert_eq!(tokens.len(), seq, "tokens row must be exactly seq long");
    assert_eq!(type_ids.len(), seq, "type_ids row must be exactly seq long");
    assert_eq!(attn.len(), seq, "attn row must be exactly seq long");
    let b_len = seq_b.map_or(0, |b| b.len());
    // budget: CLS + a + SEP (+ b + SEP)
    let specials = if b_len > 0 { 3 } else { 2 };
    let avail = seq.saturating_sub(specials);
    let (a_keep, b_keep) = if b_len == 0 {
        (seq_a.len().min(avail), 0)
    } else {
        // proportional truncation
        let total = seq_a.len() + b_len;
        if total <= avail {
            (seq_a.len(), b_len)
        } else if avail == 0 {
            (0, 0)
        } else {
            // keep a's share, but leave b at least one token when
            // avail >= 2 (the old `.max(1)` could drive `avail - a_k`
            // below zero and underflow). Widened to u128: with untrusted
            // lengths the usize product could wrap before the divide.
            let share =
                (avail as u128 * seq_a.len() as u128 / total as u128) as usize;
            let a_k = share.clamp(1, (avail - 1).max(1)).min(seq_a.len());
            // a_k <= avail in every branch above, so this cannot underflow,
            // and share >= avail - b_len guarantees b_keep <= b_len
            (a_k, avail - a_k)
        }
    };
    let mut p = 0usize;
    let mut put = |tok: i32, ty: i32| {
        if p < seq {
            tokens[p] = tok;
            type_ids[p] = ty;
            attn[p] = 1.0;
            p += 1;
        }
    };
    put(vocab::CLS, 0);
    for &t in &seq_a[..a_keep] {
        put(t, 0);
    }
    put(vocab::SEP, 0);
    if let Some(bseq) = seq_b {
        for &t in &bseq[..b_keep] {
            put(t, 1);
        }
        put(vocab::SEP, 1);
    }
    for q in p..seq {
        tokens[q] = vocab::PAD;
        type_ids[q] = 0;
        attn[q] = 0.0;
    }
}

/// Build a batch from `examples[idx]` for the given indices; if fewer than
/// `batch` indices are given, the last example is repeated (its rows count
/// toward padding, not metrics — `real` records the cutoff).
pub fn make_batch(ds: &Dataset, idx: &[usize], batch: usize, seq: usize) -> Batch {
    assert!(!idx.is_empty());
    let mut out = Batch {
        size: batch,
        seq,
        tokens: vec![0; batch * seq],
        type_ids: vec![0; batch * seq],
        attn_mask: vec![0.0; batch * seq],
        labels_onehot: vec![0.0; batch * 3],
        labels_f32: vec![0.0; batch],
        labels: vec![0; batch],
        real: idx.len().min(batch),
    };
    for b in 0..batch {
        let e = &ds.examples[idx[b.min(idx.len() - 1)]];
        encode_into(
            &e.seq_a,
            e.seq_b.as_deref(),
            seq,
            &mut out.tokens[b * seq..(b + 1) * seq],
            &mut out.type_ids[b * seq..(b + 1) * seq],
            &mut out.attn_mask[b * seq..(b + 1) * seq],
        );
        match e.label {
            Label::Class(c) => {
                out.labels_onehot[b * 3 + c] = 1.0;
                out.labels[b] = c;
            }
            Label::Score(s) => {
                out.labels_f32[b] = s;
                // regression tasks keep onehot zero
            }
        }
    }
    out
}

/// Class mask for a task ([1,1,0] for 2-class, [1,1,1] for 3-class).
pub fn class_mask(classes: usize) -> Vec<f32> {
    (0..3).map(|c| if c < classes { 1.0 } else { 0.0 }).collect()
}

/// Epoch iterator: shuffled full batches over a dataset.
pub struct BatchIter<'a> {
    ds: &'a Dataset,
    order: Vec<usize>,
    cursor: usize,
    batch: usize,
    seq: usize,
}

impl<'a> BatchIter<'a> {
    /// Shuffled iteration for training.
    pub fn new(ds: &'a Dataset, rng: &mut Rng, batch: usize, seq: usize) -> Self {
        let mut order: Vec<usize> = (0..ds.examples.len()).collect();
        rng.shuffle(&mut order);
        BatchIter { ds, order, cursor: 0, batch, seq }
    }

    /// Sequential (unshuffled) iteration for evaluation.
    pub fn sequential(ds: &'a Dataset, batch: usize, seq: usize) -> Self {
        let order: Vec<usize> = (0..ds.examples.len()).collect();
        BatchIter { ds, order, cursor: 0, batch, seq }
    }
}

impl<'a> Iterator for BatchIter<'a> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        if self.cursor >= self.order.len() {
            return None;
        }
        let end = (self.cursor + self.batch).min(self.order.len());
        let idx = &self.order[self.cursor..end];
        self.cursor = end;
        Some(make_batch(self.ds, idx, self.batch, self.seq))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::{generate, task_info};

    #[test]
    fn single_sentence_layout() {
        let ds = generate(task_info("sst2").unwrap(), 1, "train", 8);
        let b = make_batch(&ds, &[0, 1, 2, 3], 4, 32);
        assert_eq!(b.tokens[0], vocab::CLS);
        let row = &b.tokens[0..32];
        assert!(row.contains(&vocab::SEP));
        // single sentence => all type ids 0
        assert!(b.type_ids[0..32].iter().all(|&t| t == 0));
        // attention mask matches non-pad prefix
        for p in 0..32 {
            let is_real = row[p] != vocab::PAD;
            assert_eq!(b.attn_mask[p] > 0.5, is_real, "pos {p}");
        }
    }

    #[test]
    fn pair_layout_has_segment_one() {
        let ds = generate(task_info("mnli").unwrap(), 1, "train", 8);
        let b = make_batch(&ds, &[0], 1, 32);
        assert!(b.type_ids[0..32].iter().any(|&t| t == 1));
        // after the 2nd segment only PAD with type 0 mask 0
        let seps: Vec<usize> =
            (0..32).filter(|&p| b.tokens[p] == vocab::SEP).collect();
        assert!(seps.len() >= 2);
    }

    #[test]
    fn truncation_never_overflows() {
        let ds = generate(task_info("qqp").unwrap(), 2, "train", 64);
        for i in 0..64 {
            let b = make_batch(&ds, &[i], 1, 16);
            assert_eq!(b.tokens.len(), 16);
            assert_eq!(b.attn_mask.iter().filter(|&&m| m > 0.0).count()
                       <= 16, true);
        }
    }

    #[test]
    fn degenerate_seq_len_never_panics() {
        // regression: seq < specials used to underflow `seq - specials`
        // (panic in debug, wrap in release), and the pair branch could hit
        // `avail - a_k` underflow when avail <= 1.
        for task in ["sst2", "mnli", "qqp"] {
            let ds = generate(task_info(task).unwrap(), 5, "train", 8);
            for seq in 0..6 {
                for i in 0..8 {
                    let b = make_batch(&ds, &[i], 1, seq);
                    assert_eq!(b.tokens.len(), seq, "{task} seq={seq}");
                    // row never writes past seq and mask stays a 0/1 prefix
                    let real = b.attn_mask.iter().filter(|&&m| m > 0.0).count();
                    assert!(real <= seq, "{task} seq={seq}");
                    if seq > 0 {
                        assert_eq!(b.tokens[0], vocab::CLS, "{task} seq={seq}");
                    }
                    for p in real..seq {
                        assert_eq!(b.tokens[p], vocab::PAD, "{task} seq={seq} p={p}");
                    }
                }
            }
        }
    }

    #[test]
    fn pair_truncation_keeps_both_segments_when_budget_allows() {
        // with avail = seq - 3 >= 2, both sentences must keep >= 1 token
        let ds = generate(task_info("mnli").unwrap(), 7, "train", 16);
        for seq in 5..12 {
            for i in 0..16 {
                let b = make_batch(&ds, &[i], 1, seq);
                let row = &b.tokens[..seq];
                let types = &b.type_ids[..seq];
                let n_a = (0..seq)
                    .filter(|&p| {
                        types[p] == 0 && row[p] != vocab::CLS && row[p] != vocab::SEP
                            && b.attn_mask[p] > 0.0
                    })
                    .count();
                let n_b = (0..seq)
                    .filter(|&p| types[p] == 1 && row[p] != vocab::SEP)
                    .count();
                assert!(n_a >= 1, "seq={seq} row {i}: segment a emptied");
                assert!(n_b >= 1, "seq={seq} row {i}: segment b emptied");
            }
        }
    }

    /// Row-level invariants shared by the wire-boundary tests below.
    fn check_row(seq: usize, tokens: &[i32], type_ids: &[i32], attn: &[f32]) {
        let real = attn.iter().filter(|&&m| m > 0.0).count();
        assert!(real <= seq);
        // mask is a 0/1 prefix
        for p in 0..seq {
            assert_eq!(attn[p] > 0.0, p < real, "mask not a prefix at {p}");
        }
        if seq > 0 && real > 0 {
            assert_eq!(tokens[0], vocab::CLS);
        }
        for p in real..seq {
            assert_eq!(tokens[p], vocab::PAD, "pad tail at {p}");
            assert_eq!(type_ids[p], 0);
        }
        assert!(type_ids.iter().all(|&t| t == 0 || t == 1));
    }

    #[test]
    fn encode_into_wire_boundary_budgets() {
        // the serve front door feeds attacker-chosen lengths straight in;
        // pin the 0 / 1 / seq-1 / seq / beyond-seq boundaries for both the
        // single and the pair layout
        let seq = 16;
        let mut tokens = vec![0i32; seq];
        let mut type_ids = vec![0i32; seq];
        let mut attn = vec![0f32; seq];
        for a_len in [0usize, 1, seq - 1, seq, seq + 7, 3 * seq] {
            for b_len in [None, Some(0usize), Some(1), Some(seq - 1), Some(seq)] {
                let a: Vec<i32> = (0..a_len).map(|i| 5 + i as i32).collect();
                let b: Option<Vec<i32>> =
                    b_len.map(|n| (0..n).map(|i| 9 + i as i32).collect());
                encode_into(
                    &a,
                    b.as_deref(),
                    seq,
                    &mut tokens,
                    &mut type_ids,
                    &mut attn,
                );
                check_row(seq, &tokens, &type_ids, &attn);
                let n_a = (0..seq)
                    .filter(|&p| {
                        attn[p] > 0.0
                            && type_ids[p] == 0
                            && tokens[p] != vocab::CLS
                            && tokens[p] != vocab::SEP
                    })
                    .count();
                let n_b = (0..seq)
                    .filter(|&p| {
                        attn[p] > 0.0 && type_ids[p] == 1 && tokens[p] != vocab::SEP
                    })
                    .count();
                assert!(n_a <= a_len, "a_len={a_len} b_len={b_len:?}");
                match b_len {
                    None | Some(0) => assert_eq!(n_b, 0, "a_len={a_len}"),
                    Some(bl) => {
                        assert!(n_b <= bl);
                        // both segments survive whenever the budget allows
                        if a_len >= 1 && seq >= 5 {
                            assert!(n_a >= 1, "a emptied: a={a_len} b={bl}");
                            assert!(n_b >= 1, "b emptied: a={a_len} b={bl}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn encode_into_empty_second_segment_keeps_its_sep() {
        // `Some(&[])` is "pair task, empty b": budget is 2 specials (b_len
        // is 0) but the type-1 SEP is still emitted — pinned because the
        // wire path maps "text_b": [] here
        let seq = 8;
        let mut tokens = vec![0i32; seq];
        let mut type_ids = vec![0i32; seq];
        let mut attn = vec![0f32; seq];
        encode_into(&[7, 8], Some(&[]), seq, &mut tokens, &mut type_ids, &mut attn);
        assert_eq!(&tokens[..5], &[vocab::CLS, 7, 8, vocab::SEP, vocab::SEP]);
        assert_eq!(&type_ids[..5], &[0, 0, 0, 0, 1]);
        assert_eq!(tokens[5], vocab::PAD);
    }

    #[test]
    fn encode_into_attacker_sized_sentences_truncate_cleanly() {
        // very large (heap-realizable) lengths exercise the widened
        // proportional-share arithmetic: the row must saturate at seq with
        // both segments represented, never panic or overflow
        let seq = 8;
        let a = vec![7i32; 100_000];
        let b = vec![9i32; 3];
        let mut tokens = vec![0i32; seq];
        let mut type_ids = vec![0i32; seq];
        let mut attn = vec![0f32; seq];
        encode_into(&a, Some(&b), seq, &mut tokens, &mut type_ids, &mut attn);
        check_row(seq, &tokens, &type_ids, &attn);
        assert!(attn.iter().all(|&m| m > 0.0), "row must be full");
        assert!(type_ids.iter().any(|&t| t == 1), "b segment must survive");

        let b2 = vec![9i32; 250_000];
        encode_into(&a, Some(&b2), seq, &mut tokens, &mut type_ids, &mut attn);
        check_row(seq, &tokens, &type_ids, &attn);
        assert!(type_ids.iter().any(|&t| t == 1));

        // single-sentence flood
        encode_into(&a, None, seq, &mut tokens, &mut type_ids, &mut attn);
        check_row(seq, &tokens, &type_ids, &attn);
        assert_eq!(tokens[seq - 1], vocab::SEP);
    }

    #[test]
    fn onehot_and_class_mask() {
        let ds = generate(task_info("mnli").unwrap(), 3, "train", 8);
        let b = make_batch(&ds, &[0, 1, 2, 3], 4, 32);
        for row in 0..4 {
            let one: f32 = b.labels_onehot[row * 3..row * 3 + 3].iter().sum();
            assert_eq!(one, 1.0);
        }
        assert_eq!(class_mask(2), vec![1.0, 1.0, 0.0]);
        assert_eq!(class_mask(3), vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn batch_iter_covers_dataset() {
        let ds = generate(task_info("rte").unwrap(), 4, "train", 50);
        let mut rng = crate::util::Rng::new(1);
        let n: usize = BatchIter::new(&ds, &mut rng, 16, 32)
            .map(|b| b.real)
            .sum();
        assert_eq!(n, 50);
        // last batch padded by repetition but real < batch
        let last = BatchIter::new(&ds, &mut rng, 16, 32).last().unwrap();
        assert_eq!(last.real, 50 % 16);
    }
}
