//! Synthetic corpus generator: the "web text" the PLM is pre-trained on.
//!
//! Sentences are topic-conditioned token chains with bigram locality: with
//! probability `COHERENCE` the next token stays near the previous one inside
//! the topic band, otherwise it resamples from the band. This gives the MLM
//! objective real structure to learn (topic identity + local order), which
//! is what makes the downstream linear probe land in the paper's ~65-70%
//! regime instead of chance.

use crate::util::Rng;

use super::vocab;

/// Bigram locality strength.
const COHERENCE: f32 = 0.7;
/// Max distance of a "local" bigram step.
const LOCAL_STEP: i32 = 4;

/// A generated sentence with its latent topic.
#[derive(Debug, Clone)]
pub struct Sentence {
    /// Latent topic the sentence was drawn from.
    pub topic: usize,
    /// Content token ids (no specials).
    pub tokens: Vec<i32>,
}

/// Deterministic corpus generator.
pub struct Corpus {
    rng: Rng,
    /// Minimum sentence length.
    pub min_len: usize,
    /// Maximum sentence length.
    pub max_len: usize,
}

impl Corpus {
    /// A deterministic generator from a seed.
    pub fn new(seed: u64) -> Self {
        Corpus { rng: Rng::new(seed), min_len: 6, max_len: 24 }
    }

    /// Sample one token from a topic band, biased near `prev` when coherent.
    fn next_token(rng: &mut Rng, topic: usize, prev: Option<i32>) -> i32 {
        let start = vocab::band_start(topic);
        match prev {
            Some(p) if rng.chance(COHERENCE) && vocab::topic_of(p) == Some(topic) => {
                let delta = rng.range(1, LOCAL_STEP as usize + 1) as i32;
                let sign = if rng.chance(0.5) { 1 } else { -1 };
                let t = p + sign * delta;
                t.clamp(start, start + vocab::BAND - 1)
            }
            _ => start + rng.below(vocab::BAND as usize) as i32,
        }
    }

    /// Generate a sentence with an explicit topic.
    pub fn sentence_with_topic(&mut self, topic: usize) -> Sentence {
        let len = self.rng.range(self.min_len, self.max_len + 1);
        let mut tokens = Vec::with_capacity(len);
        let mut prev = None;
        for _ in 0..len {
            let t = Self::next_token(&mut self.rng, topic, prev);
            tokens.push(t);
            prev = Some(t);
        }
        Sentence { topic, tokens }
    }

    /// Generate a sentence with a random topic.
    pub fn sentence(&mut self) -> Sentence {
        let topic = self.rng.below(vocab::TOPICS);
        self.sentence_with_topic(topic)
    }

    /// Continuation of a sentence (same topic, starts near its last token) —
    /// used by entailment-style tasks for "related but different" text.
    pub fn continuation(&mut self, of: &Sentence, len: usize) -> Sentence {
        let mut tokens = Vec::with_capacity(len);
        let mut prev = of.tokens.last().copied();
        for _ in 0..len {
            let t = Self::next_token(&mut self.rng, of.topic, prev);
            tokens.push(t);
            prev = Some(t);
        }
        Sentence { topic: of.topic, tokens }
    }

    /// Borrow the generator's RNG (task generators fork substreams off it).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// An MLM pre-training batch in host form.
#[derive(Debug, Clone)]
pub struct MlmBatch {
    /// Token ids, `[B, L]`.
    pub tokens: Vec<i32>,
    /// Segment ids, `[B, L]`.
    pub type_ids: Vec<i32>,
    /// Attention mask, `[B, L]`.
    pub attn_mask: Vec<f32>,
    /// Original token at masked positions, `[B, L]`.
    pub labels: Vec<i32>,
    /// 1.0 at positions contributing to the MLM loss, `[B, L]`.
    pub loss_mask: Vec<f32>,
}

/// BERT-style MLM masking: 15% of content positions; of those 80% MASK,
/// 10% random token, 10% unchanged.
pub fn mlm_batch(
    corpus: &mut Corpus,
    rng: &mut Rng,
    batch: usize,
    seq: usize,
) -> MlmBatch {
    let n = batch * seq;
    let mut tokens = vec![vocab::PAD; n];
    let type_ids = vec![0i32; n];
    let mut attn = vec![0f32; n];
    let mut labels = vec![0i32; n];
    let mut loss_mask = vec![0f32; n];

    for b in 0..batch {
        let row = &mut tokens[b * seq..(b + 1) * seq];
        row[0] = vocab::CLS;
        let mut pos = 1;
        while pos < seq - 1 {
            let s = corpus.sentence();
            for &t in &s.tokens {
                if pos >= seq - 1 {
                    break;
                }
                row[pos] = t;
                pos += 1;
            }
            if pos < seq - 1 {
                row[pos] = vocab::SEP;
                pos += 1;
            }
        }
        row[seq - 1] = vocab::SEP;
        for p in 0..seq {
            attn[b * seq + p] = 1.0;
            let orig = row[p];
            labels[b * seq + p] = orig;
            let is_content = orig >= vocab::CONTENT_START;
            if is_content && rng.chance(0.15) {
                loss_mask[b * seq + p] = 1.0;
                let r = rng.next_f32();
                if r < 0.8 {
                    row[p] = vocab::MASK;
                } else if r < 0.9 {
                    row[p] = vocab::CONTENT_START
                        + rng.below((vocab::VOCAB - vocab::CONTENT_START) as usize) as i32;
                }
            }
        }
        let _ = type_ids; // single-segment pre-training: all zeros
    }

    MlmBatch { tokens, type_ids, attn_mask: attn, labels, loss_mask }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sentences_stay_in_topic_band() {
        let mut c = Corpus::new(1);
        for _ in 0..50 {
            let s = c.sentence();
            assert!(s.tokens.len() >= c.min_len && s.tokens.len() <= c.max_len);
            for &t in &s.tokens {
                assert_eq!(vocab::topic_of(t), Some(s.topic), "tok {t}");
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Corpus::new(9);
        let mut b = Corpus::new(9);
        for _ in 0..10 {
            assert_eq!(a.sentence().tokens, b.sentence().tokens);
        }
    }

    #[test]
    fn continuation_same_topic() {
        let mut c = Corpus::new(2);
        let s = c.sentence();
        let cont = c.continuation(&s, 8);
        assert_eq!(cont.topic, s.topic);
        assert_eq!(cont.tokens.len(), 8);
    }

    #[test]
    fn mlm_batch_invariants() {
        let mut c = Corpus::new(3);
        let mut r = Rng::new(4);
        let b = mlm_batch(&mut c, &mut r, 4, 32);
        assert_eq!(b.tokens.len(), 4 * 32);
        // CLS at row starts
        for row in 0..4 {
            assert_eq!(b.tokens[row * 32], vocab::CLS);
        }
        // loss positions only on content labels, and masking rate sane
        let masked: usize = b.loss_mask.iter().filter(|&&m| m > 0.0).count();
        assert!(masked > 0);
        for i in 0..b.tokens.len() {
            if b.loss_mask[i] > 0.0 {
                assert!(b.labels[i] >= vocab::CONTENT_START);
            }
        }
        // bulk of masked positions show the MASK token
        let mask_tok = (0..b.tokens.len())
            .filter(|&i| b.loss_mask[i] > 0.0 && b.tokens[i] == vocab::MASK)
            .count();
        assert!(mask_tok * 10 >= masked * 6, "{mask_tok}/{masked}");
    }
}
