//! Vocabulary layout for the synthetic language.
//!
//! The corpus generator and all eight task generators share this layout;
//! the MLM pre-training therefore teaches the backbone exactly the
//! co-occurrence structure the downstream tasks query — the same regime the
//! paper gets from GLUE-on-top-of-BERT-pretraining (DESIGN.md §3).

/// Special tokens.
pub const PAD: i32 = 0;
/// `[CLS]` classification token.
pub const CLS: i32 = 1;
/// `[SEP]` separator token.
pub const SEP: i32 = 2;
/// `[MASK]` MLM mask token.
pub const MASK: i32 = 3;
/// Unknown-token id.
pub const UNK: i32 = 4;
/// Question marker (QNLI/QQP-style "questions").
pub const QMARK: i32 = 5;
/// Negation/contradiction marker (MNLI/RTE contradictions).
pub const NEG_MARKER: i32 = 6;

/// First content token id.
pub const CONTENT_START: i32 = 8;
/// Vocabulary size (must match `configs.ModelConfig.vocab` on the JAX side).
pub const VOCAB: i32 = 512;
/// Number of latent topics in the synthetic language.
pub const TOPICS: usize = 8;

/// Tokens per topic band.
pub const BAND: i32 = (VOCAB - CONTENT_START) / TOPICS as i32;

/// Sentiment lexicon: the first `SENT_K` tokens of band 0 are "positive",
/// the first `SENT_K` of band 1 are "negative".
pub const SENT_K: i32 = 12;

/// Topic band start for topic `t`.
pub fn band_start(t: usize) -> i32 {
    CONTENT_START + (t as i32) * BAND
}

/// Which topic a content token belongs to (None for specials).
pub fn topic_of(tok: i32) -> Option<usize> {
    if tok < CONTENT_START || tok >= VOCAB {
        return None;
    }
    Some(((tok - CONTENT_START) / BAND) as usize).filter(|&t| t < TOPICS)
}

/// "Synonym" of a token: its band-neighbour (used by paraphrase tasks).
pub fn synonym(tok: i32) -> i32 {
    match topic_of(tok) {
        Some(t) => {
            let s = band_start(t);
            s + ((tok - s) ^ 1).min(BAND - 1)
        }
        None => tok,
    }
}

/// "Antonym" of a token: mirrored within its band (used by contradiction).
pub fn antonym(tok: i32) -> i32 {
    match topic_of(tok) {
        Some(t) => {
            let s = band_start(t);
            s + (BAND - 1 - (tok - s))
        }
        None => tok,
    }
}

/// Positive-sentiment lexicon.
pub fn positive_tokens() -> impl Iterator<Item = i32> {
    (0..SENT_K).map(|i| band_start(0) + i)
}

/// Negative-sentiment lexicon.
pub fn negative_tokens() -> impl Iterator<Item = i32> {
    (0..SENT_K).map(|i| band_start(1) + i)
}

/// Whether a token belongs to the positive sentiment lexicon.
pub fn is_positive(tok: i32) -> bool {
    tok >= band_start(0) && tok < band_start(0) + SENT_K
}

/// Whether a token belongs to the negative sentiment lexicon.
pub fn is_negative(tok: i32) -> bool {
    tok >= band_start(1) && tok < band_start(1) + SENT_K
}

/// The QNLI "answer token" for a question token: fixed offset mapping into
/// the last topic band (a learnable but non-trivial association).
pub fn answer_token(question_tok: i32) -> i32 {
    let base = band_start(TOPICS - 1);
    base + (question_tok - CONTENT_START) % BAND
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bands_partition_content_range() {
        assert_eq!(BAND * TOPICS as i32 + CONTENT_START, VOCAB);
        for t in 0..TOPICS {
            let s = band_start(t);
            assert_eq!(topic_of(s), Some(t));
            assert_eq!(topic_of(s + BAND - 1), Some(t));
        }
        assert_eq!(topic_of(PAD), None);
        assert_eq!(topic_of(VOCAB), None);
    }

    #[test]
    fn synonym_stays_in_band() {
        for t in 0..TOPICS {
            for i in 0..BAND {
                let tok = band_start(t) + i;
                assert_eq!(topic_of(synonym(tok)), Some(t));
            }
        }
    }

    #[test]
    fn antonym_is_involution() {
        for tok in CONTENT_START..VOCAB {
            assert_eq!(antonym(antonym(tok)), tok);
        }
    }

    #[test]
    fn sentiment_lexicons_disjoint() {
        let pos: Vec<i32> = positive_tokens().collect();
        let neg: Vec<i32> = negative_tokens().collect();
        assert!(pos.iter().all(|t| !neg.contains(t)));
        assert!(pos.iter().all(|&t| is_positive(t) && !is_negative(t)));
        assert!(neg.iter().all(|&t| is_negative(t) && !is_positive(t)));
    }

    #[test]
    fn answer_token_in_last_band() {
        for q in CONTENT_START..VOCAB {
            assert_eq!(topic_of(answer_token(q)), Some(TOPICS - 1));
        }
    }
}
