//! Model layer: host parameter store, freeze-mask algebra, checkpoints.

pub mod mask;
pub mod store;

pub use mask::{layer_of, parse_modules, FreezeMask, LayerRange, Module};
pub use store::ParamStore;
