//! Freeze-mask algebra: which parameters actually update.
//!
//! A gradient-group artifact computes grads for its whole group; the mask
//! selects the subset that the optimizer applies. This is what implements
//! the paper's ablations: module combos W/B/N/A (Table 4) and layer-range
//! unfreezing (Table 5 / Fig 4). Masking a gradient to zero is exactly
//! equivalent to differentiating the subset (losses are sums; discarded
//! grads touch nothing).

use std::collections::HashSet;

use crate::runtime::ModelInfo;

/// Module selectors within the hadamard gradient group (paper Table 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Module {
    /// Hadamard adapter weight vectors (`W`).
    HadamardWeight,
    /// Hadamard adapter bias vectors (`B`).
    HadamardBias,
    /// Output LayerNorm — right after the intermediate/FFN outputs (`N`).
    Norm,
    /// Attention-output LayerNorm (`A`).
    AttNorm,
    /// Sec. 2.2 fitting-study quadratic coefficients.
    HadamardW2,
    /// Sec. 2.2 fitting-study cubic coefficients.
    HadamardW3,
}

impl Module {
    /// Whether a canonical parameter name belongs to this module.
    pub fn matches(&self, name: &str) -> bool {
        match self {
            Module::HadamardWeight => name.ends_with(".hadamard.weight"),
            Module::HadamardBias => name.ends_with(".hadamard.bias"),
            Module::HadamardW2 => name.ends_with(".hadamard.w2"),
            Module::HadamardW3 => name.ends_with(".hadamard.w3"),
            Module::AttNorm => name.contains(".attention.output.LayerNorm."),
            Module::Norm => {
                name.contains(".output.LayerNorm.")
                    && !name.contains(".attention.")
            }
        }
    }

    /// Paper-style single-letter label (Table 4 column headers).
    pub fn label(&self) -> &'static str {
        match self {
            Module::HadamardWeight => "W",
            Module::HadamardBias => "B",
            Module::Norm => "N",
            Module::AttNorm => "A",
            Module::HadamardW2 => "W2",
            Module::HadamardW3 => "W3",
        }
    }
}

/// Parse a Table-4-style combo label like "W+B+N" into modules.
pub fn parse_modules(combo: &str) -> Vec<Module> {
    combo
        .split('+')
        .filter_map(|tok| match tok.trim() {
            "W" => Some(Module::HadamardWeight),
            "B" => Some(Module::HadamardBias),
            "N" => Some(Module::Norm),
            "A" => Some(Module::AttNorm),
            "W2" => Some(Module::HadamardW2),
            "W3" => Some(Module::HadamardW3),
            _ => None,
        })
        .collect()
}

/// Which encoder layers train (Table 5: unfreeze the *last* k layers —
/// consistent with Fig. 1's finding that late layers change most).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerRange {
    /// Unfreeze every encoder layer.
    All,
    /// Unfreeze the top (last) `k` layers; earlier adapter layers stay
    /// identity.
    LastK(usize),
}

impl LayerRange {
    fn allows(&self, layer: Option<usize>, total: usize) -> bool {
        match (self, layer) {
            (LayerRange::All, _) => true,
            // Non-layer params (heads, embeddings LN) always allowed.
            (LayerRange::LastK(_), None) => true,
            (LayerRange::LastK(k), Some(l)) => l + k >= total,
        }
    }
}

/// Extract the encoder layer index from a canonical parameter name.
pub fn layer_of(name: &str) -> Option<usize> {
    let rest = name.strip_prefix("encoder.layer.")?;
    let end = rest.find('.')?;
    rest[..end].parse().ok()
}

/// A freeze mask over a model's canonical parameter list.
#[derive(Debug, Clone)]
pub struct FreezeMask {
    /// trainable[i] == true => parameter i updates.
    pub trainable: Vec<bool>,
}

impl FreezeMask {
    /// Nothing trains.
    pub fn frozen(info: &ModelInfo) -> Self {
        FreezeMask { trainable: vec![false; info.params.len()] }
    }

    /// Everything in `names` trains.
    pub fn from_names(info: &ModelInfo, names: &[String]) -> Self {
        let set: HashSet<&str> = names.iter().map(|s| s.as_str()).collect();
        FreezeMask {
            trainable: info
                .params
                .iter()
                .map(|p| set.contains(p.name.as_str()))
                .collect(),
        }
    }

    /// The paper's stage-2 mask: selected modules (within the hadamard
    /// group) + optionally the head, restricted to a layer range.
    pub fn stage2(
        info: &ModelInfo,
        modules: &[Module],
        layers: LayerRange,
        include_head: bool,
    ) -> Self {
        let trainable = info
            .params
            .iter()
            .map(|p| {
                let n = p.name.as_str();
                if n.starts_with("pooler.")
                    || n.starts_with("classifier.")
                    || n.starts_with("regressor.")
                {
                    return include_head;
                }
                let in_module = modules.iter().any(|m| m.matches(n));
                in_module && layers.allows(layer_of(n), info.layers)
            })
            .collect();
        FreezeMask { trainable }
    }

    /// Restrict an existing mask to a layer range (keeps non-layer params).
    pub fn restrict_layers(&self, info: &ModelInfo, layers: LayerRange) -> Self {
        FreezeMask {
            trainable: self
                .trainable
                .iter()
                .zip(&info.params)
                .map(|(&t, p)| t && layers.allows(layer_of(&p.name), info.layers))
                .collect(),
        }
    }

    /// Whether parameter `idx` updates under this mask.
    pub fn is_trainable(&self, idx: usize) -> bool {
        self.trainable[idx]
    }

    /// Count trainable scalars (the paper's parameter accounting).
    pub fn trainable_scalars(&self, info: &ModelInfo) -> usize {
        self.trainable
            .iter()
            .zip(&info.params)
            .filter(|(&t, _)| t)
            .map(|(_, p)| p.numel())
            .sum()
    }

    /// Trainable fraction vs the vanilla backbone (the paper's "% params").
    pub fn trainable_fraction(&self, info: &ModelInfo) -> f64 {
        self.trainable_scalars(info) as f64 / info.backbone_params() as f64
    }

    /// Element-wise OR of two masks over the same parameter list.
    pub fn union(&self, other: &FreezeMask) -> FreezeMask {
        FreezeMask {
            trainable: self
                .trainable
                .iter()
                .zip(&other.trainable)
                .map(|(&a, &b)| a || b)
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{InitKind, ParamSpec};
    use std::collections::HashMap;

    fn info2() -> ModelInfo {
        let names = [
            "embeddings.word_embeddings.weight",
            "encoder.layer.0.hadamard.weight",
            "encoder.layer.0.hadamard.bias",
            "encoder.layer.0.attention.output.LayerNorm.weight",
            "encoder.layer.0.output.LayerNorm.weight",
            "encoder.layer.1.hadamard.weight",
            "encoder.layer.1.hadamard.bias",
            "encoder.layer.1.attention.output.LayerNorm.weight",
            "encoder.layer.1.output.LayerNorm.weight",
            "pooler.dense.weight",
            "classifier.weight",
        ];
        let params: Vec<ParamSpec> = names
            .iter()
            .map(|n| ParamSpec {
                name: n.to_string(),
                shape: vec![2],
                init: InitKind::Zeros,
            })
            .collect();
        let index = params
            .iter()
            .enumerate()
            .map(|(i, p)| (p.name.clone(), i))
            .collect();
        let mut groups = HashMap::new();
        groups.insert(
            "full".to_string(),
            vec!["embeddings.word_embeddings.weight".to_string()],
        );
        ModelInfo {
            name: "m".into(),
            layers: 2,
            hidden: 2,
            heads: 1,
            ffn: 4,
            vocab: 8,
            max_len: 4,
            lora_alpha: 8.0,
            params,
            index,
            groups,
            mlm_group: vec![],
        }
    }

    #[test]
    fn module_matching() {
        assert!(Module::HadamardWeight.matches("encoder.layer.3.hadamard.weight"));
        assert!(!Module::HadamardWeight.matches("encoder.layer.3.hadamard.bias"));
        assert!(Module::AttNorm.matches("encoder.layer.0.attention.output.LayerNorm.bias"));
        assert!(Module::Norm.matches("encoder.layer.0.output.LayerNorm.bias"));
        assert!(!Module::Norm.matches("encoder.layer.0.attention.output.LayerNorm.bias"));
    }

    #[test]
    fn parse_combo() {
        let m = parse_modules("W+B+N+A");
        assert_eq!(m.len(), 4);
        assert_eq!(parse_modules("B+N"),
                   vec![Module::HadamardBias, Module::Norm]);
    }

    #[test]
    fn layer_of_parses() {
        assert_eq!(layer_of("encoder.layer.17.hadamard.weight"), Some(17));
        assert_eq!(layer_of("pooler.dense.weight"), None);
    }

    #[test]
    fn stage2_mask_modules() {
        let info = info2();
        let m = FreezeMask::stage2(
            &info,
            &[Module::HadamardBias, Module::Norm],
            LayerRange::All,
            true,
        );
        let on: Vec<&str> = info
            .params
            .iter()
            .zip(&m.trainable)
            .filter(|(_, &t)| t)
            .map(|(p, _)| p.name.as_str())
            .collect();
        assert_eq!(
            on,
            vec![
                "encoder.layer.0.hadamard.bias",
                "encoder.layer.0.output.LayerNorm.weight",
                "encoder.layer.1.hadamard.bias",
                "encoder.layer.1.output.LayerNorm.weight",
                "pooler.dense.weight",
                "classifier.weight",
            ]
        );
    }

    #[test]
    fn stage2_mask_last_k_layers() {
        let info = info2();
        let m = FreezeMask::stage2(
            &info,
            &[Module::HadamardWeight],
            LayerRange::LastK(1),
            false,
        );
        let on: Vec<&str> = info
            .params
            .iter()
            .zip(&m.trainable)
            .filter(|(_, &t)| t)
            .map(|(p, _)| p.name.as_str())
            .collect();
        assert_eq!(on, vec!["encoder.layer.1.hadamard.weight"]);
    }

    #[test]
    fn union_and_counts() {
        let info = info2();
        let a = FreezeMask::stage2(&info, &[Module::HadamardWeight], LayerRange::All, false);
        let b = FreezeMask::stage2(&info, &[Module::HadamardBias], LayerRange::All, false);
        let u = a.union(&b);
        assert_eq!(u.trainable_scalars(&info), 4 * 2);
        assert!(u.trainable_fraction(&info) > 0.0);
    }
}
