//! ParamStore: the host-side source of truth for model parameters.
//!
//! Parameters live in canonical manifest order as named f32 tensors. The
//! store owns initialization (same distribution kinds as the Python side:
//! normal(0, 0.02), zeros, ones — identity-initialized adapters), checkpoint
//! save/load, and conversion to the literal/buffer lists the artifacts take.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::{InitKind, ModelInfo, Tensor};
use crate::util::Rng;

/// Magic + version for the checkpoint container.
const MAGIC: &[u8; 8] = b"HADAPT01";

/// Host-resident parameters for one model instance.
#[derive(Debug, Clone)]
pub struct ParamStore {
    /// Model name the store was initialized for.
    pub model: String,
    /// tensors in canonical (manifest) order.
    pub tensors: Vec<Tensor>,
    /// canonical names (mirrors ModelInfo.params).
    pub names: Vec<String>,
}

impl ParamStore {
    /// Initialize from the manifest inventory with the given seed.
    /// `w=1, b=0` adapters make every PEFT module an exact no-op (paper
    /// Sec. 3.1: "the initial value is equivalent to not adding any
    /// adapter").
    pub fn init(info: &ModelInfo, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut tensors = Vec::with_capacity(info.params.len());
        let mut names = Vec::with_capacity(info.params.len());
        for spec in &info.params {
            let mut t = Tensor::zeros(spec.shape.clone());
            match spec.init {
                InitKind::Normal => {
                    let mut stream = rng.fork(crate::util::fnv1a(&spec.name));
                    stream.fill_normal(&mut t.data, 0.02);
                }
                InitKind::Ones => t.data.fill(1.0),
                InitKind::Zeros => {}
            }
            names.push(spec.name.clone());
            tensors.push(t);
        }
        ParamStore { model: info.name.clone(), tensors, names }
    }

    /// Number of tensors (== the manifest's parameter count).
    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    /// Whether the store holds no tensors.
    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Total scalars across all tensors.
    pub fn total_scalars(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    /// Canonical index of a parameter name.
    pub fn index_of(&self, name: &str) -> Result<usize> {
        self.names
            .iter()
            .position(|n| n == name)
            .ok_or_else(|| anyhow::anyhow!("no parameter '{name}'"))
    }

    /// Borrow a parameter tensor by name.
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        Ok(&self.tensors[self.index_of(name)?])
    }

    /// Mutably borrow a parameter tensor by name.
    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        let i = self.index_of(name)?;
        Ok(&mut self.tensors[i])
    }

    /// Copy the named tensors from another store (the two-stage pipeline's
    /// "reload the trained classifier" step).
    pub fn copy_from(&mut self, other: &ParamStore, names: &[String]) -> Result<()> {
        for n in names {
            let src = other.get(n)?.clone();
            let dst = self.get_mut(n)?;
            if dst.shape != src.shape {
                bail!("shape mismatch for '{n}'");
            }
            *dst = src;
        }
        Ok(())
    }

    // ------------------------------------------------------------ checkpoint

    /// Save to a simple binary container: magic, model name, tensor count,
    /// then per tensor (name, rank, dims, f32 data). No compression — these
    /// are small at our scale and load speed matters for experiments.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut buf: Vec<u8> = Vec::with_capacity(self.total_scalars() * 4 + 4096);
        buf.extend_from_slice(MAGIC);
        write_str(&mut buf, &self.model);
        buf.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, t) in self.names.iter().zip(&self.tensors) {
            write_str(&mut buf, name);
            buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
            for &d in &t.shape {
                buf.extend_from_slice(&(d as u64).to_le_bytes());
            }
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
            };
            buf.extend_from_slice(bytes);
        }
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = path.as_ref().with_extension("tmp");
        std::fs::File::create(&tmp)?.write_all(&buf)?;
        std::fs::rename(&tmp, path.as_ref())?;
        Ok(())
    }

    /// Load a checkpoint written by [`ParamStore::save`].
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut bytes = Vec::new();
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening checkpoint {:?}", path.as_ref()))?
            .read_to_end(&mut bytes)?;
        let mut pos = 0usize;
        let magic = take(&bytes, &mut pos, 8)?;
        if magic != MAGIC {
            bail!("bad checkpoint magic");
        }
        let model = read_str(&bytes, &mut pos)?;
        let count = u32::from_le_bytes(take(&bytes, &mut pos, 4)?.try_into()?) as usize;
        let mut names = Vec::with_capacity(count);
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let name = read_str(&bytes, &mut pos)?;
            let rank = u32::from_le_bytes(take(&bytes, &mut pos, 4)?.try_into()?) as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(u64::from_le_bytes(take(&bytes, &mut pos, 8)?.try_into()?) as usize);
            }
            let n: usize = shape.iter().product();
            let raw = take(&bytes, &mut pos, n * 4)?;
            let mut data = vec![0f32; n];
            unsafe {
                std::ptr::copy_nonoverlapping(
                    raw.as_ptr(),
                    data.as_mut_ptr() as *mut u8,
                    n * 4,
                );
            }
            names.push(name);
            tensors.push(Tensor::new(shape, data)?);
        }
        Ok(ParamStore { model, tensors, names })
    }

    /// Validate that this store matches a manifest inventory (names, order,
    /// shapes) — run after every checkpoint load.
    pub fn check_against(&self, info: &ModelInfo) -> Result<()> {
        if self.names.len() != info.params.len() {
            bail!(
                "checkpoint has {} tensors, manifest wants {}",
                self.names.len(),
                info.params.len()
            );
        }
        for (i, spec) in info.params.iter().enumerate() {
            if self.names[i] != spec.name {
                bail!("tensor {i}: name '{}' != manifest '{}'", self.names[i], spec.name);
            }
            if self.tensors[i].shape != spec.shape {
                bail!("tensor '{}': shape {:?} != manifest {:?}",
                      spec.name, self.tensors[i].shape, spec.shape);
            }
        }
        Ok(())
    }
}

fn write_str(buf: &mut Vec<u8>, s: &str) {
    buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
}

fn read_str(bytes: &[u8], pos: &mut usize) -> Result<String> {
    let len = u32::from_le_bytes(take(bytes, pos, 4)?.try_into()?) as usize;
    Ok(String::from_utf8(take(bytes, pos, len)?.to_vec())?)
}

fn take<'a>(bytes: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8]> {
    if *pos + n > bytes.len() {
        bail!("truncated checkpoint");
    }
    let s = &bytes[*pos..*pos + n];
    *pos += n;
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ParamSpec;
    use std::collections::HashMap;

    fn mini_info() -> ModelInfo {
        let params = vec![
            ParamSpec { name: "w".into(), shape: vec![4, 4], init: InitKind::Normal },
            ParamSpec { name: "hadamard.weight".into(), shape: vec![4], init: InitKind::Ones },
            ParamSpec { name: "hadamard.bias".into(), shape: vec![4], init: InitKind::Zeros },
        ];
        let index = params
            .iter()
            .enumerate()
            .map(|(i, p)| (p.name.clone(), i))
            .collect();
        let mut groups = HashMap::new();
        groups.insert("full".to_string(), vec!["w".to_string()]);
        ModelInfo {
            name: "mini".into(),
            layers: 1,
            hidden: 4,
            heads: 1,
            ffn: 8,
            vocab: 16,
            max_len: 8,
            lora_alpha: 8.0,
            params,
            index,
            groups,
            mlm_group: vec!["w".to_string()],
        }
    }

    #[test]
    fn init_kinds() {
        let s = ParamStore::init(&mini_info(), 1);
        assert_eq!(s.get("hadamard.weight").unwrap().data, vec![1.0; 4]);
        assert_eq!(s.get("hadamard.bias").unwrap().data, vec![0.0; 4]);
        let w = s.get("w").unwrap();
        assert!(w.data.iter().any(|&x| x != 0.0));
        assert!(w.data.iter().all(|&x| x.abs() < 0.2)); // std 0.02
    }

    #[test]
    fn init_deterministic_per_name() {
        let a = ParamStore::init(&mini_info(), 7);
        let b = ParamStore::init(&mini_info(), 7);
        assert_eq!(a.get("w").unwrap(), b.get("w").unwrap());
        let c = ParamStore::init(&mini_info(), 8);
        assert_ne!(a.get("w").unwrap(), c.get("w").unwrap());
    }

    #[test]
    fn checkpoint_roundtrip() {
        let s = ParamStore::init(&mini_info(), 3);
        let dir = std::env::temp_dir().join("hadapt_test_ckpt");
        let path = dir.join("mini.ckpt");
        s.save(&path).unwrap();
        let back = ParamStore::load(&path).unwrap();
        assert_eq!(back.model, "mini");
        assert_eq!(back.names, s.names);
        for (a, b) in back.tensors.iter().zip(&s.tensors) {
            assert_eq!(a, b);
        }
        back.check_against(&mini_info()).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn copy_from_selected() {
        let info = mini_info();
        let mut a = ParamStore::init(&info, 1);
        let b = ParamStore::init(&info, 2);
        a.copy_from(&b, &["w".to_string()]).unwrap();
        assert_eq!(a.get("w").unwrap(), b.get("w").unwrap());
        assert_eq!(a.get("hadamard.weight").unwrap().data, vec![1.0; 4]);
    }

    #[test]
    fn check_against_catches_mismatch() {
        let mut s = ParamStore::init(&mini_info(), 1);
        s.names[0] = "wrong".into();
        assert!(s.check_against(&mini_info()).is_err());
    }
}
