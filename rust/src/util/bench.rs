//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets in `rust/benches/` are plain binaries
//! (`harness = false`) built on this module: warmup, timed iterations,
//! mean / p50 / p95 / throughput reporting, and a stable one-line-per-bench
//! output format that `bench_output.txt` captures.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Sample {
    /// Benchmark name (one line per bench in the output).
    pub name: String,
    /// Measured iterations.
    pub iters: usize,
    /// Mean wall time.
    pub mean: Duration,
    /// Median wall time.
    pub p50: Duration,
    /// 95th-percentile wall time.
    pub p95: Duration,
    /// Fastest iteration.
    pub min: Duration,
}

impl Sample {
    /// Print the standard one-line report.
    pub fn print(&self) {
        println!(
            "bench {:<44} iters={:<4} mean={:>10.3?} p50={:>10.3?} p95={:>10.3?} min={:>10.3?}",
            self.name, self.iters, self.mean, self.p50, self.p95, self.min
        );
    }

    /// Mean wall time in milliseconds (for derived throughput lines).
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }
}

/// Benchmark runner with fixed warmup + measurement iteration counts.
pub struct Bench {
    /// Untimed warmup iterations.
    pub warmup: usize,
    /// Timed iterations.
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 3, iters: 10 }
    }
}

impl Bench {
    /// A runner with explicit warmup/iteration counts.
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bench { warmup, iters }
    }

    /// Quick config for expensive end-to-end benches.
    pub fn heavy() -> Self {
        Bench { warmup: 1, iters: 5 }
    }

    /// Run `f` repeatedly and report. The closure's return value is
    /// black-boxed to keep the optimizer honest.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Sample {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed());
        }
        times.sort();
        let total: Duration = times.iter().sum();
        let s = Sample {
            name: name.to_string(),
            iters: self.iters,
            mean: total / self.iters as u32,
            p50: times[times.len() / 2],
            p95: times[(times.len() * 95 / 100).min(times.len() - 1)],
            min: times[0],
        };
        s.print();
        s
    }
}

/// Print a derived throughput line in the shared bench format.
pub fn report_throughput(name: &str, items: f64, sample: &Sample) {
    let per_sec = items / sample.mean.as_secs_f64();
    println!("bench {name:<44} throughput={per_sec:>12.1}/s");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let b = Bench::new(1, 4);
        let s = b.run("noop", || 1 + 1);
        assert_eq!(s.iters, 4);
        assert!(s.min <= s.p50 && s.p50 <= s.p95);
    }

    #[test]
    fn measures_sleep_roughly() {
        let b = Bench::new(0, 3);
        let s = b.run("sleep1ms", || std::thread::sleep(Duration::from_millis(1)));
        assert!(s.mean >= Duration::from_millis(1));
    }
}
