//! Minimal JSON substrate (parser + writer).
//!
//! The offline environment carries no serde/serde_json, so the framework
//! ships its own small, strict JSON implementation. It covers everything the
//! system needs: the AOT `manifest.json`, the experiment run cache, and
//! result tables. Numbers parse as f64; object key order is preserved
//! (important for stable result files).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects keep insertion order via a Vec of pairs plus a
/// BTreeMap index for O(log n) lookup.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (always f64).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Json>),
    /// JSON object (insertion-ordered).
    Obj(Obj),
}

#[derive(Debug, Clone, Default, PartialEq)]
/// An insertion-ordered JSON object with indexed lookup.
pub struct Obj {
    pairs: Vec<(String, Json)>,
    index: BTreeMap<String, usize>,
}

impl Obj {
    /// An empty object.
    pub fn new() -> Self {
        Obj::default()
    }

    /// Insert or replace a key (replacement keeps the original position).
    pub fn insert(&mut self, key: impl Into<String>, val: Json) {
        let key = key.into();
        if let Some(&i) = self.index.get(&key) {
            self.pairs[i].1 = val;
        } else {
            self.index.insert(key.clone(), self.pairs.len());
            self.pairs.push((key, val));
        }
    }

    /// Look up a key.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.index.get(key).map(|&i| &self.pairs[i].1)
    }

    /// Iterate pairs in insertion order.
    pub fn iter(&self) -> impl Iterator<Item = (&String, &Json)> {
        self.pairs.iter().map(|(k, v)| (k, v))
    }

    /// Number of pairs.
    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    /// Whether the object has no pairs.
    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }
}

impl Json {
    /// An empty object value.
    pub fn obj() -> Json {
        Json::Obj(Obj::new())
    }

    /// A string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// A number value.
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    /// An array value.
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    /// Insert into an object value (panics on non-objects); chains.
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(o) = self {
            o.insert(key, val);
        }
        self
    }

    // ---- typed accessors -------------------------------------------------

    /// Require a key on an object value.
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(o) => o
                .get(key)
                .ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (want key '{key}')"),
        }
    }

    /// Optional key lookup on an object value.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    /// Require a string.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    /// Require a number.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    /// Require a non-negative integer-valued number.
    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    /// Require a boolean.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    /// Require an array.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    /// Require an object.
    pub fn as_obj(&self) -> Result<&Obj> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Require an array of strings, cloned.
    pub fn str_vec(&self) -> Result<Vec<String>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_str().map(String::from))
            .collect()
    }

    // ---- serialization ---------------------------------------------------

    /// Render compactly.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    /// Render with 2-space indentation (stable result files).
    pub fn render_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, depth: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        indent(out, depth + 1);
                    }
                    item.write(out, depth + 1, pretty);
                }
                if pretty && !items.is_empty() {
                    out.push('\n');
                    indent(out, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        indent(out, depth + 1);
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, depth + 1, pretty);
                }
                if pretty && !o.is_empty() {
                    out.push('\n');
                    indent(out, depth);
                }
                out.push('}');
            }
        }
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------- parsing

/// Parse a JSON document (strict; trailing garbage is an error).
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing data at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", c as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => bail!("unexpected end of input"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut o = Obj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(o));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            o.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(o));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?, 16)?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
                None => bail!("unterminated string"),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number '{text}' at byte {start}: {e}")
        })?))
    }
}

// ------------------------------------------------------------------ tests

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.render()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b").unwrap().as_str().unwrap(),
            "x\ny"
        );
    }

    #[test]
    fn object_preserves_order() {
        let v = parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<&String> =
            v.as_obj().unwrap().iter().map(|(k, _)| k).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn roundtrip_pretty() {
        let mut o = Json::obj();
        o.set("name", Json::str("hadapt"))
            .set("score", Json::num(88.5))
            .set("tags", Json::arr(vec![Json::str("peft"), Json::num(1)]));
        let text = o.render_pretty();
        assert_eq!(parse(&text).unwrap(), o);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn big_int_stays_exact() {
        let v = parse("123456789").unwrap();
        assert_eq!(v.render(), "123456789");
    }
}
