//! Recursion-free, allocation-free JSON pull parser for the wire path.
//!
//! The offline substrate already ships a DOM-style JSON implementation
//! ([`super::json`]) for manifests and result files; that one allocates
//! freely and is the right tool for configuration. The serve front door
//! cannot use it: request parsing sits on the per-request hot path, where
//! the runtime's counting-allocator contract demands zero heap traffic
//! after warmup. This module is the ingress-grade alternative, following
//! the picojson discipline:
//!
//! - **Pull, don't build.** The parser is an iterator-like state machine
//!   over a borrowed byte slice. `next()` returns one [`Event`] at a time;
//!   no tree is ever materialized.
//! - **No recursion.** Nesting is tracked with a *bitstack*: one bit per
//!   open container (1 = array, 0 = object) packed into a `u64`, bounded
//!   by [`MAX_DEPTH`]. Hostile deep nesting yields a typed error, never a
//!   stack overflow.
//! - **Borrowed strings, caller-owned scratch.** Strings without escapes
//!   are returned as slices of the input. Escaped strings are unescaped
//!   into a caller-provided scratch buffer (copy-on-write); after warmup
//!   the scratch capacity is resident and re-used, so even escaped keys
//!   cost no allocation.
//! - **Typed errors.** Every failure mode is a [`JsonError`] variant with
//!   a stable wire code — a `Copy` enum, not an allocating error string.
//!
//! The typed extractor that consumes these events for the serve request
//! shape lives in `runtime::wire`; this module knows nothing about HTTP.

/// Maximum container nesting depth (bits available in the bitstack).
pub const MAX_DEPTH: usize = 64;

/// Typed parse failure. `Copy` on purpose: hot-path errors must not touch
/// the heap (the vendored `anyhow` shim is `String`-backed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JsonError {
    /// Input ended inside a value, string, or container.
    UnexpectedEof,
    /// A byte that cannot start or continue the expected token.
    UnexpectedByte,
    /// More than [`MAX_DEPTH`] nested containers (bitstack exhausted).
    DepthOverflow,
    /// A malformed `true`/`false`/`null` literal.
    BadLiteral,
    /// A number violating the strict JSON grammar (e.g. `01`, `1.`, `-`).
    BadNumber,
    /// A syntactically valid number that overflows to ±inf (e.g. `1e999`).
    NonFiniteNumber,
    /// A raw control byte (< 0x20) inside a string.
    BadString,
    /// An unknown backslash escape.
    BadEscape,
    /// A malformed `\uXXXX` escape or invalid surrogate pairing.
    BadUnicodeEscape,
    /// String bytes that are not valid UTF-8.
    InvalidUtf8,
    /// Bytes remaining after the top-level value closed.
    TrailingData,
}

impl JsonError {
    /// Stable kebab-case wire code (used in error response bodies and as
    /// fixture-file name prefixes in the adversarial corpus).
    pub fn code(self) -> &'static str {
        match self {
            JsonError::UnexpectedEof => "json-eof",
            JsonError::UnexpectedByte => "json-byte",
            JsonError::DepthOverflow => "json-depth",
            JsonError::BadLiteral => "json-literal",
            JsonError::BadNumber => "json-number",
            JsonError::NonFiniteNumber => "json-nonfinite",
            JsonError::BadString => "json-string",
            JsonError::BadEscape => "json-escape",
            JsonError::BadUnicodeEscape => "json-unicode",
            JsonError::InvalidUtf8 => "json-utf8",
            JsonError::TrailingData => "json-trailing",
        }
    }
}

/// One parse event. String payloads borrow either the input slice or the
/// caller's scratch buffer — never an owned `String`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Event<'a> {
    /// `{`
    ObjBegin,
    /// `}`
    ObjEnd,
    /// `[`
    ArrBegin,
    /// `]`
    ArrEnd,
    /// An object key (the following event is its value).
    Key(&'a str),
    /// A string value.
    Str(&'a str),
    /// A number value (finite f64).
    Num(f64),
    /// A boolean value.
    Bool(bool),
    /// `null`.
    Null,
    /// The top-level value is complete and no bytes remain.
    End,
}

/// Where a just-parsed string token lives (resolved to `&str` at return).
#[derive(Clone, Copy)]
enum StrTok {
    /// Escape-free: byte range of the input slice.
    Borrowed(usize, usize),
    /// Contained escapes: unescaped bytes are in the scratch buffer.
    Scratch,
}

/// Parser state between events.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    /// Expecting a value (top level, after `:`, or after `,` in an array).
    Value,
    /// Expecting a value or `]` (immediately after `[`).
    ValueOrEnd,
    /// Expecting a key (after `,` in an object).
    Key,
    /// Expecting a key or `}` (immediately after `{`).
    KeyOrEnd,
    /// Expecting `,` or the matching close bracket.
    CommaOrEnd,
    /// Top-level value complete; only whitespace may remain.
    Done,
}

/// The pull parser: borrowed input, borrowed scratch, bitstack nesting.
pub struct PullParser<'a, 's> {
    input: &'a [u8],
    scratch: &'s mut Vec<u8>,
    pos: usize,
    /// One bit per open container; LSB is the innermost (1 = array).
    stack: u64,
    depth: usize,
    state: State,
}

impl<'a, 's> PullParser<'a, 's> {
    /// Start parsing `input`. `scratch` is only written when a string
    /// contains escapes; its capacity is retained across requests.
    pub fn new(input: &'a [u8], scratch: &'s mut Vec<u8>) -> PullParser<'a, 's> {
        scratch.clear();
        PullParser { input, scratch, pos: 0, stack: 0, depth: 0, state: State::Value }
    }

    /// Byte offset of the parse cursor (for diagnostics/tests).
    pub fn offset(&self) -> usize {
        self.pos
    }

    /// Pull the next event. After [`Event::End`] this keeps returning
    /// `End`; every error is sticky in the sense that the caller is
    /// expected to stop (state is not rewound).
    pub fn next(&mut self) -> Result<Event<'_>, JsonError> {
        loop {
            self.skip_ws();
            match self.state {
                State::Done => {
                    if self.pos < self.input.len() {
                        return Err(JsonError::TrailingData);
                    }
                    return Ok(Event::End);
                }
                State::Value => return self.begin_value(),
                State::ValueOrEnd => {
                    if self.peek() == Some(b']') {
                        self.pos += 1;
                        self.pop_container();
                        return Ok(Event::ArrEnd);
                    }
                    return self.begin_value();
                }
                State::Key | State::KeyOrEnd => match self.peek() {
                    None => return Err(JsonError::UnexpectedEof),
                    Some(b'}') if self.state == State::KeyOrEnd => {
                        self.pos += 1;
                        self.pop_container();
                        return Ok(Event::ObjEnd);
                    }
                    Some(b'"') => {
                        let tok = self.parse_string()?;
                        self.skip_ws();
                        match self.peek() {
                            Some(b':') => self.pos += 1,
                            Some(_) => return Err(JsonError::UnexpectedByte),
                            None => return Err(JsonError::UnexpectedEof),
                        }
                        self.state = State::Value;
                        return Ok(Event::Key(self.resolve(tok)?));
                    }
                    Some(_) => return Err(JsonError::UnexpectedByte),
                },
                State::CommaOrEnd => match self.peek() {
                    None => return Err(JsonError::UnexpectedEof),
                    Some(b',') => {
                        self.pos += 1;
                        // `,` never permits a close bracket next: trailing
                        // commas are rejected via Key/Value (not *OrEnd).
                        self.state =
                            if self.stack & 1 == 1 { State::Value } else { State::Key };
                    }
                    Some(b']') => {
                        if self.stack & 1 != 1 {
                            return Err(JsonError::UnexpectedByte);
                        }
                        self.pos += 1;
                        self.pop_container();
                        return Ok(Event::ArrEnd);
                    }
                    Some(b'}') => {
                        if self.stack & 1 != 0 {
                            return Err(JsonError::UnexpectedByte);
                        }
                        self.pos += 1;
                        self.pop_container();
                        return Ok(Event::ObjEnd);
                    }
                    Some(_) => return Err(JsonError::UnexpectedByte),
                },
            }
        }
    }

    // ---- values ----------------------------------------------------------

    fn begin_value(&mut self) -> Result<Event<'_>, JsonError> {
        match self.peek() {
            None => Err(JsonError::UnexpectedEof),
            Some(b'{') => {
                self.pos += 1;
                self.push_container(false)?;
                self.state = State::KeyOrEnd;
                Ok(Event::ObjBegin)
            }
            Some(b'[') => {
                self.pos += 1;
                self.push_container(true)?;
                self.state = State::ValueOrEnd;
                Ok(Event::ArrBegin)
            }
            Some(b'"') => {
                let tok = self.parse_string()?;
                self.after_value();
                Ok(Event::Str(self.resolve(tok)?))
            }
            Some(b't') => {
                self.expect_literal(b"true")?;
                self.after_value();
                Ok(Event::Bool(true))
            }
            Some(b'f') => {
                self.expect_literal(b"false")?;
                self.after_value();
                Ok(Event::Bool(false))
            }
            Some(b'n') => {
                self.expect_literal(b"null")?;
                self.after_value();
                Ok(Event::Null)
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => {
                let v = self.parse_number()?;
                self.after_value();
                Ok(Event::Num(v))
            }
            Some(_) => Err(JsonError::UnexpectedByte),
        }
    }

    fn after_value(&mut self) {
        self.state = if self.depth == 0 { State::Done } else { State::CommaOrEnd };
    }

    fn push_container(&mut self, is_array: bool) -> Result<(), JsonError> {
        if self.depth >= MAX_DEPTH {
            return Err(JsonError::DepthOverflow);
        }
        self.stack = (self.stack << 1) | (is_array as u64);
        self.depth += 1;
        Ok(())
    }

    fn pop_container(&mut self) {
        self.stack >>= 1;
        self.depth -= 1;
        self.after_value();
    }

    // ---- scanning helpers ------------------------------------------------

    fn peek(&self) -> Option<u8> {
        self.input.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_literal(&mut self, lit: &[u8]) -> Result<(), JsonError> {
        if self.input[self.pos..].starts_with(lit) {
            self.pos += lit.len();
            Ok(())
        } else {
            Err(JsonError::BadLiteral)
        }
    }

    // ---- numbers ---------------------------------------------------------

    fn eat_digits(&mut self) -> usize {
        let start = self.pos;
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        self.pos - start
    }

    fn parse_number(&mut self) -> Result<f64, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // strict integer part: "0" or [1-9][0-9]*
        match self.peek() {
            Some(b'0') => {
                self.pos += 1;
                if matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    return Err(JsonError::BadNumber);
                }
            }
            Some(c) if c.is_ascii_digit() => {
                self.eat_digits();
            }
            _ => return Err(JsonError::BadNumber),
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if self.eat_digits() == 0 {
                return Err(JsonError::BadNumber);
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if self.eat_digits() == 0 {
                return Err(JsonError::BadNumber);
            }
        }
        // The token is pure ASCII by construction; core's float parsing
        // does not allocate.
        let text = std::str::from_utf8(&self.input[start..self.pos])
            .map_err(|_| JsonError::BadNumber)?;
        let v: f64 = text.parse().map_err(|_| JsonError::BadNumber)?;
        if !v.is_finite() {
            return Err(JsonError::NonFiniteNumber);
        }
        Ok(v)
    }

    // ---- strings ---------------------------------------------------------

    fn resolve(&self, tok: StrTok) -> Result<&str, JsonError> {
        let bytes = match tok {
            StrTok::Borrowed(s, e) => &self.input[s..e],
            StrTok::Scratch => &self.scratch[..],
        };
        std::str::from_utf8(bytes).map_err(|_| JsonError::InvalidUtf8)
    }

    /// Parse a string starting at the opening quote. Fast path borrows the
    /// input; on the first escape the prefix is copied into scratch and
    /// unescaping continues there.
    fn parse_string(&mut self) -> Result<StrTok, JsonError> {
        debug_assert_eq!(self.peek(), Some(b'"'));
        self.pos += 1;
        let start = self.pos;
        loop {
            match self.input.get(self.pos) {
                None => return Err(JsonError::UnexpectedEof),
                Some(b'"') => {
                    let end = self.pos;
                    self.pos += 1;
                    return Ok(StrTok::Borrowed(start, end));
                }
                Some(b'\\') => break,
                Some(&c) if c < 0x20 => return Err(JsonError::BadString),
                Some(_) => self.pos += 1,
            }
        }
        // copy-on-write: escape found at self.pos
        self.scratch.clear();
        self.scratch.extend_from_slice(&self.input[start..self.pos]);
        loop {
            match self.input.get(self.pos) {
                None => return Err(JsonError::UnexpectedEof),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(StrTok::Scratch);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    self.unescape_one()?;
                }
                Some(&c) if c < 0x20 => return Err(JsonError::BadString),
                Some(&c) => {
                    self.scratch.push(c);
                    self.pos += 1;
                }
            }
        }
    }

    fn unescape_one(&mut self) -> Result<(), JsonError> {
        let c = *self.input.get(self.pos).ok_or(JsonError::UnexpectedEof)?;
        self.pos += 1;
        let out = match c {
            b'"' => b'"',
            b'\\' => b'\\',
            b'/' => b'/',
            b'n' => b'\n',
            b't' => b'\t',
            b'r' => b'\r',
            b'b' => 0x08,
            b'f' => 0x0C,
            b'u' => {
                let hi = self.hex4()?;
                let ch = if (0xD800..0xDC00).contains(&hi) {
                    // high surrogate: a low surrogate escape must follow
                    if self.input.get(self.pos) != Some(&b'\\')
                        || self.input.get(self.pos + 1) != Some(&b'u')
                    {
                        return Err(JsonError::BadUnicodeEscape);
                    }
                    self.pos += 2;
                    let lo = self.hex4()?;
                    if !(0xDC00..0xE000).contains(&lo) {
                        return Err(JsonError::BadUnicodeEscape);
                    }
                    let v = 0x10000
                        + (((hi as u32 - 0xD800) << 10) | (lo as u32 - 0xDC00));
                    char::from_u32(v).ok_or(JsonError::BadUnicodeEscape)?
                } else if (0xDC00..0xE000).contains(&hi) {
                    // lone low surrogate
                    return Err(JsonError::BadUnicodeEscape);
                } else {
                    char::from_u32(hi as u32).ok_or(JsonError::BadUnicodeEscape)?
                };
                let mut buf = [0u8; 4];
                self.scratch.extend_from_slice(ch.encode_utf8(&mut buf).as_bytes());
                return Ok(());
            }
            _ => return Err(JsonError::BadEscape),
        };
        self.scratch.push(out);
        Ok(())
    }

    fn hex4(&mut self) -> Result<u16, JsonError> {
        let mut v: u16 = 0;
        for _ in 0..4 {
            let c = *self.input.get(self.pos).ok_or(JsonError::UnexpectedEof)?;
            self.pos += 1;
            let d = match c {
                b'0'..=b'9' => c - b'0',
                b'a'..=b'f' => c - b'a' + 10,
                b'A'..=b'F' => c - b'A' + 10,
                _ => return Err(JsonError::BadUnicodeEscape),
            };
            v = (v << 4) | d as u16;
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drain a document, collecting owned event descriptions (tests only).
    fn drain(input: &[u8]) -> Result<Vec<String>, JsonError> {
        let mut scratch = Vec::new();
        let mut p = PullParser::new(input, &mut scratch);
        let mut out = Vec::new();
        loop {
            match p.next()? {
                Event::End => return Ok(out),
                ev => out.push(format!("{ev:?}")),
            }
        }
    }

    #[test]
    fn pulls_a_flat_request_shape() {
        let evs = drain(br#"{"task":"sst2","text_a":[5,6],"text_b":null}"#).unwrap();
        assert_eq!(
            evs,
            vec![
                "ObjBegin",
                "Key(\"task\")",
                "Str(\"sst2\")",
                "Key(\"text_a\")",
                "ArrBegin",
                "Num(5.0)",
                "Num(6.0)",
                "ArrEnd",
                "Key(\"text_b\")",
                "Null",
                "ObjEnd",
            ]
        );
    }

    #[test]
    fn scalars_and_whitespace() {
        assert_eq!(drain(b" true ").unwrap(), vec!["Bool(true)"]);
        assert_eq!(drain(b"false").unwrap(), vec!["Bool(false)"]);
        assert_eq!(drain(b"null").unwrap(), vec!["Null"]);
        assert_eq!(drain(b"-12.5e2").unwrap(), vec!["Num(-1250.0)"]);
        assert_eq!(drain(b"\t[ ]\r\n").unwrap(), vec!["ArrBegin", "ArrEnd"]);
        assert_eq!(drain(b"{ }").unwrap(), vec!["ObjBegin", "ObjEnd"]);
    }

    #[test]
    fn escapes_unescape_into_scratch() {
        let evs = drain(br#""a\n\"b\"\u0041\ud83d\ude00""#).unwrap();
        assert_eq!(evs, vec!["Str(\"a\\n\\\"b\\\"A\u{1F600}\")"]);
    }

    #[test]
    fn end_is_sticky() {
        let mut scratch = Vec::new();
        let mut p = PullParser::new(b"1", &mut scratch);
        assert_eq!(p.next().unwrap(), Event::Num(1.0));
        assert_eq!(p.next().unwrap(), Event::End);
        assert_eq!(p.next().unwrap(), Event::End);
    }

    #[test]
    fn typed_errors_for_malformed_documents() {
        let cases: &[(&[u8], JsonError)] = &[
            (b"", JsonError::UnexpectedEof),
            (b"{", JsonError::UnexpectedEof),
            (b"[1,", JsonError::UnexpectedEof),
            (b"\"abc", JsonError::UnexpectedEof),
            (b"{\"a\"", JsonError::UnexpectedEof),
            (b"x", JsonError::UnexpectedByte),
            (b"[1 2]", JsonError::UnexpectedByte),
            (b"{\"a\":1]", JsonError::UnexpectedByte),
            (b"[1,2}", JsonError::UnexpectedByte),
            (b"[1,]", JsonError::UnexpectedByte),
            (b"{\"a\":1,}", JsonError::UnexpectedByte),
            (b"{1:2}", JsonError::UnexpectedByte),
            (b"NaN", JsonError::UnexpectedByte),
            (b"tru", JsonError::BadLiteral),
            (b"nul", JsonError::BadLiteral),
            (b"falsy", JsonError::BadLiteral),
            (b"01", JsonError::BadNumber),
            (b"1.", JsonError::BadNumber),
            (b"-", JsonError::BadNumber),
            (b"1e", JsonError::BadNumber),
            (b"1e999", JsonError::NonFiniteNumber),
            (b"\"a\x01b\"", JsonError::BadString),
            (b"\"a\\x\"", JsonError::BadEscape),
            (b"\"\\u12g4\"", JsonError::BadUnicodeEscape),
            (b"\"\\ud800x\"", JsonError::BadUnicodeEscape),
            (b"\"\\udc00\"", JsonError::BadUnicodeEscape),
            (b"\"\xff\"", JsonError::InvalidUtf8),
            (b"1 2", JsonError::TrailingData),
            (b"{}{}", JsonError::TrailingData),
        ];
        for (input, want) in cases {
            let got = drain(input);
            assert_eq!(
                got.as_ref().err(),
                Some(want),
                "input {:?} -> {:?}",
                String::from_utf8_lossy(input),
                got
            );
        }
    }

    #[test]
    fn bitstack_depth_is_bounded_not_recursive() {
        // depth == MAX_DEPTH parses; one deeper overflows with a typed error
        let mut ok = Vec::new();
        ok.extend(std::iter::repeat(b'[').take(MAX_DEPTH));
        ok.extend(std::iter::repeat(b']').take(MAX_DEPTH));
        let evs = drain(&ok).unwrap();
        assert_eq!(evs.len(), 2 * MAX_DEPTH);

        let mut deep = Vec::new();
        deep.extend(std::iter::repeat(b'[').take(MAX_DEPTH + 1));
        assert_eq!(drain(&deep).err(), Some(JsonError::DepthOverflow));

        // mixed object/array nesting keeps the bits straight
        let evs = drain(b"{\"a\":[{\"b\":[[]]}]}").unwrap();
        assert_eq!(evs.last().unwrap(), "ObjEnd");
    }

    #[test]
    fn borrowed_fast_path_skips_scratch() {
        let mut scratch = Vec::new();
        let input = br#"{"key":"plain value"}"#;
        let mut p = PullParser::new(input, &mut scratch);
        loop {
            if p.next().unwrap() == Event::End {
                break;
            }
        }
        assert_eq!(scratch.capacity(), 0, "escape-free parse must not touch scratch");
    }
}
