//! Deterministic RNG substrate (SplitMix64 + helpers).
//!
//! The environment has no `rand` crate; every stochastic component in the
//! framework (parameter init, corpus/task generation, batch shuffling, MLM
//! masking) draws from this generator so experiments are bit-reproducible
//! from a single seed.

/// SplitMix64: tiny, fast, high-quality 64-bit PRNG. Not cryptographic.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// A generator from a seed (SplitMix64-scrambled).
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Derive an independent stream (for per-task / per-layer substreams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let s = self.next_u64() ^ tag.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        Rng::new(s)
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Bernoulli draw with probability `p`.
    pub fn chance(&mut self, p: f32) -> bool {
        self.next_f32() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.next_f32() + 1e-12).min(1.0);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fill with N(0, std^2).
    pub fn fill_normal(&mut self, buf: &mut [f32], std: f32) {
        for v in buf.iter_mut() {
            *v = self.normal() * std;
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut x = self.next_f32() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k.min(n));
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_differ() {
        let mut root = Rng::new(7);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mean: f32 = (0..n).map(|_| r.next_f32()).sum::<f32>() / n as f32;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(5);
        let w = [0.0, 0.0, 10.0, 0.1];
        let hits = (0..1000).filter(|_| r.weighted(&w) == 2).count();
        assert!(hits > 900);
    }
}
