//! Shared substrates built in-tree because the offline environment carries
//! no third-party crates (even `anyhow` is a vendored shim): JSON,
//! deterministic RNG, and a mini benchmark harness.

pub mod bench;
pub mod json;
pub mod pull_json;
pub mod rng;

pub use json::Json;
pub use pull_json::{Event, JsonError, PullParser};
pub use rng::Rng;

/// Simple stable hash (FNV-1a) for cache keys and run ids.
pub fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_stable_and_distinct() {
        assert_eq!(fnv1a("abc"), fnv1a("abc"));
        assert_ne!(fnv1a("abc"), fnv1a("abd"));
    }
}
