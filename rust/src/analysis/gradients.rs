//! Table 1: gradient and unit-gradient ranking of parameter modules in the
//! first and last training epoch.
//!
//! The paper sums |grad| per named parameter over an epoch, ranks the top
//! five, and separately ranks "unit gradients" (|grad| / #params) — the
//! analysis that motivates training the classifier + normalization modules.

use std::collections::HashMap;

/// Accumulated gradient statistics over an epoch.
#[derive(Debug, Clone, Default)]
pub struct GradAccum {
    /// name -> (sum |grad|, numel)
    totals: HashMap<String, (f64, usize)>,
}

impl GradAccum {
    /// An empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one batch's per-parameter L1 norms.
    pub fn add(&mut self, norms: &[(String, f64)], numels: &HashMap<String, usize>) {
        for (name, l1) in norms {
            let e = self
                .totals
                .entry(name.clone())
                .or_insert((0.0, *numels.get(name).unwrap_or(&1)));
            e.0 += l1;
        }
    }

    /// Top-k by raw gradient mass.
    pub fn top_by_gradient(&self, k: usize) -> Vec<(String, f64)> {
        let mut v: Vec<(String, f64)> = self
            .totals
            .iter()
            .map(|(n, (g, _))| (n.clone(), *g))
            .collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v.truncate(k);
        v
    }

    /// Top-k by unit gradient (gradient mass / parameter count).
    pub fn top_by_unit_gradient(&self, k: usize) -> Vec<(String, f64)> {
        let mut v: Vec<(String, f64)> = self
            .totals
            .iter()
            .map(|(n, (g, c))| (n.clone(), *g / (*c).max(1) as f64))
            .collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v.truncate(k);
        v
    }

    /// Fraction of total gradient mass captured by names matching `pred`
    /// (used to verify the paper's claim that classifier/embedding/
    /// intermediate dominate raw gradients).
    pub fn mass_fraction(&self, pred: impl Fn(&str) -> bool) -> f64 {
        let total: f64 = self.totals.values().map(|(g, _)| g).sum();
        if total == 0.0 {
            return 0.0;
        }
        let hit: f64 = self
            .totals
            .iter()
            .filter(|(n, _)| pred(n))
            .map(|(_, (g, _))| g)
            .sum();
        hit / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn numels() -> HashMap<String, usize> {
        [
            ("big.weight".to_string(), 10_000usize),
            ("small.bias".to_string(), 10usize),
        ]
        .into_iter()
        .collect()
    }

    #[test]
    fn rankings_differ_between_gradient_and_unit() {
        let mut acc = GradAccum::new();
        acc.add(
            &[
                ("big.weight".to_string(), 100.0),
                ("small.bias".to_string(), 50.0),
            ],
            &numels(),
        );
        // raw: big wins
        assert_eq!(acc.top_by_gradient(1)[0].0, "big.weight");
        // unit: small wins (50/10 >> 100/10000)
        assert_eq!(acc.top_by_unit_gradient(1)[0].0, "small.bias");
    }

    #[test]
    fn accumulates_over_batches() {
        let mut acc = GradAccum::new();
        for _ in 0..3 {
            acc.add(&[("big.weight".to_string(), 1.0)], &numels());
        }
        assert!((acc.top_by_gradient(1)[0].1 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn mass_fraction_partition() {
        let mut acc = GradAccum::new();
        acc.add(
            &[
                ("big.weight".to_string(), 75.0),
                ("small.bias".to_string(), 25.0),
            ],
            &numels(),
        );
        let f = acc.mass_fraction(|n| n.contains("big"));
        assert!((f - 0.75).abs() < 1e-12);
        let g = acc.mass_fraction(|n| n.contains("small"));
        assert!((f + g - 1.0).abs() < 1e-12);
    }
}
