//! Fig. 5: exploratory analysis of trained Hadamard adapters across tasks —
//! per-layer weight/bias distributions and cross-task cosine-similarity
//! heatmaps (the paper's evidence that adapter weights are reusable across
//! tasks while biases carry the task identity).

use std::collections::HashMap;

use anyhow::Result;

use crate::model::ParamStore;
use crate::report::BoxStats;

use super::cosine;

/// Extracted adapter vectors from a tuned store.
#[derive(Debug, Clone)]
pub struct AdapterVectors {
    /// Task the vectors were tuned on.
    pub task: String,
    /// per-layer hadamard.weight.
    pub weights: Vec<Vec<f32>>,
    /// per-layer hadamard.bias.
    pub biases: Vec<Vec<f32>>,
    /// per-layer output LayerNorm weight / bias (the Fig 5 b-panels).
    pub norm_weights: Vec<Vec<f32>>,
    /// Per-layer output-LayerNorm biases.
    pub norm_biases: Vec<Vec<f32>>,
}

/// Pull the adapter + norm vectors for all layers out of a tuned store.
pub fn extract(task: &str, store: &ParamStore, layers: usize) -> Result<AdapterVectors> {
    let grab = |pat: &str| -> Result<Vec<Vec<f32>>> {
        (0..layers)
            .map(|l| {
                let name = format!("encoder.layer.{l}.{pat}");
                Ok(store.get(&name)?.data.clone())
            })
            .collect()
    };
    Ok(AdapterVectors {
        task: task.to_string(),
        weights: grab("hadamard.weight")?,
        biases: grab("hadamard.bias")?,
        norm_weights: grab("output.LayerNorm.weight")?,
        norm_biases: grab("output.LayerNorm.bias")?,
    })
}

/// Per-layer distribution of a vector family pooled across tasks
/// (Fig 5 a1/a2/b1..b4: one box per layer over all tasks' values).
pub fn layer_distributions(
    all: &[AdapterVectors],
    select: impl Fn(&AdapterVectors) -> &Vec<Vec<f32>>,
) -> Vec<BoxStats> {
    assert!(!all.is_empty());
    let layers = select(&all[0]).len();
    (0..layers)
        .map(|l| {
            let pooled: Vec<f32> = all
                .iter()
                .flat_map(|av| select(av)[l].iter().copied())
                .collect();
            BoxStats::from(&pooled)
        })
        .collect()
}

/// Cross-task cosine-similarity matrix at one layer (or averaged).
#[derive(Debug, Clone)]
pub struct SimMatrix {
    /// Task order of the matrix rows/columns.
    pub tasks: Vec<String>,
    /// row-major [n x n].
    pub values: Vec<f64>,
}

impl SimMatrix {
    /// Similarity between tasks `i` and `j`.
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.values[i * self.tasks.len() + j]
    }

    /// Mean of off-diagonal entries (the paper's headline: ~1.0 for
    /// weights, much lower for biases).
    pub fn off_diagonal_mean(&self) -> f64 {
        let n = self.tasks.len();
        if n < 2 {
            return 1.0;
        }
        let mut sum = 0.0;
        let mut count = 0;
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    sum += self.get(i, j);
                    count += 1;
                }
            }
        }
        sum / count as f64
    }
}

/// Similarity of one vector family at one layer across tasks.
pub fn similarity_at_layer(
    all: &[AdapterVectors],
    layer: usize,
    select: impl Fn(&AdapterVectors) -> &Vec<Vec<f32>>,
) -> SimMatrix {
    let n = all.len();
    let mut values = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            values[i * n + j] = cosine(&select(&all[i])[layer], &select(&all[j])[layer]);
        }
    }
    SimMatrix {
        tasks: all.iter().map(|a| a.task.clone()).collect(),
        values,
    }
}

/// Layer-averaged similarity matrix.
pub fn similarity_avg(
    all: &[AdapterVectors],
    select: impl Fn(&AdapterVectors) -> &Vec<Vec<f32>> + Copy,
) -> SimMatrix {
    let layers = select(&all[0]).len();
    let n = all.len();
    let mut acc = vec![0.0; n * n];
    for l in 0..layers {
        let m = similarity_at_layer(all, l, select);
        for (a, v) in acc.iter_mut().zip(&m.values) {
            *a += v / layers as f64;
        }
    }
    SimMatrix {
        tasks: all.iter().map(|a| a.task.clone()).collect(),
        values: acc,
    }
}

/// Deviation-from-identity summaries (how far w strays from 1, b from 0) —
/// used by the Fig 5 "vary around 1.0 / 0.0" observation.
pub fn identity_deviation(av: &AdapterVectors) -> HashMap<&'static str, f64> {
    let dev = |vs: &Vec<Vec<f32>>, center: f32| -> f64 {
        let all: Vec<f32> = vs.iter().flatten().map(|&x| x - center).collect();
        (all.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / all.len() as f64).sqrt()
    };
    let mut m = HashMap::new();
    m.insert("weight_rms_dev_from_1", dev(&av.weights, 1.0));
    m.insert("bias_rms_dev_from_0", dev(&av.biases, 0.0));
    m.insert("norm_weight_rms_dev_from_1", dev(&av.norm_weights, 1.0));
    m.insert("norm_bias_rms_dev_from_0", dev(&av.norm_biases, 0.0));
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    fn av(task: &str, w: Vec<f32>, b: Vec<f32>) -> AdapterVectors {
        AdapterVectors {
            task: task.into(),
            weights: vec![w.clone(), w],
            biases: vec![b.clone(), b],
            norm_weights: vec![vec![1.0; 4]; 2],
            norm_biases: vec![vec![0.0; 4]; 2],
        }
    }

    #[test]
    fn identical_weights_give_unit_similarity() {
        let a = av("t1", vec![1.0, 1.1, 0.9, 1.0], vec![0.1, 0.0, -0.1, 0.0]);
        let b = av("t2", vec![1.0, 1.1, 0.9, 1.0], vec![-0.1, 0.2, 0.1, 0.0]);
        let m = similarity_at_layer(&[a.clone(), b.clone()], 0, |x| &x.weights);
        assert!((m.get(0, 1) - 1.0).abs() < 1e-9);
        let mb = similarity_at_layer(&[a, b], 0, |x| &x.biases);
        assert!(mb.get(0, 1) < 0.9); // biases diverge
    }

    #[test]
    fn off_diagonal_mean_ignores_diagonal() {
        let a = av("t1", vec![1.0, 0.0], vec![1.0, 0.0]);
        let b = av("t2", vec![0.0, 1.0], vec![0.0, 1.0]);
        let m = similarity_at_layer(&[a, b], 0, |x| &x.weights);
        assert!((m.off_diagonal_mean()).abs() < 1e-9);
    }

    #[test]
    fn layer_distributions_pool_tasks() {
        let a = av("t1", vec![1.0; 4], vec![0.0; 4]);
        let b = av("t2", vec![2.0; 4], vec![0.0; 4]);
        let d = layer_distributions(&[a, b], |x| &x.weights);
        assert_eq!(d.len(), 2);
        assert_eq!(d[0].min, 1.0);
        assert_eq!(d[0].max, 2.0);
        assert_eq!(d[0].mean, 1.5);
    }

    #[test]
    fn identity_deviation_zero_at_init() {
        let a = av("t", vec![1.0; 4], vec![0.0; 4]);
        let d = identity_deviation(&a);
        assert_eq!(d["weight_rms_dev_from_1"], 0.0);
        assert_eq!(d["bias_rms_dev_from_0"], 0.0);
    }
}
