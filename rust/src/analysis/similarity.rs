//! Fig. 5: exploratory analysis of trained Hadamard adapters across tasks —
//! per-layer weight/bias distributions and cross-task cosine-similarity
//! heatmaps (the paper's evidence that adapter weights are reusable across
//! tasks while biases carry the task identity).

use std::collections::HashMap;

use anyhow::Result;

use crate::model::ParamStore;
use crate::report::BoxStats;

use super::cosine;

/// Extracted adapter vectors from a tuned store.
#[derive(Debug, Clone)]
pub struct AdapterVectors {
    /// Task the vectors were tuned on.
    pub task: String,
    /// per-layer hadamard.weight.
    pub weights: Vec<Vec<f32>>,
    /// per-layer hadamard.bias.
    pub biases: Vec<Vec<f32>>,
    /// per-layer output LayerNorm weight / bias (the Fig 5 b-panels).
    pub norm_weights: Vec<Vec<f32>>,
    /// Per-layer output-LayerNorm biases.
    pub norm_biases: Vec<Vec<f32>>,
}

/// Pull the adapter + norm vectors for all layers out of a tuned store.
pub fn extract(task: &str, store: &ParamStore, layers: usize) -> Result<AdapterVectors> {
    let grab = |pat: &str| -> Result<Vec<Vec<f32>>> {
        (0..layers)
            .map(|l| {
                let name = format!("encoder.layer.{l}.{pat}");
                Ok(store.get(&name)?.data.clone())
            })
            .collect()
    };
    Ok(AdapterVectors {
        task: task.to_string(),
        weights: grab("hadamard.weight")?,
        biases: grab("hadamard.bias")?,
        norm_weights: grab("output.LayerNorm.weight")?,
        norm_biases: grab("output.LayerNorm.bias")?,
    })
}

/// Per-layer distribution of a vector family pooled across tasks
/// (Fig 5 a1/a2/b1..b4: one box per layer over all tasks' values).
pub fn layer_distributions(
    all: &[AdapterVectors],
    select: impl Fn(&AdapterVectors) -> &Vec<Vec<f32>>,
) -> Vec<BoxStats> {
    assert!(!all.is_empty());
    let layers = select(&all[0]).len();
    (0..layers)
        .map(|l| {
            let pooled: Vec<f32> = all
                .iter()
                .flat_map(|av| select(av)[l].iter().copied())
                .collect();
            BoxStats::from(&pooled)
        })
        .collect()
}

/// Cross-task cosine-similarity matrix at one layer (or averaged).
#[derive(Debug, Clone)]
pub struct SimMatrix {
    /// Task order of the matrix rows/columns.
    pub tasks: Vec<String>,
    /// row-major [n x n].
    pub values: Vec<f64>,
}

impl SimMatrix {
    /// Similarity between tasks `i` and `j`.
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.values[i * self.tasks.len() + j]
    }

    /// Mean of off-diagonal entries (the paper's headline: ~1.0 for
    /// weights, much lower for biases).
    pub fn off_diagonal_mean(&self) -> f64 {
        let n = self.tasks.len();
        if n < 2 {
            return 1.0;
        }
        let mut sum = 0.0;
        let mut count = 0;
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    sum += self.get(i, j);
                    count += 1;
                }
            }
        }
        sum / count as f64
    }
}

/// Similarity of one vector family at one layer across tasks.
pub fn similarity_at_layer(
    all: &[AdapterVectors],
    layer: usize,
    select: impl Fn(&AdapterVectors) -> &Vec<Vec<f32>>,
) -> SimMatrix {
    let n = all.len();
    let mut values = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            values[i * n + j] = cosine(&select(&all[i])[layer], &select(&all[j])[layer]);
        }
    }
    SimMatrix {
        tasks: all.iter().map(|a| a.task.clone()).collect(),
        values,
    }
}

/// Layer-averaged similarity matrix.
pub fn similarity_avg(
    all: &[AdapterVectors],
    select: impl Fn(&AdapterVectors) -> &Vec<Vec<f32>> + Copy,
) -> SimMatrix {
    let layers = select(&all[0]).len();
    let n = all.len();
    let mut acc = vec![0.0; n * n];
    for l in 0..layers {
        let m = similarity_at_layer(all, l, select);
        for (a, v) in acc.iter_mut().zip(&m.values) {
            *a += v / layers as f64;
        }
    }
    SimMatrix {
        tasks: all.iter().map(|a| a.task.clone()).collect(),
        values: acc,
    }
}

/// Deviation-from-identity summaries (how far w strays from 1, b from 0) —
/// used by the Fig 5 "vary around 1.0 / 0.0" observation.
pub fn identity_deviation(av: &AdapterVectors) -> HashMap<&'static str, f64> {
    let dev = |vs: &Vec<Vec<f32>>, center: f32| -> f64 {
        let all: Vec<f32> = vs.iter().flatten().map(|&x| x - center).collect();
        (all.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / all.len() as f64).sqrt()
    };
    let mut m = HashMap::new();
    m.insert("weight_rms_dev_from_1", dev(&av.weights, 1.0));
    m.insert("bias_rms_dev_from_0", dev(&av.biases, 0.0));
    m.insert("norm_weight_rms_dev_from_1", dev(&av.norm_weights, 1.0));
    m.insert("norm_bias_rms_dev_from_0", dev(&av.norm_biases, 0.0));
    m
}

/// K-means clustering of adapter-vector bundles into shared centroids.
///
/// This is the serve-time exploitation of the paper's cross-task
/// similarity finding: Hadamard weights are near-reusable across tasks,
/// so a large tenant fleet collapses onto a few shared per-layer
/// centroids, with per-tenant storage reduced to the rows that differ
/// (see `runtime::bankstore`).
#[derive(Debug, Clone)]
pub struct ClusterModel {
    /// Number of clusters (clamped to the input size).
    pub k: usize,
    /// Per-input cluster assignment; `assignments[i]` indexes `centroids`.
    pub assignments: Vec<usize>,
    /// Index into the input slice of the member each centroid snapped to.
    pub medoids: Vec<usize>,
    /// Cluster centers. Each is a **bitwise copy of its medoid member**
    /// (not a floating mean), so a centroid row can dedupe a duplicate
    /// member row exactly — the property the delta encoder relies on.
    pub centroids: Vec<AdapterVectors>,
}

fn flatten(av: &AdapterVectors) -> Vec<f64> {
    let mut out = Vec::new();
    for fam in [&av.weights, &av.biases, &av.norm_weights, &av.norm_biases] {
        for row in fam.iter() {
            out.extend(row.iter().map(|&x| x as f64));
        }
    }
    out
}

fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

fn nearest(f: &[f64], centers: &[Vec<f64>]) -> usize {
    let mut best = 0;
    let mut best_d = f64::INFINITY;
    for (c, center) in centers.iter().enumerate() {
        let d = dist2(f, center);
        if d < best_d {
            best_d = d;
            best = c;
        }
    }
    best
}

/// Deterministic Lloyd k-means over flattened adapter vectors, snapped to
/// medoids.
///
/// Initial centers are evenly spaced members (no RNG — same input, same
/// clustering, on every machine). After `iters` Lloyd rounds the centers
/// are snapped to their nearest member (the medoid) and every input is
/// re-assigned against the snapped centers, so an input that is a bitwise
/// duplicate of a medoid always lands in that medoid's cluster at
/// distance zero. Empty clusters keep their previous center.
pub fn cluster_adapters(all: &[AdapterVectors], k: usize, iters: usize) -> ClusterModel {
    assert!(!all.is_empty(), "cluster_adapters: empty input");
    let k = k.clamp(1, all.len());
    let feats: Vec<Vec<f64>> = all.iter().map(flatten).collect();
    let mut centers: Vec<Vec<f64>> = (0..k).map(|c| feats[c * all.len() / k].clone()).collect();
    let mut assignments = vec![0usize; all.len()];
    for _ in 0..iters.max(1) {
        for (i, f) in feats.iter().enumerate() {
            assignments[i] = nearest(f, &centers);
        }
        for (c, center) in centers.iter_mut().enumerate() {
            let members: Vec<usize> =
                (0..all.len()).filter(|&i| assignments[i] == c).collect();
            if members.is_empty() {
                continue;
            }
            for (d, slot) in center.iter_mut().enumerate() {
                *slot = members.iter().map(|&m| feats[m][d]).sum::<f64>() / members.len() as f64;
            }
        }
    }
    let mut medoids = Vec::with_capacity(k);
    let mut centroids = Vec::with_capacity(k);
    for (c, center) in centers.iter().enumerate() {
        let best = (0..all.len())
            .filter(|&i| assignments[i] == c)
            .min_by(|&a, &b| {
                dist2(&feats[a], center)
                    .partial_cmp(&dist2(&feats[b], center))
                    .unwrap()
            })
            .unwrap_or(c * all.len() / k);
        medoids.push(best);
        let mut cv = all[best].clone();
        cv.task = format!("centroid.{c}");
        centroids.push(cv);
    }
    let med_feats: Vec<Vec<f64>> = medoids.iter().map(|&m| feats[m].clone()).collect();
    for (i, f) in feats.iter().enumerate() {
        assignments[i] = nearest(f, &med_feats);
    }
    ClusterModel { k, assignments, medoids, centroids }
}

/// Which layers of one task's adapter are redundant — within `epsilon`
/// (max-abs, all four vector families) of a reference bundle, typically
/// the untuned backbone rows (weight = 1, bias = 0, backbone LayerNorm).
///
/// The paper's §redundant-layers result (0.033% → 0.022% of model
/// parameters): a redundant layer serves the backbone row and stores
/// nothing. For `epsilon = 0` the mask only marks bitwise-equal layers,
/// so reconstruction from a mask is exact, not approximate.
#[derive(Debug, Clone)]
pub struct RedundancyMask {
    /// Task the mask was computed for.
    pub task: String,
    /// `redundant[l]` — layer `l` is within epsilon of the reference.
    pub redundant: Vec<bool>,
}

impl RedundancyMask {
    /// Number of layers that must actually be stored (non-redundant).
    pub fn stored_layers(&self) -> usize {
        self.redundant.iter().filter(|r| !**r).count()
    }
}

/// Compute the per-layer redundancy mask of `av` against `reference`.
pub fn redundant_layers(
    av: &AdapterVectors,
    reference: &AdapterVectors,
    epsilon: f64,
) -> RedundancyMask {
    let layers = av.weights.len();
    assert_eq!(layers, reference.weights.len(), "layer count mismatch");
    let redundant = (0..layers)
        .map(|l| {
            let fams = [
                (&av.weights[l], &reference.weights[l]),
                (&av.biases[l], &reference.biases[l]),
                (&av.norm_weights[l], &reference.norm_weights[l]),
                (&av.norm_biases[l], &reference.norm_biases[l]),
            ];
            fams.iter().all(|(a, r)| {
                a.len() == r.len()
                    && a.iter()
                        .zip(r.iter())
                        .all(|(&x, &y)| ((x - y).abs() as f64) <= epsilon)
            })
        })
        .collect();
    RedundancyMask { task: av.task.clone(), redundant }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn av(task: &str, w: Vec<f32>, b: Vec<f32>) -> AdapterVectors {
        AdapterVectors {
            task: task.into(),
            weights: vec![w.clone(), w],
            biases: vec![b.clone(), b],
            norm_weights: vec![vec![1.0; 4]; 2],
            norm_biases: vec![vec![0.0; 4]; 2],
        }
    }

    #[test]
    fn identical_weights_give_unit_similarity() {
        let a = av("t1", vec![1.0, 1.1, 0.9, 1.0], vec![0.1, 0.0, -0.1, 0.0]);
        let b = av("t2", vec![1.0, 1.1, 0.9, 1.0], vec![-0.1, 0.2, 0.1, 0.0]);
        let m = similarity_at_layer(&[a.clone(), b.clone()], 0, |x| &x.weights);
        assert!((m.get(0, 1) - 1.0).abs() < 1e-9);
        let mb = similarity_at_layer(&[a, b], 0, |x| &x.biases);
        assert!(mb.get(0, 1) < 0.9); // biases diverge
    }

    #[test]
    fn off_diagonal_mean_ignores_diagonal() {
        let a = av("t1", vec![1.0, 0.0], vec![1.0, 0.0]);
        let b = av("t2", vec![0.0, 1.0], vec![0.0, 1.0]);
        let m = similarity_at_layer(&[a, b], 0, |x| &x.weights);
        assert!((m.off_diagonal_mean()).abs() < 1e-9);
    }

    #[test]
    fn layer_distributions_pool_tasks() {
        let a = av("t1", vec![1.0; 4], vec![0.0; 4]);
        let b = av("t2", vec![2.0; 4], vec![0.0; 4]);
        let d = layer_distributions(&[a, b], |x| &x.weights);
        assert_eq!(d.len(), 2);
        assert_eq!(d[0].min, 1.0);
        assert_eq!(d[0].max, 2.0);
        assert_eq!(d[0].mean, 1.5);
    }

    #[test]
    fn identity_deviation_zero_at_init() {
        let a = av("t", vec![1.0; 4], vec![0.0; 4]);
        let d = identity_deviation(&a);
        assert_eq!(d["weight_rms_dev_from_1"], 0.0);
        assert_eq!(d["bias_rms_dev_from_0"], 0.0);
    }

    #[test]
    fn clustering_separates_obvious_groups_and_snaps_to_medoids() {
        let g1a = av("a", vec![1.0; 4], vec![0.0; 4]);
        let g1b = av("b", vec![1.01; 4], vec![0.0; 4]);
        let g2a = av("c", vec![3.0; 4], vec![0.5; 4]);
        let g2b = av("d", vec![3.02; 4], vec![0.5; 4]);
        let all = [g1a, g2a, g1b, g2b];
        let m = cluster_adapters(&all, 2, 8);
        assert_eq!(m.k, 2);
        assert_eq!(m.assignments[0], m.assignments[2]);
        assert_eq!(m.assignments[1], m.assignments[3]);
        assert_ne!(m.assignments[0], m.assignments[1]);
        // every centroid is a bitwise copy of its medoid member
        for (c, &mi) in m.medoids.iter().enumerate() {
            assert_eq!(m.centroids[c].weights, all[mi].weights);
            assert_eq!(m.centroids[c].biases, all[mi].biases);
            assert_eq!(m.assignments[mi], c, "medoid must belong to its own cluster");
        }
    }

    #[test]
    fn duplicate_of_a_medoid_lands_in_that_cluster() {
        let base = av("base", vec![1.0, 1.2, 0.8, 1.1], vec![0.1, -0.2, 0.0, 0.3]);
        let dup = AdapterVectors { task: "dup".into(), ..base.clone() };
        let far = av("far", vec![5.0; 4], vec![2.0; 4]);
        let all = [base, far, dup];
        let m = cluster_adapters(&all, 2, 4);
        assert_eq!(m.assignments[0], m.assignments[2]);
        let c = m.assignments[2];
        assert_eq!(m.centroids[c].weights, all[2].weights);
    }

    #[test]
    fn redundancy_mask_marks_identity_layers() {
        let reference = av("ref", vec![1.0; 4], vec![0.0; 4]);
        let mut tuned = av("t", vec![1.0; 4], vec![0.0; 4]);
        tuned.weights[1][2] = 1.25; // only layer 1 deviates
        let m = redundant_layers(&tuned, &reference, 0.0);
        assert_eq!(m.redundant, vec![true, false]);
        assert_eq!(m.stored_layers(), 1);
        // a loose epsilon absorbs the deviation
        let loose = redundant_layers(&tuned, &reference, 0.5);
        assert_eq!(loose.stored_layers(), 0);
    }
}
