//! Analysis module: the paper's empirical studies (Sec. 2) and exploratory
//! analysis (Sec. 5), computed from evaluation probes, gradient probes, and
//! trained adapter vectors.

pub mod gradients;
pub mod similarity;

use crate::report::BoxStats;

/// Fig. 1: per-layer distribution of the self-attention output 2-norms
/// before/after fine-tuning, plus the relative change Δ (paper Eq. 2).
#[derive(Debug, Clone)]
pub struct NormShift {
    /// Encoder layer index.
    pub layer: usize,
    /// Norm distribution before fine-tuning.
    pub before: BoxStats,
    /// Norm distribution after fine-tuning.
    pub after: BoxStats,
    /// Δ = (||A_a|| - ||A_b||) / ||A_b||, distribution over examples.
    pub delta: BoxStats,
}

/// Compute Fig. 1 statistics from per-layer norm samples.
/// `before[l]` / `after[l]` are per-example spectral norms at layer `l`
/// (paired: same examples, pre- and post-fine-tuning parameters).
pub fn norm_shift(before: &[Vec<f32>], after: &[Vec<f32>]) -> Vec<NormShift> {
    assert_eq!(before.len(), after.len());
    before
        .iter()
        .zip(after)
        .enumerate()
        .map(|(layer, (b, a))| {
            assert_eq!(b.len(), a.len());
            let delta: Vec<f32> = b
                .iter()
                .zip(a)
                .map(|(&x, &y)| if x.abs() > 1e-9 { (y - x) / x } else { 0.0 })
                .collect();
            NormShift {
                layer,
                before: BoxStats::from(b),
                after: BoxStats::from(a),
                delta: BoxStats::from(&delta),
            }
        })
        .collect()
}

/// Fig. 2: characteristic values (mean adapter output per example, averaged
/// over hidden and sequence — paper Eq. 3-4) per layer for one setting.
#[derive(Debug, Clone)]
pub struct Characteristic {
    /// Encoder layer index.
    pub layer: usize,
    /// Distribution of per-example characteristic values.
    pub dist: BoxStats,
}

/// Compute Fig. 2 statistics from per-layer adapter-output means.
pub fn characteristics(means: &[Vec<f32>]) -> Vec<Characteristic> {
    means
        .iter()
        .enumerate()
        .map(|(layer, m)| Characteristic { layer, dist: BoxStats::from(m) })
        .collect()
}

/// Cosine similarity between two vectors.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += x as f64 * x as f64;
        nb += y as f64 * y as f64;
    }
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na.sqrt() * nb.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norm_shift_signs() {
        let before = vec![vec![1.0f32, 2.0, 4.0]];
        let after = vec![vec![2.0f32, 4.0, 8.0]];
        let s = norm_shift(&before, &after);
        assert_eq!(s.len(), 1);
        assert!((s[0].delta.mean - 1.0).abs() < 1e-9); // doubled everywhere
        assert!(s[0].after.median > s[0].before.median);
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[0.0, 1.0])).abs() < 1e-12);
        assert!((cosine(&[1.0, 2.0], &[-1.0, -2.0]) + 1.0).abs() < 1e-12);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn characteristics_shape() {
        let c = characteristics(&[vec![0.0, 1.0], vec![2.0, 4.0]]);
        assert_eq!(c.len(), 2);
        assert_eq!(c[1].dist.mean, 3.0);
    }
}
