//! The engine: a manifest plus a [`Backend`] that executes artifacts.
//!
//! The default build uses [`NativeBackend`] — a pure-Rust executor needing
//! no artifacts directory, no Python and no network (the manifest falls
//! back to the builtin inventory when `manifest.json` is absent). With the
//! `xla` cargo feature, [`Engine::xla`] runs the original PJRT path over
//! AOT-lowered HLO text instead. All call sites (sessions, eval,
//! coordinator, experiments) are backend-agnostic through this type.

use std::cell::RefCell;
use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use super::backend::{Backend, BatchAdapters, DeviceTensor, InferBatch, InferOut};
use super::manifest::Manifest;
use super::native::NativeBackend;
use super::pool::PoolStats;
use super::tensor::{IntTensor, Tensor};

/// Compile + execution statistics (exposed for the perf harness).
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    /// Artifacts compiled (XLA only).
    pub compiles: usize,
    /// Seconds spent compiling.
    pub compile_secs: f64,
    /// Artifact executions.
    pub executions: usize,
    /// Seconds spent executing.
    pub execute_secs: f64,
}

/// The runtime engine. The engine itself runs one artifact at a time (the
/// PJRT wrapper types are not `Send`); the native backend's blocked
/// kernels fan out internally over the configured worker pool (the
/// `threads` config key).
pub struct Engine {
    manifest: Manifest,
    backend: Box<dyn Backend>,
    stats: RefCell<EngineStats>,
}

impl Engine {
    /// Native engine over the builtin model inventory — zero external
    /// dependencies; what tests and offline runs use.
    pub fn native() -> Result<Self> {
        Ok(Engine::with_backend(
            Manifest::builtin("artifacts"),
            Box::new(NativeBackend::new()),
        ))
    }

    /// Native engine over an artifacts directory: uses its `manifest.json`
    /// when present (so run geometry matches AOT artifacts), else the
    /// builtin inventory. Kernel workers auto-size to the machine.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        Engine::new_with_threads(artifacts_dir, 0)
    }

    /// Like [`Engine::new`] with an explicit kernel worker count (`0` =
    /// auto-detect; `1` = fully deterministic single-threaded kernels).
    pub fn new_with_threads(artifacts_dir: impl AsRef<Path>, threads: usize) -> Result<Self> {
        Engine::new_with_opts(artifacts_dir, threads, true)
    }

    /// Full native-engine knob set: worker count plus the frozen-weight
    /// packing toggle (the `packing` config key; on by default).
    pub fn new_with_opts(
        artifacts_dir: impl AsRef<Path>,
        threads: usize,
        packing: bool,
    ) -> Result<Self> {
        let manifest = Manifest::load_or_builtin(artifacts_dir)?;
        Ok(Engine::with_backend(
            manifest,
            Box::new(NativeBackend::with_threads(threads).packing(packing)),
        ))
    }

    /// PJRT engine over an artifacts directory produced by `make artifacts`.
    #[cfg(feature = "xla")]
    pub fn xla(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let backend = super::xla_backend::XlaBackend::new()?;
        Ok(Engine::with_backend(manifest, Box::new(backend)))
    }

    /// Assemble an engine from parts (custom backends, tests).
    pub fn with_backend(manifest: Manifest, backend: Box<dyn Backend>) -> Self {
        Engine { manifest, backend, stats: RefCell::new(EngineStats::default()) }
    }

    /// The engine's manifest (model inventory + artifact contracts).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Short backend id ("native" / "xla").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Execution statistics snapshot (compiles merged from the backend).
    pub fn stats(&self) -> EngineStats {
        let mut s = self.stats.borrow().clone();
        let (compiles, compile_secs) = self.backend.compile_stats();
        s.compiles = compiles;
        s.compile_secs = compile_secs;
        s
    }

    /// Prepare an artifact ahead of first use (compiles on XLA).
    pub fn warmup(&self, name: &str) -> Result<()> {
        let info = self.manifest.artifact(name)?;
        self.backend.warmup(&self.manifest, info)
    }

    /// Move a host f32 tensor into backend-resident form.
    pub fn upload(&self, t: &Tensor) -> Result<DeviceTensor> {
        self.backend.upload(t)
    }

    /// Move a host i32 tensor into backend-resident form.
    pub fn upload_int(&self, t: &IntTensor) -> Result<DeviceTensor> {
        self.backend.upload_int(t)
    }

    /// Owned upload: host-resident backends (native) wrap the tensor
    /// without copying. Prefer this whenever the caller builds the tensor
    /// just to upload it.
    pub fn upload_owned(&self, t: Tensor) -> Result<DeviceTensor> {
        self.backend.upload_owned(t)
    }

    /// Owned i32 upload; see [`Engine::upload_owned`].
    pub fn upload_int_owned(&self, t: IntTensor) -> Result<DeviceTensor> {
        self.backend.upload_int_owned(t)
    }

    /// Workspace-arena counters `(hits, misses)` — native backend only.
    pub fn arena_stats(&self) -> (u64, u64) {
        self.backend.arena_stats()
    }

    /// Pack-cache counters `(live packed weights, repacks)` — native only.
    pub fn pack_stats(&self) -> (u64, u64) {
        self.backend.pack_stats()
    }

    /// Kernel-pool dispatch counters (spawns/jobs/wakeups) — native only.
    /// `threads_spawned` stops growing after the first parallel step: the
    /// zero-spawn steady state `bench_runtime` and the pool tests pin.
    pub fn pool_stats(&self) -> PoolStats {
        self.backend.pool_stats()
    }

    /// Forward-only serve entry ([`crate::runtime::Backend::infer`]):
    /// run an inference pass of `model` over host batch slices with
    /// optional per-example adapter overlays, writing into a reusable
    /// [`InferOut`]. No training state, no probes, no output tensors —
    /// the multi-tenant serve path ([`crate::runtime::ServeSession`])
    /// drives all its batches through here.
    pub fn infer(
        &self,
        model: &str,
        params: &[DeviceTensor],
        batch: InferBatch<'_>,
        adapters: Option<&BatchAdapters>,
        out: &mut InferOut,
    ) -> Result<()> {
        let t0 = Instant::now();
        self.backend
            .infer(&self.manifest, model, params, batch, adapters, out)?;
        let mut s = self.stats.borrow_mut();
        s.executions += 1;
        s.execute_secs += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// Execute an artifact: parameters in canonical order, then batch
    /// tensors. Returns host tensors in manifest output order.
    pub fn run(&self, name: &str, inputs: &[&DeviceTensor]) -> Result<Vec<Tensor>> {
        let info = self.manifest.artifact(name)?;
        let t0 = Instant::now();
        let outs = self.backend.execute(&self.manifest, info, inputs)?;
        let mut s = self.stats.borrow_mut();
        s.executions += 1;
        s.execute_secs += t0.elapsed().as_secs_f64();
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_engine_builds_and_counts_stats() {
        let e = Engine::native().unwrap();
        assert_eq!(e.backend_name(), "native");
        assert!(e.manifest().model("tiny").is_ok());
        assert_eq!(e.stats().executions, 0);
        e.warmup("fwd_tiny").unwrap();
        assert!(e.warmup("fwd_nope").is_err());
    }

    #[test]
    fn new_falls_back_to_builtin_manifest() {
        let e = Engine::new("/definitely/not/a/dir").unwrap();
        assert!(e.manifest().artifact("train_cls_hadamard_tiny").is_ok());
    }

    #[test]
    fn new_with_threads_builds_native() {
        let e = Engine::new_with_threads("/definitely/not/a/dir", 2).unwrap();
        assert_eq!(e.backend_name(), "native");
        e.warmup("fwd_tiny").unwrap();
    }
}
