//! The PJRT engine: loads HLO-text artifacts, compiles them on the CPU
//! client, caches executables, and runs them.
//!
//! HLO *text* is the interchange format (see DESIGN.md §4.1):
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`. One compiled executable per artifact,
//! compiled on first use and cached for the life of the engine.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::manifest::Manifest;
use super::tensor::Tensor;

/// Compile + execution statistics (exposed for the perf harness).
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub compiles: usize,
    pub compile_secs: f64,
    pub executions: usize,
    pub execute_secs: f64,
}

/// The runtime engine. Single-threaded by construction (the PJRT wrapper
/// types are not `Send`); the coordinator owns exactly one.
pub struct Engine {
    client: PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    stats: RefCell<EngineStats>,
}

impl Engine {
    /// Create a CPU engine over an artifacts directory produced by
    /// `make artifacts`.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(EngineStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    pub fn stats(&self) -> EngineStats {
        self.stats.borrow().clone()
    }

    /// Fetch (compiling on first use) the executable for an artifact.
    pub fn executable(&self, name: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let info = self.manifest.artifact(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            info.file.to_str().unwrap(),
        )
        .with_context(|| format!("loading HLO text {:?}", info.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{name}'"))?,
        );
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut s = self.stats.borrow_mut();
            s.compiles += 1;
            s.compile_secs += dt;
        }
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile an artifact (used by the CLI `info`/warmup paths).
    pub fn warmup(&self, name: &str) -> Result<()> {
        self.executable(name).map(|_| ())
    }

    /// Run an artifact on host literals; unwraps the 1-tuple output into the
    /// per-output literal list.
    pub fn run(&self, name: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let exe = self.executable(name)?;
        let t0 = Instant::now();
        let result = exe.execute::<Literal>(inputs)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        let mut s = self.stats.borrow_mut();
        s.executions += 1;
        s.execute_secs += t0.elapsed().as_secs_f64();
        Ok(outs)
    }

    /// Run an artifact on device buffers (the hot path: frozen parameters
    /// stay resident on device; see `train::TrainSession`).
    pub fn run_buffers(
        &self,
        name: &str,
        inputs: &[&PjRtBuffer],
    ) -> Result<Vec<Literal>> {
        let exe = self.executable(name)?;
        let t0 = Instant::now();
        let result = exe.execute_b::<&PjRtBuffer>(inputs)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        let mut s = self.stats.borrow_mut();
        s.executions += 1;
        s.execute_secs += t0.elapsed().as_secs_f64();
        Ok(outs)
    }

    /// Upload a host tensor to the device.
    pub fn upload(&self, t: &Tensor) -> Result<PjRtBuffer> {
        t.to_buffer(&self.client)
    }
}
