//! Wire grammar for the serve front door: request framing, body decode,
//! response encode — all without per-request heap traffic.
//!
//! This module is the protocol half of the ingress layer (the socket
//! loop lives in [`super::server`]). Three pieces:
//!
//! * [`parse_head`] — an incremental HTTP/1.1 head parser over the
//!   connection's read buffer. Length-prefixed bodies only
//!   (`Content-Length`; `Transfer-Encoding` is rejected as unsupported),
//!   byte-slice scanning, no allocation, hard limits from
//!   [`WireLimits`].
//! * [`decode_request`] — the typed extractor over
//!   [`crate::util::PullParser`] events: decodes
//!   `{"task", "text_a", "text_b"}` into a caller-owned
//!   [`RequestScratch`] whose buffers are reused request to request.
//!   Strict by design: unknown fields, duplicate fields, wrong types,
//!   fractional/overflowing token ids and oversized token arrays each
//!   map to their own [`WireError`].
//! * [`ResponseBuf`] — a per-connection response accumulator: bodies are
//!   serialized into a reusable scratch, framed with a computed
//!   `Content-Length`, and appended to an output buffer so a pipelined
//!   wave is flushed with one `write_all`.
//!
//! Every failure mode is a `Copy` [`WireError`] with a stable kebab-case
//! [`WireError::code`] — the adversarial fixture corpus
//! (`rust/tests/fixtures/wire/`) names each fixture after the code it
//! must produce, and the `String`-backed `anyhow` shim never appears on
//! this path.

use crate::util::pull_json::{Event, JsonError, PullParser};

use super::serve::DirectReply;

/// Hard ceilings for untrusted wire input. Defaults are generous for the
/// models in the manifest and small enough that a hostile peer cannot
/// make the server buffer unbounded memory.
#[derive(Debug, Clone, Copy)]
pub struct WireLimits {
    /// Maximum request-head bytes (request line + headers + CRLFCRLF).
    pub max_head: usize,
    /// Maximum declared `Content-Length`.
    pub max_body: usize,
    /// Maximum token ids per `text_a`/`text_b` array.
    pub max_tokens: usize,
    /// Idle-connection deadline in milliseconds: how long a connection
    /// may sit without delivering a byte the server is waiting for
    /// before it is closed with [`WireError::IdleTimeout`]. A stalled
    /// client must not wedge the single-threaded wave loop. `0` disables
    /// the deadline (tests only; production keeps one).
    pub idle_timeout_ms: u64,
    /// Slowloris guard, distinct from the idle deadline: once a frame's
    /// first byte arrives, the *whole* frame must complete within this
    /// many milliseconds or the connection is closed with
    /// [`WireError::ProgressTimeout`]. A client trickling one byte per
    /// idle window resets the idle clock forever but never this one.
    /// `0` disables the guard.
    pub progress_timeout_ms: u64,
}

impl Default for WireLimits {
    fn default() -> WireLimits {
        WireLimits {
            max_head: 4096,
            max_body: 64 * 1024,
            max_tokens: 4096,
            idle_timeout_ms: 10_000,
            progress_timeout_ms: 30_000,
        }
    }
}

/// Which server-side counter a rejected request lands in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectKind {
    /// Framing/routing rejections (bad head, unknown route, bad method).
    Http,
    /// Body rejections (JSON grammar or request-shape violations).
    Parse,
    /// Admission rejections (unknown task, out-of-vocab token).
    Submit,
    /// Per-tenant rate rejections (429 with `Retry-After`).
    Throttle,
    /// Load-shedding rejections (queue full, draining for shutdown).
    Shed,
}

/// Typed wire failure: every way an untrusted request can be refused.
/// `Copy` on purpose — produced and serialized on the zero-alloc path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireError {
    /// Request line is not `METHOD SP TARGET SP VERSION`.
    BadRequestLine,
    /// Version is neither `HTTP/1.1` nor `HTTP/1.0`.
    BadVersion,
    /// Head exceeds [`WireLimits::max_head`] bytes.
    HeadTooLarge,
    /// A header line without a colon or with an empty name.
    BadHeader,
    /// `Content-Length` is not a plain decimal (or two headers disagree).
    BadContentLength,
    /// `Transfer-Encoding` present (only length-prefixed bodies served).
    UnsupportedTransferEncoding,
    /// Connection closed mid-head.
    TruncatedHead,
    /// Connection closed before `Content-Length` bytes arrived.
    TruncatedBody,
    /// The connection sat idle past [`WireLimits::idle_timeout_ms`]
    /// while the server was waiting for request bytes.
    IdleTimeout,
    /// Declared `Content-Length` exceeds [`WireLimits::max_body`].
    BodyTooLarge,
    /// No handler at the request target.
    UnknownRoute,
    /// Known route, wrong method.
    MethodNotAllowed,
    /// A JSON grammar violation in the body (wrapped parser error).
    Json(JsonError),
    /// The body's top-level value is not an object.
    NotAnObject,
    /// A request field appeared twice.
    DuplicateField,
    /// A field outside `task`/`text_a`/`text_b`.
    UnknownField,
    /// A field with the wrong JSON type (e.g. nested arrays as tokens).
    BadFieldType,
    /// No (or empty) `task` field.
    MissingTask,
    /// No `text_a` field.
    MissingText,
    /// A token id with a fractional part.
    TokenNotAnInteger,
    /// A token id outside the `i32` range.
    TokenOutOfRange,
    /// More than [`WireLimits::max_tokens`] ids in one array.
    TooManyTokens,
    /// The task has no registered adapter.
    UnknownTask,
    /// A token id outside the model's vocabulary.
    TokenOutOfVocab,
    /// The tenant is over its admission rate; the payload is the
    /// milliseconds until its token bucket refills (surfaced both as a
    /// `Retry-After` header and a `retry_after_ms` body field).
    TenantThrottled(u32),
    /// The global request queue is at capacity — load shed with 503.
    QueueFull,
    /// The server is draining after `POST /shutdown`; new submits are
    /// refused while in-flight waves complete.
    ShuttingDown,
    /// The connection-slot table is full: the accept-limit tier shed
    /// this connection before it could submit anything. Always fatal —
    /// the server answers once and closes.
    TooManyConns,
    /// A frame's first byte arrived but the frame did not complete
    /// within [`WireLimits::progress_timeout_ms`] (slowloris guard).
    ProgressTimeout,
    /// The serve path failed after admission (never expected; the
    /// response closes the connection).
    Internal,
}

impl From<JsonError> for WireError {
    fn from(e: JsonError) -> WireError {
        WireError::Json(e)
    }
}

impl WireError {
    /// Stable kebab-case code used in error bodies and fixture names.
    pub fn code(self) -> &'static str {
        match self {
            WireError::BadRequestLine => "bad-request-line",
            WireError::BadVersion => "bad-version",
            WireError::HeadTooLarge => "head-too-large",
            WireError::BadHeader => "bad-header",
            WireError::BadContentLength => "bad-content-length",
            WireError::UnsupportedTransferEncoding => "unsupported-transfer-encoding",
            WireError::TruncatedHead => "truncated-head",
            WireError::TruncatedBody => "truncated-body",
            WireError::IdleTimeout => "idle-timeout",
            WireError::BodyTooLarge => "body-too-large",
            WireError::UnknownRoute => "unknown-route",
            WireError::MethodNotAllowed => "method-not-allowed",
            WireError::Json(e) => e.code(),
            WireError::NotAnObject => "not-an-object",
            WireError::DuplicateField => "duplicate-field",
            WireError::UnknownField => "unknown-field",
            WireError::BadFieldType => "bad-field-type",
            WireError::MissingTask => "missing-task",
            WireError::MissingText => "missing-text",
            WireError::TokenNotAnInteger => "token-not-integer",
            WireError::TokenOutOfRange => "token-out-of-range",
            WireError::TooManyTokens => "too-many-tokens",
            WireError::UnknownTask => "unknown-task",
            WireError::TokenOutOfVocab => "token-out-of-vocab",
            WireError::TenantThrottled(_) => "tenant-throttled",
            WireError::QueueFull => "queue-full",
            WireError::ShuttingDown => "shutting-down",
            WireError::TooManyConns => "too-many-connections",
            WireError::ProgressTimeout => "progress-timeout",
            WireError::Internal => "internal",
        }
    }

    /// HTTP status and reason phrase.
    pub fn status(self) -> (u16, &'static str) {
        match self {
            WireError::HeadTooLarge => (431, "Request Header Fields Too Large"),
            WireError::BodyTooLarge | WireError::TooManyTokens => (413, "Payload Too Large"),
            WireError::UnknownRoute | WireError::UnknownTask => (404, "Not Found"),
            WireError::MethodNotAllowed => (405, "Method Not Allowed"),
            WireError::IdleTimeout | WireError::ProgressTimeout => (408, "Request Timeout"),
            WireError::TenantThrottled(_) => (429, "Too Many Requests"),
            WireError::QueueFull | WireError::ShuttingDown | WireError::TooManyConns => {
                (503, "Service Unavailable")
            }
            WireError::UnsupportedTransferEncoding => (501, "Not Implemented"),
            WireError::BadVersion => (505, "HTTP Version Not Supported"),
            WireError::Internal => (500, "Internal Server Error"),
            _ => (400, "Bad Request"),
        }
    }

    /// Short human-readable message (static: no quotes, no escapes).
    pub fn message(self) -> &'static str {
        match self {
            WireError::BadRequestLine => "malformed request line",
            WireError::BadVersion => "only HTTP/1.1 and HTTP/1.0 are served",
            WireError::HeadTooLarge => "request head exceeds the size limit",
            WireError::BadHeader => "malformed header line",
            WireError::BadContentLength => "content-length is not a plain decimal",
            WireError::UnsupportedTransferEncoding => {
                "transfer-encoding is not supported; send content-length"
            }
            WireError::TruncatedHead => "connection closed mid-head",
            WireError::TruncatedBody => "connection closed before the declared body arrived",
            WireError::IdleTimeout => "connection idle past the server deadline",
            WireError::BodyTooLarge => "declared content-length exceeds the body limit",
            WireError::UnknownRoute => "no handler at this path",
            WireError::MethodNotAllowed => "wrong method for this path",
            WireError::Json(_) => "request body is not valid JSON",
            WireError::NotAnObject => "request body must be a JSON object",
            WireError::DuplicateField => "a request field appeared twice",
            WireError::UnknownField => "only task, text_a and text_b are accepted",
            WireError::BadFieldType => "a request field has the wrong type",
            WireError::MissingTask => "a non-empty task field is required",
            WireError::MissingText => "a text_a token array is required",
            WireError::TokenNotAnInteger => "token ids must be integers",
            WireError::TokenOutOfRange => "token ids must fit in 32 bits",
            WireError::TooManyTokens => "too many token ids in one array",
            WireError::UnknownTask => "task has no registered adapter",
            WireError::TokenOutOfVocab => "token id outside the model vocabulary",
            WireError::TenantThrottled(_) => "tenant over its admission rate; honor retry-after",
            WireError::QueueFull => "request queue at capacity; retry with backoff",
            WireError::ShuttingDown => "server is draining for shutdown",
            WireError::TooManyConns => "connection limit reached; retry with backoff",
            WireError::ProgressTimeout => "request frame did not complete within the deadline",
            WireError::Internal => "serve path failed after admission",
        }
    }

    /// Whether the connection must close after this error. Framing and
    /// length errors desynchronize the byte stream — nothing after them
    /// can be trusted to start a request — so they are fatal; body-level
    /// rejections keep the connection (the frame boundary is intact).
    pub fn fatal(self) -> bool {
        matches!(
            self,
            WireError::BadRequestLine
                | WireError::BadVersion
                | WireError::HeadTooLarge
                | WireError::BadHeader
                | WireError::BadContentLength
                | WireError::UnsupportedTransferEncoding
                | WireError::TruncatedHead
                | WireError::TruncatedBody
                | WireError::IdleTimeout
                | WireError::ProgressTimeout
                | WireError::BodyTooLarge
                | WireError::ShuttingDown
                | WireError::TooManyConns
                | WireError::Internal
        )
    }

    /// Which reject counter this error lands in.
    pub fn bucket(self) -> RejectKind {
        match self {
            WireError::UnknownTask | WireError::TokenOutOfVocab => RejectKind::Submit,
            WireError::TenantThrottled(_) => RejectKind::Throttle,
            WireError::QueueFull | WireError::ShuttingDown | WireError::TooManyConns => {
                RejectKind::Shed
            }
            WireError::Json(_)
            | WireError::NotAnObject
            | WireError::DuplicateField
            | WireError::UnknownField
            | WireError::BadFieldType
            | WireError::MissingTask
            | WireError::MissingText
            | WireError::TokenNotAnInteger
            | WireError::TokenOutOfRange
            | WireError::TooManyTokens => RejectKind::Parse,
            _ => RejectKind::Http,
        }
    }
}

/// Request method (only the two served ones are distinguished).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// `GET`
    Get,
    /// `POST`
    Post,
    /// Anything else (always method-not-allowed or not-found).
    Other,
}

/// Request target, resolved at head-parse time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// `POST /infer` — decode, admit, serve.
    Infer,
    /// `GET /stats` — counter snapshot (server + session + engine).
    Stats,
    /// `GET /healthz` — liveness.
    Health,
    /// `POST /shutdown` — drain and exit the accept loop.
    Shutdown,
    /// No handler.
    Unknown,
}

/// A parsed request head.
#[derive(Debug, Clone, Copy)]
pub struct Head {
    /// Request method.
    pub method: Method,
    /// Resolved route.
    pub route: Route,
    /// Declared body length (0 when absent).
    pub content_length: usize,
    /// Bytes the head occupies in the buffer (through the CRLFCRLF).
    pub head_len: usize,
    /// Whether the connection stays open after the response
    /// (HTTP/1.1 default true, `Connection: close` false).
    pub keep_alive: bool,
}

/// Incrementally parse a request head from the front of `buf`.
///
/// Returns `Ok(None)` when the head is not complete yet (caller reads
/// more), `Ok(Some)` once the CRLFCRLF terminator is in the buffer, or a
/// typed error. No allocation, no copies — everything is byte-slice
/// scanning over the caller's read buffer.
pub fn parse_head(buf: &[u8], limits: &WireLimits) -> Result<Option<Head>, WireError> {
    let head_end = match find_subslice(buf, b"\r\n\r\n") {
        Some(i) => i,
        None => {
            if buf.len() > limits.max_head {
                return Err(WireError::HeadTooLarge);
            }
            return Ok(None);
        }
    };
    if head_end + 4 > limits.max_head {
        return Err(WireError::HeadTooLarge);
    }
    let head = &buf[..head_end];
    let line_end = find_subslice(head, b"\r\n").unwrap_or(head.len());
    let line = &head[..line_end];
    let sp1 = line.iter().position(|&c| c == b' ').ok_or(WireError::BadRequestLine)?;
    let rest = &line[sp1 + 1..];
    let sp2 = rest.iter().position(|&c| c == b' ').ok_or(WireError::BadRequestLine)?;
    let method_b = &line[..sp1];
    let target = &rest[..sp2];
    let version = &rest[sp2 + 1..];
    if method_b.is_empty() || target.is_empty() {
        return Err(WireError::BadRequestLine);
    }
    let http11 = version == b"HTTP/1.1";
    if !http11 && version != b"HTTP/1.0" {
        return Err(WireError::BadVersion);
    }
    let method = match method_b {
        b"GET" => Method::Get,
        b"POST" => Method::Post,
        _ => Method::Other,
    };
    let route = match target {
        b"/infer" => Route::Infer,
        b"/stats" => Route::Stats,
        b"/healthz" => Route::Health,
        b"/shutdown" => Route::Shutdown,
        _ => Route::Unknown,
    };
    let mut content_length: Option<usize> = None;
    let mut keep_alive = http11;
    let mut at = line_end;
    while at < head.len() {
        at += 2; // step over the separating CRLF
        let next = match find_subslice(&head[at..], b"\r\n") {
            Some(i) => at + i,
            None => head.len(),
        };
        let hline = &head[at..next];
        let colon =
            hline.iter().position(|&c| c == b':').ok_or(WireError::BadHeader)?;
        let name = trim_ascii(&hline[..colon]);
        let value = trim_ascii(&hline[colon + 1..]);
        if name.is_empty() {
            return Err(WireError::BadHeader);
        }
        if name.eq_ignore_ascii_case(b"content-length") {
            let n = parse_decimal(value).ok_or(WireError::BadContentLength)?;
            if content_length.is_some_and(|prev| prev != n) {
                return Err(WireError::BadContentLength);
            }
            content_length = Some(n);
        } else if name.eq_ignore_ascii_case(b"transfer-encoding") {
            return Err(WireError::UnsupportedTransferEncoding);
        } else if name.eq_ignore_ascii_case(b"connection") {
            if value.eq_ignore_ascii_case(b"close") {
                keep_alive = false;
            } else if value.eq_ignore_ascii_case(b"keep-alive") {
                keep_alive = true;
            }
        }
        at = next;
    }
    let content_length = content_length.unwrap_or(0);
    if content_length > limits.max_body {
        return Err(WireError::BodyTooLarge);
    }
    Ok(Some(Head { method, route, content_length, head_len: head_end + 4, keep_alive }))
}

/// Caller-owned decode target: every request reuses these buffers, so
/// after the first (largest) request the decode path allocates nothing.
#[derive(Debug, Default)]
pub struct RequestScratch {
    /// Decoded task name.
    pub task: String,
    /// Decoded `text_a` token ids.
    pub seq_a: Vec<i32>,
    /// Decoded `text_b` token ids (meaningful when `has_b`).
    pub seq_b: Vec<i32>,
    /// Whether the request carried a `text_b` *array* — an empty array
    /// is distinct from absent/`null` (pair rows encode an extra SEP).
    pub has_b: bool,
    /// Unescape scratch lent to the pull parser.
    str_buf: Vec<u8>,
}

impl RequestScratch {
    /// The `text_b` view the batcher takes (`None` when absent/`null`).
    pub fn text_b(&self) -> Option<&[i32]> {
        if self.has_b {
            Some(&self.seq_b)
        } else {
            None
        }
    }
}

/// Decode one `/infer` body into `scratch`. Strict single-pass extraction
/// over pull-parser events; on success `scratch` holds the request, on
/// failure it holds partial garbage the next decode overwrites.
pub fn decode_request(
    body: &[u8],
    limits: &WireLimits,
    scratch: &mut RequestScratch,
) -> Result<(), WireError> {
    // split borrow: the parser holds `str_buf` for its whole lifetime
    // while the extractor fills the sibling fields
    let RequestScratch { task, seq_a, seq_b, has_b, str_buf } = scratch;
    task.clear();
    seq_a.clear();
    seq_b.clear();
    *has_b = false;
    let mut p = PullParser::new(body, str_buf);
    match p.next()? {
        Event::ObjBegin => {}
        _ => return Err(WireError::NotAnObject),
    }
    const F_TASK: u8 = 1;
    const F_TEXT_A: u8 = 2;
    const F_TEXT_B: u8 = 4;
    let mut seen: u8 = 0;
    loop {
        let field = match p.next()? {
            Event::ObjEnd => break,
            Event::Key("task") => F_TASK,
            Event::Key("text_a") => F_TEXT_A,
            Event::Key("text_b") => F_TEXT_B,
            Event::Key(_) => return Err(WireError::UnknownField),
            // the parser only yields Key/ObjEnd in key position
            _ => return Err(WireError::NotAnObject),
        };
        if seen & field != 0 {
            return Err(WireError::DuplicateField);
        }
        seen |= field;
        match field {
            F_TASK => match p.next()? {
                Event::Str(s) => {
                    if s.is_empty() {
                        return Err(WireError::MissingTask);
                    }
                    task.push_str(s);
                }
                _ => return Err(WireError::BadFieldType),
            },
            F_TEXT_A => {
                match p.next()? {
                    Event::ArrBegin => {}
                    _ => return Err(WireError::BadFieldType),
                }
                read_token_items(&mut p, seq_a, limits.max_tokens)?;
            }
            _ => match p.next()? {
                Event::Null => {}
                Event::ArrBegin => {
                    read_token_items(&mut p, seq_b, limits.max_tokens)?;
                    *has_b = true;
                }
                _ => return Err(WireError::BadFieldType),
            },
        }
    }
    // the object closed at top level; only End (or trailing garbage,
    // which the parser types as an error) can follow
    match p.next()? {
        Event::End => {}
        _ => return Err(WireError::Json(JsonError::TrailingData)),
    }
    if seen & F_TASK == 0 {
        return Err(WireError::MissingTask);
    }
    if seen & F_TEXT_A == 0 {
        return Err(WireError::MissingText);
    }
    Ok(())
}

/// Read number events into `out` until the matching `ArrEnd`.
fn read_token_items(
    p: &mut PullParser<'_, '_>,
    out: &mut Vec<i32>,
    max: usize,
) -> Result<(), WireError> {
    loop {
        match p.next()? {
            Event::ArrEnd => return Ok(()),
            Event::Num(v) => {
                if v.fract() != 0.0 {
                    return Err(WireError::TokenNotAnInteger);
                }
                if v < i32::MIN as f64 || v > i32::MAX as f64 {
                    return Err(WireError::TokenOutOfRange);
                }
                if out.len() >= max {
                    return Err(WireError::TooManyTokens);
                }
                out.push(v as i32);
            }
            _ => return Err(WireError::BadFieldType),
        }
    }
}

/// Per-connection response accumulator: one reusable body scratch, one
/// output buffer a whole pipelined wave is flushed from with a single
/// `write_all`. Both buffers hold their high-water capacity, so steady
/// traffic serializes responses with zero allocation.
#[derive(Debug, Default)]
pub struct ResponseBuf {
    out: Vec<u8>,
    body: Vec<u8>,
}

impl ResponseBuf {
    /// The accumulated wire bytes (one or more framed responses).
    pub fn bytes(&self) -> &[u8] {
        &self.out
    }

    /// Drop the accumulated bytes, keeping capacity.
    pub fn clear(&mut self) {
        self.out.clear();
    }

    /// Append a response whose JSON body is written by `f` into the
    /// reusable body scratch.
    pub fn push_json(
        &mut self,
        status: u16,
        reason: &str,
        close: bool,
        f: impl FnOnce(&mut Vec<u8>),
    ) {
        self.body.clear();
        f(&mut self.body);
        self.finish(status, reason, close);
    }

    /// Append the 200 reply for one served request. Logits use Rust's
    /// shortest round-trip float repr: parsing the decimal back as `f64`
    /// and narrowing to `f32` reproduces the exact bits (the
    /// wire-vs-in-process equality test relies on this).
    pub fn push_reply(&mut self, r: &DirectReply<'_>) {
        use std::io::Write as _;
        self.body.clear();
        let _ = write!(self.body, "{{\"id\":{},\"task\":\"", r.id);
        write_json_escaped(&mut self.body, r.task);
        let _ = write!(
            self.body,
            "\",\"label\":{},\"latency_us\":{},\"logits\":[",
            r.label,
            (r.latency_s * 1e6) as u64
        );
        for (i, v) in r.logits.iter().enumerate() {
            if i > 0 {
                self.body.push(b',');
            }
            let _ = write!(self.body, "{v}");
        }
        self.body.extend_from_slice(b"]}");
        self.finish(200, "OK", false);
    }

    /// Append the typed error response for `e` (closing variants carry
    /// `Connection: close`; throttle responses carry `Retry-After` and a
    /// machine-readable `retry_after_ms` body field).
    pub fn push_error(&mut self, e: WireError) {
        use std::io::Write as _;
        let (status, reason) = e.status();
        self.body.clear();
        let _ = write!(
            self.body,
            "{{\"error\":\"{}\",\"message\":\"{}\"",
            e.code(),
            e.message()
        );
        let retry_after_s = match e {
            WireError::TenantThrottled(ms) => {
                let _ = write!(self.body, ",\"retry_after_ms\":{ms}");
                // Retry-After is whole seconds; round up so honoring it
                // always lands after the bucket refills
                Some((ms as u64).div_ceil(1000).max(1))
            }
            _ => None,
        };
        self.body.push(b'}');
        self.finish_with(status, reason, e.fatal(), retry_after_s);
    }

    fn finish(&mut self, status: u16, reason: &str, close: bool) {
        self.finish_with(status, reason, close, None);
    }

    fn finish_with(
        &mut self,
        status: u16,
        reason: &str,
        close: bool,
        retry_after_s: Option<u64>,
    ) {
        use std::io::Write as _;
        let _ = write!(
            self.out,
            "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\n",
            self.body.len()
        );
        if let Some(s) = retry_after_s {
            let _ = write!(self.out, "Retry-After: {s}\r\n");
        }
        if close {
            self.out.extend_from_slice(b"Connection: close\r\n");
        }
        self.out.extend_from_slice(b"\r\n");
        self.out.extend_from_slice(&self.body);
    }
}

/// Write `s` as JSON string content: `"`/`\`/control bytes escaped,
/// multi-byte UTF-8 passed through raw (valid JSON either way).
pub fn write_json_escaped(out: &mut Vec<u8>, s: &str) {
    use std::io::Write as _;
    for &c in s.as_bytes() {
        match c {
            b'"' => out.extend_from_slice(b"\\\""),
            b'\\' => out.extend_from_slice(b"\\\\"),
            0x08 => out.extend_from_slice(b"\\b"),
            0x0C => out.extend_from_slice(b"\\f"),
            b'\n' => out.extend_from_slice(b"\\n"),
            b'\r' => out.extend_from_slice(b"\\r"),
            b'\t' => out.extend_from_slice(b"\\t"),
            c if c < 0x20 => {
                let _ = write!(out, "\\u{c:04x}");
            }
            c => out.push(c),
        }
    }
}

// ---- byte-scanning helpers ----------------------------------------------

fn find_subslice(hay: &[u8], needle: &[u8]) -> Option<usize> {
    hay.windows(needle.len()).position(|w| w == needle)
}

fn trim_ascii(mut b: &[u8]) -> &[u8] {
    while let Some((&c, rest)) = b.split_first() {
        if c == b' ' || c == b'\t' {
            b = rest;
        } else {
            break;
        }
    }
    while let Some((&c, rest)) = b.split_last() {
        if c == b' ' || c == b'\t' {
            b = rest;
        } else {
            break;
        }
    }
    b
}

fn parse_decimal(v: &[u8]) -> Option<usize> {
    if v.is_empty() {
        return None;
    }
    let mut n: usize = 0;
    for &c in v {
        if !c.is_ascii_digit() {
            return None;
        }
        n = n.checked_mul(10)?.checked_add((c - b'0') as usize)?;
    }
    Some(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    const L: WireLimits = WireLimits {
        max_head: 256,
        max_body: 1024,
        max_tokens: 8,
        idle_timeout_ms: 0,
        progress_timeout_ms: 0,
    };

    #[test]
    fn head_parses_incrementally() {
        let full = b"POST /infer HTTP/1.1\r\nContent-Length: 12\r\n\r\n";
        for cut in 0..full.len() {
            assert!(
                parse_head(&full[..cut], &L).unwrap().is_none(),
                "cut at {cut} must ask for more bytes"
            );
        }
        let h = parse_head(full, &L).unwrap().unwrap();
        assert_eq!(h.method, Method::Post);
        assert_eq!(h.route, Route::Infer);
        assert_eq!(h.content_length, 12);
        assert_eq!(h.head_len, full.len());
        assert!(h.keep_alive);
    }

    #[test]
    fn head_routes_methods_and_connection() {
        let h = parse_head(b"GET /stats HTTP/1.1\r\nConnection: close\r\n\r\n", &L)
            .unwrap()
            .unwrap();
        assert_eq!((h.route, h.method), (Route::Stats, Method::Get));
        assert!(!h.keep_alive);
        let h = parse_head(b"GET /healthz HTTP/1.0\r\n\r\n", &L).unwrap().unwrap();
        assert_eq!(h.route, Route::Health);
        assert!(!h.keep_alive, "HTTP/1.0 defaults to close");
        let h = parse_head(b"POST /shutdown HTTP/1.1\r\n\r\n", &L).unwrap().unwrap();
        assert_eq!(h.route, Route::Shutdown);
        assert_eq!(h.content_length, 0, "missing content-length means empty body");
        let h = parse_head(b"PUT /nope HTTP/1.1\r\n\r\n", &L).unwrap().unwrap();
        assert_eq!((h.route, h.method), (Route::Unknown, Method::Other));
    }

    #[test]
    fn head_rejections_are_typed() {
        let cases: &[(&[u8], WireError)] = &[
            (b"garbage\r\n\r\n", WireError::BadRequestLine),
            (b"GET / HTTP/0.9\r\n\r\n", WireError::BadVersion),
            (b"GET / HTTP/1.1\r\nno-colon-here\r\n\r\n", WireError::BadHeader),
            (b"GET / HTTP/1.1\r\nContent-Length: -5\r\n\r\n", WireError::BadContentLength),
            (b"GET / HTTP/1.1\r\nContent-Length: 2x\r\n\r\n", WireError::BadContentLength),
            (
                b"POST /infer HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n",
                WireError::UnsupportedTransferEncoding,
            ),
            (
                b"POST /infer HTTP/1.1\r\nContent-Length: 99999\r\n\r\n",
                WireError::BodyTooLarge,
            ),
        ];
        for (input, want) in cases {
            assert_eq!(
                parse_head(input, &L).err(),
                Some(*want),
                "{:?}",
                String::from_utf8_lossy(input)
            );
        }
        // oversized heads reject with or without the terminator in sight
        let mut big = b"GET / HTTP/1.1\r\n".to_vec();
        big.extend(std::iter::repeat(b'a').take(300));
        assert_eq!(parse_head(&big, &L).err(), Some(WireError::HeadTooLarge));
        let mut terminated = b"GET / HTTP/1.1\r\nX: ".to_vec();
        terminated.extend(std::iter::repeat(b'a').take(300));
        terminated.extend_from_slice(b"\r\n\r\n");
        assert_eq!(parse_head(&terminated, &L).err(), Some(WireError::HeadTooLarge));
    }

    #[test]
    fn decode_fills_scratch_and_reuses_it() {
        let mut s = RequestScratch::default();
        decode_request(br#"{"task":"sst2","text_a":[5,6,7]}"#, &L, &mut s).unwrap();
        assert_eq!(s.task, "sst2");
        assert_eq!(s.seq_a, vec![5, 6, 7]);
        assert_eq!(s.text_b(), None);

        decode_request(
            br#"{"text_b":[9],"task":"rte","text_a":[1]}"#,
            &L,
            &mut s,
        )
        .unwrap();
        assert_eq!(s.task, "rte");
        assert_eq!(s.seq_a, vec![1]);
        assert_eq!(s.text_b(), Some(&[9][..]));

        // null and empty-array text_b are distinct
        decode_request(br#"{"task":"a","text_a":[],"text_b":null}"#, &L, &mut s).unwrap();
        assert_eq!(s.text_b(), None);
        decode_request(br#"{"task":"a","text_a":[],"text_b":[]}"#, &L, &mut s).unwrap();
        assert_eq!(s.text_b(), Some(&[][..]));

        // escaped task names land through the parser scratch
        decode_request(br#"{"task":"sst2","text_a":[4]}"#, &L, &mut s).unwrap();
        assert_eq!(s.task, "sst2");
    }

    #[test]
    fn decode_rejections_are_typed() {
        let cases: &[(&[u8], WireError)] = &[
            (b"[1,2]", WireError::NotAnObject),
            (b"\"s\"", WireError::NotAnObject),
            (br#"{"task":"a","task":"b","text_a":[]}"#, WireError::DuplicateField),
            (br#"{"task":"a","text_a":[],"extra":1}"#, WireError::UnknownField),
            (br#"{"task":7,"text_a":[]}"#, WireError::BadFieldType),
            (br#"{"task":"a","text_a":[[1]]}"#, WireError::BadFieldType),
            (br#"{"task":"a","text_a":{"x":1}}"#, WireError::BadFieldType),
            (br#"{"task":"a","text_a":[1,"x"]}"#, WireError::BadFieldType),
            (br#"{"task":"","text_a":[]}"#, WireError::MissingTask),
            (br#"{"text_a":[1]}"#, WireError::MissingTask),
            (br#"{"task":"a"}"#, WireError::MissingText),
            (br#"{"task":"a","text_a":[1.5]}"#, WireError::TokenNotAnInteger),
            (
                br#"{"task":"a","text_a":[3000000000]}"#,
                WireError::TokenOutOfRange,
            ),
            (
                br#"{"task":"a","text_a":[1,2,3,4,5,6,7,8,9]}"#,
                WireError::TooManyTokens,
            ),
            (br#"{"task":"a","text_a":[1]}{}"#, WireError::Json(JsonError::TrailingData)),
            (br#"{"task":"a","text_a":[1]"#, WireError::Json(JsonError::UnexpectedEof)),
            (br#"{"task":"a","text_a":[1e999]}"#, WireError::Json(JsonError::NonFiniteNumber)),
        ];
        let mut s = RequestScratch::default();
        for (body, want) in cases {
            assert_eq!(
                decode_request(body, &L, &mut s).err(),
                Some(*want),
                "{:?}",
                String::from_utf8_lossy(body)
            );
        }
    }

    #[test]
    fn response_buf_frames_and_accumulates() {
        let mut r = ResponseBuf::default();
        r.push_json(200, "OK", false, |b| b.extend_from_slice(b"{\"ok\":true}"));
        r.push_error(WireError::UnknownTask);
        let text = String::from_utf8(r.bytes().to_vec()).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: 11\r\n"), "{text}");
        assert!(text.contains("HTTP/1.1 404 Not Found\r\n"), "{text}");
        assert!(text.contains("\"error\":\"unknown-task\""), "{text}");
        assert!(!text.contains("Connection: close"), "non-fatal errors keep alive");
        r.clear();
        r.push_error(WireError::TruncatedBody);
        let text = String::from_utf8(r.bytes().to_vec()).unwrap();
        assert!(text.contains("Connection: close"), "fatal errors close: {text}");
        // declared lengths frame the stream exactly
        let body_at = text.find("\r\n\r\n").unwrap() + 4;
        let cl: usize = text
            .lines()
            .find(|l| l.starts_with("Content-Length:"))
            .and_then(|l| l.split(':').nth(1))
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        assert_eq!(text.len() - body_at, cl);
    }

    #[test]
    fn throttle_responses_carry_retry_after() {
        let mut r = ResponseBuf::default();
        r.push_error(WireError::TenantThrottled(2400));
        let text = String::from_utf8(r.bytes().to_vec()).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        // 2400 ms rounds UP to 3 s: honoring the header always lands
        // after the bucket refills
        assert!(text.contains("Retry-After: 3\r\n"), "{text}");
        assert!(text.contains("\"error\":\"tenant-throttled\""), "{text}");
        assert!(text.contains("\"retry_after_ms\":2400"), "{text}");
        assert!(!text.contains("Connection: close"), "throttles keep the connection");
        // sub-second waits still advertise at least one whole second
        r.clear();
        r.push_error(WireError::TenantThrottled(1));
        let text = String::from_utf8(r.bytes().to_vec()).unwrap();
        assert!(text.contains("Retry-After: 1\r\n"), "{text}");
        // shed responses are plain 503s
        r.clear();
        r.push_error(WireError::QueueFull);
        let text = String::from_utf8(r.bytes().to_vec()).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"), "{text}");
        assert!(text.contains("\"error\":\"queue-full\""), "{text}");
        assert_eq!(WireError::QueueFull.bucket(), RejectKind::Shed);
        assert_eq!(WireError::TenantThrottled(7).bucket(), RejectKind::Throttle);
        assert_eq!(WireError::ShuttingDown.bucket(), RejectKind::Shed);
        assert!(WireError::ProgressTimeout.fatal(), "slowloris closes the connection");
        assert!(!WireError::QueueFull.fatal(), "shed keeps the framing intact");
    }

    #[test]
    fn json_escaping_covers_specials() {
        let mut out = Vec::new();
        write_json_escaped(&mut out, "a\"b\\c\nd\u{1}é");
        assert_eq!(out, b"a\\\"b\\\\c\\nd\\u0001\xc3\xa9".to_vec());
    }
}
