//! Runtime layer: PJRT client wrapper, artifact manifest, tensors.
//!
//! The Rust side of the AOT bridge. `Engine` loads `artifacts/*.hlo.txt`
//! (lowered once by `python -m compile.aot`), compiles each on the PJRT CPU
//! client, and executes them from the coordinator hot path. Python never
//! runs at this point.

pub mod engine;
pub mod manifest;
pub mod tensor;

pub use engine::{Engine, EngineStats};
pub use manifest::{ArtifactInfo, ArtifactKind, InitKind, Manifest, ModelInfo, ParamSpec};
pub use tensor::{IntTensor, Tensor};
