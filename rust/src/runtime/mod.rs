//! Runtime layer: backends, artifact manifest, tensors, compute kernels,
//! and the multi-tenant serve path.
//!
//! An [`Engine`] pairs a [`Manifest`] (model inventory + artifact I/O
//! contracts) with a [`Backend`] that executes artifacts:
//!
//! * [`NativeBackend`] (default) — pure-Rust forward/backward evaluation of
//!   the transformer and every gradient group, mirroring the JAX reference
//!   semantics in `python/compile/kernels/ref.py`. No artifacts directory,
//!   Python or network required; `Manifest::builtin()` supplies the model
//!   inventory.
//! * `XlaBackend` (`--features xla`) — the PJRT path over HLO-text
//!   artifacts lowered once by `python -m compile.aot`.
//!
//! Besides the artifact path, the runtime exposes a forward-only serve
//! entry ([`Backend::infer`] / [`Engine::infer`]) and the serving layer
//! built on it ([`serve::ServeSession`]): one packed frozen backbone, a
//! bank of per-task Hadamard adapters, cross-task micro-batching. In
//! front of the session sits the wire ingress layer ([`wire`] for the
//! std-only HTTP/1.1 + pull-JSON request grammar, [`server`] for the
//! socket loop): a `serve-http` front door that multiplexes many
//! nonblocking connections into the single-owner session — waves may
//! mix rows from several connections — and whose request path touches
//! the heap zero times after warmup, connection churn included. Overload never falls over silently:
//! [`admit`] supplies per-tenant token buckets and fair-share weights,
//! the session runs a bounded queue with deadline batching, and
//! [`faultpoint`] (non-default `fault-inject` feature) lets the test
//! suite force each failure mode and assert the typed degradation. See
//! `ARCHITECTURE.md` at the repo root for the layer-by-layer design.

pub mod admit;
pub mod backend;
pub mod bankstore;
pub mod engine;
pub mod faultpoint;
pub mod inventory;
pub mod kernels;
pub mod manifest;
pub mod native;
pub mod pool;
pub mod serve;
pub mod server;
pub mod tensor;
pub mod wire;
pub mod workspace;
#[cfg(feature = "xla")]
pub mod xla_backend;

pub use backend::{Backend, BatchAdapters, DeviceTensor, InferBatch, InferOut};
pub use bankstore::{
    BankBuilder, BankDamage, BankGeometry, BankReader, BankSummary, CompactSummary, DamageKind,
    ScrubReport,
};
pub use engine::{Engine, EngineStats};
pub use kernels::PackedMat;
pub use manifest::{ArtifactInfo, ArtifactKind, InitKind, Manifest, ModelInfo, ParamSpec};
pub use native::NativeBackend;
pub use pool::{Pool, PoolStats};
pub use admit::AdmissionController;
pub use serve::{
    synthetic_adapters, synthetic_tenant, AdapterBank, BankStats, DirectReply, ResolveMiss,
    ServePolicy, ServeReply, ServeRequest, ServeSession, ServeStats, SubmitError, TaskAdapter,
};
pub use server::{spawn_synthetic_server, ServerStats, SpawnOpts, WireServer};
pub use tensor::{IntTensor, Tensor};
pub use wire::{RequestScratch, ResponseBuf, WireError, WireLimits};
pub use workspace::{Workspace, WorkspaceStats};
#[cfg(feature = "xla")]
pub use xla_backend::XlaBackend;
