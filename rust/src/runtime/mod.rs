//! Runtime layer: backends, artifact manifest, tensors, compute kernels,
//! and the multi-tenant serve path.
//!
//! An [`Engine`] pairs a [`Manifest`] (model inventory + artifact I/O
//! contracts) with a [`Backend`] that executes artifacts:
//!
//! * [`NativeBackend`] (default) — pure-Rust forward/backward evaluation of
//!   the transformer and every gradient group, mirroring the JAX reference
//!   semantics in `python/compile/kernels/ref.py`. No artifacts directory,
//!   Python or network required; `Manifest::builtin()` supplies the model
//!   inventory.
//! * `XlaBackend` (`--features xla`) — the PJRT path over HLO-text
//!   artifacts lowered once by `python -m compile.aot`.
//!
//! Besides the artifact path, the runtime exposes a forward-only serve
//! entry ([`Backend::infer`] / [`Engine::infer`]) and the serving layer
//! built on it ([`serve::ServeSession`]): one packed frozen backbone, a
//! bank of per-task Hadamard adapters, cross-task micro-batching. See
//! `ARCHITECTURE.md` at the repo root for the layer-by-layer design.

pub mod backend;
pub mod engine;
pub mod inventory;
pub mod kernels;
pub mod manifest;
pub mod native;
pub mod pool;
pub mod serve;
pub mod tensor;
pub mod workspace;
#[cfg(feature = "xla")]
pub mod xla_backend;

pub use backend::{Backend, BatchAdapters, DeviceTensor, InferBatch, InferOut};
pub use engine::{Engine, EngineStats};
pub use kernels::PackedMat;
pub use manifest::{ArtifactInfo, ArtifactKind, InitKind, Manifest, ModelInfo, ParamSpec};
pub use native::NativeBackend;
pub use pool::{Pool, PoolStats};
pub use serve::{
    AdapterBank, ServeReply, ServeRequest, ServeSession, ServeStats, TaskAdapter,
};
pub use tensor::{IntTensor, Tensor};
pub use workspace::{Workspace, WorkspaceStats};
#[cfg(feature = "xla")]
pub use xla_backend::XlaBackend;
