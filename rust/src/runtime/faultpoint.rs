//! Named fault-injection points for the serve path, compiled under the
//! non-default `fault-inject` feature.
//!
//! Robustness claims need a way to *make* the bad thing happen: a full
//! queue, a tenant suddenly over its rate, a connection torn mid-reply,
//! a panic in the middle of a wave. Each site on the serve/wire path
//! calls [`fire`] with a stable point name; with the feature off the
//! call compiles to `false` and the branch folds away, so the production
//! binary carries no fault-injection code at all. With the feature on
//! (tests and benches build with it via the crate's self
//! dev-dependency), a point fires when armed either
//!
//! * programmatically — [`arm`]`("serve.mid-wave-panic", 1)` fires the
//!   point on its next `n` hits, or [`arm_always`] forever; or
//! * by environment — `HADAPT_FAULT="point=3;other=always"` parsed on
//!   first use, for driving the release binary from a harness.
//!
//! Points in the tree:
//!
//! | point                  | effect when fired                           |
//! |------------------------|---------------------------------------------|
//! | `serve.queue-full`     | submit rejects as if the queue were full    |
//! | `admit.slow-tenant`    | submit rejects as if the bucket were empty  |
//! | `serve.mid-wave-panic` | the wave panics before inference            |
//! | `wire.torn-reply`      | the reply write stops halfway, then drops   |
//! | `wire.accept-fail`     | the accept sheds as if the slot table were full |
//! | `conn.slow-reader`     | that connection reads at most 1 byte per ms |
//! | `bank.short-write`     | a bank write lands half its bytes, then fails |
//! | `bank.fsync-fail`      | a bank `fsync` reports failure              |
//! | `bank.rename-fail`     | the atomic rename commit point fails        |
//! | `bank.compact-crash`   | compaction dies mid-rewrite (partial `.tmp`) |
//!
//! The table is process-global and mutex-guarded; integration tests that
//! arm points run in their own test binary (`tests/fault_injection.rs`)
//! so armed state cannot leak into unrelated parallel tests.

#[cfg(feature = "fault-inject")]
mod imp {
    use std::sync::{Mutex, OnceLock};

    /// Remaining fire count per armed point; `i64::MIN` = always.
    type Table = Vec<(String, i64)>;

    fn table() -> &'static Mutex<Table> {
        static TABLE: OnceLock<Mutex<Table>> = OnceLock::new();
        TABLE.get_or_init(|| {
            let mut t = Table::new();
            if let Ok(spec) = std::env::var("HADAPT_FAULT") {
                for part in spec.split(';').filter(|p| !p.is_empty()) {
                    let (name, count) = part.split_once('=').unwrap_or((part, "1"));
                    let n = if count == "always" {
                        i64::MIN
                    } else {
                        count.parse().unwrap_or(1)
                    };
                    t.push((name.trim().to_string(), n));
                }
            }
            Mutex::new(t)
        })
    }

    /// Whether `point` fires now (consuming one armed hit).
    pub fn fire(point: &str) -> bool {
        let mut t = table().lock().unwrap();
        match t.iter_mut().find(|(n, _)| n == point) {
            Some((_, n)) if *n == i64::MIN => true,
            Some((_, n)) if *n > 0 => {
                *n -= 1;
                true
            }
            _ => false,
        }
    }

    /// Arm `point` to fire on its next `count` hits.
    pub fn arm(point: &str, count: i64) {
        let mut t = table().lock().unwrap();
        match t.iter_mut().find(|(n, _)| n == point) {
            Some((_, n)) => *n = count,
            None => t.push((point.to_string(), count)),
        }
    }

    /// Arm `point` to fire on every hit until [`reset`].
    pub fn arm_always(point: &str) {
        arm(point, i64::MIN);
    }

    /// Disarm every point (including ones armed via `HADAPT_FAULT`).
    pub fn reset() {
        table().lock().unwrap().clear();
    }
}

#[cfg(feature = "fault-inject")]
pub use imp::{arm, arm_always, fire, reset};

/// Whether `point` fires now. With `fault-inject` off this is a
/// constant `false` the optimizer deletes along with the guarded branch.
#[cfg(not(feature = "fault-inject"))]
#[inline(always)]
pub fn fire(_point: &str) -> bool {
    false
}

/// No-op without the `fault-inject` feature.
#[cfg(not(feature = "fault-inject"))]
pub fn arm(_point: &str, _count: i64) {}

/// No-op without the `fault-inject` feature.
#[cfg(not(feature = "fault-inject"))]
pub fn arm_always(_point: &str) {}

/// No-op without the `fault-inject` feature.
#[cfg(not(feature = "fault-inject"))]
pub fn reset() {}

#[cfg(all(test, feature = "fault-inject"))]
mod tests {
    // The armed-point table is process-global, so these unit tests only
    // touch names no serve-path site ever checks — firing them cannot
    // perturb a server test running in a sibling thread.
    use super::*;

    #[test]
    fn counted_arms_fire_exactly_n_times() {
        arm("test.counted-point", 2);
        assert!(fire("test.counted-point"));
        assert!(fire("test.counted-point"));
        assert!(!fire("test.counted-point"));
        assert!(!fire("test.never-armed-point"));
    }

    #[test]
    fn always_fires_until_rearmed_to_zero() {
        arm_always("test.always-point");
        for _ in 0..10 {
            assert!(fire("test.always-point"));
        }
        arm("test.always-point", 0);
        assert!(!fire("test.always-point"));
    }
}
