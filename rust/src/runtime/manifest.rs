//! The AOT manifest: the single contract between the Python compile path and
//! the Rust runtime.
//!
//! `python -m compile.aot` writes `artifacts/manifest.json` recording batch
//! geometry, each model's parameter inventory (canonical order, shapes, init
//! kinds, gradient-group membership) and each artifact's input/output lists.
//! Nothing else couples the layers.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// Parameter initialization kind (mirrors `model.param_specs` in Python).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InitKind {
    /// Truncated-normal initialization.
    Normal,
    /// Zero initialization.
    Zeros,
    /// Ones initialization (LayerNorm gains, identity adapters).
    Ones,
}

/// One model parameter: canonical name, shape, init kind.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    /// Canonical parameter name.
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Initialization kind.
    pub init: InitKind,
}

impl ParamSpec {
    /// Total scalars in the tensor.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Model-level metadata (one per size: tiny/base/large).
#[derive(Debug, Clone)]
pub struct ModelInfo {
    /// Model name ("tiny"/"base"/"large").
    pub name: String,
    /// Encoder layer count.
    pub layers: usize,
    /// Hidden width.
    pub hidden: usize,
    /// Attention heads.
    pub heads: usize,
    /// FFN inner width.
    pub ffn: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Maximum sequence length.
    pub max_len: usize,
    /// LoRA scaling numerator (alpha; scale = alpha / rank).
    pub lora_alpha: f32,
    /// Parameter inventory in canonical order.
    pub params: Vec<ParamSpec>,
    /// name -> index in `params` (canonical order).
    pub index: HashMap<String, usize>,
    /// gradient group -> member parameter names (canonical order).
    pub groups: HashMap<String, Vec<String>>,
    /// parameters trained during MLM pre-training.
    pub mlm_group: Vec<String>,
}

impl ModelInfo {
    /// Canonical index of a parameter name.
    pub fn param_index(&self, name: &str) -> Result<usize> {
        self.index
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("unknown parameter '{name}'"))
    }

    /// Total scalars across all parameters.
    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Total scalars in the vanilla PLM (the paper's denominator for
    /// "0.033% of full fine-tuning"): the `full` group.
    pub fn backbone_params(&self) -> usize {
        let full = &self.groups["full"];
        full.iter()
            .map(|n| self.params[self.index[n]].numel())
            .sum()
    }

    /// Member names of a gradient group.
    pub fn group(&self, name: &str) -> Result<&[String]> {
        self.groups
            .get(name)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow!("unknown gradient group '{name}'"))
    }
}

/// Artifact kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Probe-carrying forward pass (logits + figure probes).
    Forward,
    /// Loss + per-group gradients for fine-tuning.
    Train,
    /// MLM pre-training step.
    Mlm,
}

/// One HLO artifact: file, model, entry-point metadata and I/O lists.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    /// Artifact name (also the manifest key).
    pub name: String,
    /// HLO file the XLA backend compiles (unused natively).
    pub file: PathBuf,
    /// Model the artifact runs.
    pub model: String,
    /// What the artifact computes.
    pub kind: ArtifactKind,
    /// "cls" | "reg" for train artifacts.
    pub loss: Option<String>,
    /// gradient group for train artifacts.
    pub group: Option<String>,
    /// batch tensor names appended after the parameters, in order.
    pub batch_inputs: Vec<String>,
    /// output names: "loss"/"logits"/... and "grad:<param>" entries.
    pub outputs: Vec<String>,
}

impl ArtifactInfo {
    /// Names of parameters receiving gradients, in output order.
    pub fn grad_params(&self) -> Vec<&str> {
        self.outputs
            .iter()
            .filter_map(|o| o.strip_prefix("grad:"))
            .collect()
    }
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Examples per batch, baked into every artifact.
    pub batch: usize,
    /// Tokens per example.
    pub seq_len: usize,
    /// Global classifier-head width.
    pub num_classes: usize,
    /// Model inventory by name.
    pub models: HashMap<String, ModelInfo>,
    /// Artifact inventory by name.
    pub artifacts: HashMap<String, ArtifactInfo>,
    /// Artifacts directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts` first)"))?;
        let root = json::parse(&text).context("parsing manifest.json")?;
        Self::from_json(&root, dir)
    }

    /// Parse a manifest from its JSON root.
    pub fn from_json(root: &Json, dir: PathBuf) -> Result<Self> {
        let mut models = HashMap::new();
        for (name, m) in root.get("models")?.as_obj()?.iter() {
            let cfg = m.get("config")?;
            let mut params = Vec::new();
            let mut index = HashMap::new();
            for p in m.get("params")?.as_arr()? {
                let pname = p.get("name")?.as_str()?.to_string();
                let shape = p
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|v| v.as_usize())
                    .collect::<Result<Vec<_>>>()?;
                let init = match p.get("init")?.as_str()? {
                    "normal" => InitKind::Normal,
                    "zeros" => InitKind::Zeros,
                    "ones" => InitKind::Ones,
                    other => bail!("unknown init kind '{other}'"),
                };
                index.insert(pname.clone(), params.len());
                params.push(ParamSpec { name: pname, shape, init });
            }
            let mut groups = HashMap::new();
            for (g, list) in m.get("groups")?.as_obj()?.iter() {
                groups.insert(g.clone(), list.str_vec()?);
            }
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    layers: cfg.get("layers")?.as_usize()?,
                    hidden: cfg.get("hidden")?.as_usize()?,
                    heads: cfg.get("heads")?.as_usize()?,
                    ffn: cfg.get("ffn")?.as_usize()?,
                    vocab: cfg.get("vocab")?.as_usize()?,
                    max_len: cfg.get("max_len")?.as_usize()?,
                    lora_alpha: cfg
                        .opt("lora_alpha")
                        .and_then(|v| v.as_f64().ok())
                        .unwrap_or(8.0) as f32,
                    params,
                    index,
                    groups,
                    mlm_group: m.get("mlm_group")?.str_vec()?,
                },
            );
        }

        let mut artifacts = HashMap::new();
        for (name, a) in root.get("artifacts")?.as_obj()?.iter() {
            let kind = match a.get("kind")?.as_str()? {
                "fwd" => ArtifactKind::Forward,
                "train" => ArtifactKind::Train,
                "mlm" => ArtifactKind::Mlm,
                other => bail!("unknown artifact kind '{other}'"),
            };
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name: name.clone(),
                    file: dir.join(a.get("file")?.as_str()?),
                    model: a.get("model")?.as_str()?.to_string(),
                    kind,
                    loss: match a.get("loss")? {
                        Json::Str(s) => Some(s.clone()),
                        _ => None,
                    },
                    group: match a.get("group")? {
                        Json::Str(s) => Some(s.clone()),
                        _ => None,
                    },
                    batch_inputs: a.get("batch_inputs")?.str_vec()?,
                    outputs: a.get("outputs")?.str_vec()?,
                },
            );
        }

        Ok(Manifest {
            batch: root.get("batch")?.as_usize()?,
            seq_len: root.get("seq_len")?.as_usize()?,
            num_classes: root.get("num_classes")?.as_usize()?,
            models,
            artifacts,
            dir,
        })
    }

    /// Look up a model by name.
    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model '{name}' not in manifest (have: {:?})",
                                   self.models.keys().collect::<Vec<_>>()))
    }

    /// Look up an artifact by name.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    /// Conventional artifact names.
    pub fn fwd_name(model: &str) -> String {
        format!("fwd_{model}")
    }

    /// Conventional train-artifact name.
    pub fn train_name(loss: &str, group: &str, model: &str) -> String {
        format!("train_{loss}_{group}_{model}")
    }

    /// Conventional MLM-artifact name.
    pub fn mlm_name(model: &str) -> String {
        format!("mlm_{model}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_manifest_json() -> &'static str {
        r#"{
          "version": 1, "batch": 2, "seq_len": 4, "num_classes": 3,
          "models": {
            "t": {
              "config": {"layers": 1, "hidden": 8, "heads": 2, "ffn": 16,
                          "vocab": 32, "max_len": 4, "head_dim": 4},
              "params": [
                {"name": "a.weight", "shape": [8, 8], "init": "normal"},
                {"name": "a.bias", "shape": [8], "init": "zeros"},
                {"name": "n.weight", "shape": [8], "init": "ones"}
              ],
              "groups": {"full": ["a.weight", "a.bias", "n.weight"],
                          "head": ["a.bias"]},
              "mlm_group": ["a.weight"]
            }
          },
          "artifacts": {
            "train_cls_head_t": {
              "file": "train_cls_head_t.hlo.txt", "model": "t",
              "kind": "train", "loss": "cls", "group": "head",
              "batch_inputs": ["tokens", "type_ids"],
              "outputs": ["loss", "grad:a.bias"]
            }
          }
        }"#
    }

    #[test]
    fn parses_mini_manifest() {
        let root = json::parse(mini_manifest_json()).unwrap();
        let m = Manifest::from_json(&root, PathBuf::from("/tmp/x")).unwrap();
        assert_eq!(m.batch, 2);
        let model = m.model("t").unwrap();
        assert_eq!(model.params.len(), 3);
        assert_eq!(model.total_params(), 64 + 8 + 8);
        assert_eq!(model.param_index("n.weight").unwrap(), 2);
        let a = m.artifact("train_cls_head_t").unwrap();
        assert_eq!(a.kind, ArtifactKind::Train);
        assert_eq!(a.grad_params(), vec!["a.bias"]);
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn artifact_names() {
        assert_eq!(Manifest::fwd_name("base"), "fwd_base");
        assert_eq!(Manifest::train_name("cls", "hadamard", "large"),
                   "train_cls_hadamard_large");
        assert_eq!(Manifest::mlm_name("tiny"), "mlm_tiny");
    }
}
