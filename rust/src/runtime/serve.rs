//! Multi-tenant, forward-only adapter serving.
//!
//! The paper's headline number — a competitive adapter at ~0.033% of
//! full fine-tuning parameters — makes one deployment story uniquely
//! cheap: a **single frozen backbone serving many tasks**, with per-task
//! Hadamard weight/bias vectors swapped per request. This module is that
//! deployment story on the native backend:
//!
//! * a [`TaskAdapter`] is everything task-specific the Hadamard method
//!   trains, distilled out of a tuned [`ParamStore`]: per-layer Hadamard
//!   `(W, B)` vectors, the per-layer output-LayerNorm affine pair (the
//!   paper's `N` module) and the stage-1-trained pooler + classifier
//!   head — tens of KB per task, orders of magnitude below the backbone;
//! * an [`AdapterBank`] holds named task adapters, registered and
//!   replaced at runtime by plain vector copies. The backbone's packed
//!   panels are keyed by the *frozen* parameters only, so bank updates
//!   never touch the pack cache (`Engine::pack_stats` stays frozen —
//!   task switching costs vector-copy time, not repack time);
//! * a [`ServeSession`] owns the uploaded backbone, queues
//!   classification requests tagged by task, **micro-batches requests
//!   across tasks** (same backbone, per-example adapter rows gathered
//!   from the bank), runs the inference-only forward
//!   ([`crate::runtime::Backend::infer`] — no training slabs, no taps,
//!   no probes) and returns per-request logits, a label and latency.
//!
//! Because every kernel on the forward path is row/example-local, a
//! request's logits are **bit-identical** whether it is served alone or
//! inside a mixed-task micro-batch (`tests/serve_path.rs` pins this).
//! Batches are padded to a fixed `max_batch` geometry, so the
//! steady-state serve loop inherits the training path's zero-allocation
//! and zero-spawn contracts (`Engine::arena_stats` / `pool_stats`
//! counters freeze after warm-up — also pinned by the tests and recorded
//! by `bench_runtime`'s `serve` rows).
//!
//! The session exposes two admission paths over **one bounded queue**.
//! [`ServeSession::submit`] takes an owned [`ServeRequest`] (the
//! in-process API, rich error messages). [`ServeSession::submit_borrowed`]
//! is the wire front door's entry: it encodes borrowed token slices
//! **directly into the resident queue buffers**, fails with a typed
//! `Copy` [`SubmitError`] instead of an allocating message, and its
//! replies ([`DirectReply`]) borrow the session's output buffers — end
//! to end, a served request touches the heap zero times after warmup.
//! Both paths validate, resolve (faulting cold tenants in) and admit at
//! **submit time**, so a doomed request is refused before it can occupy
//! a queue slot or poison the wave it would have ridden in.
//!
//! Overload behavior is governed by a [`ServePolicy`]: a hard queue cap
//! (typed [`SubmitError::QueueFull`] — load shed, never a silent drop),
//! per-tenant token buckets ([`super::admit`] —
//! [`SubmitError::Throttled`] with a deterministic retry hint), a flush
//! window (`window_us`: the wire loop flushes a wave at `max_batch` rows
//! *or* when the oldest queued row has waited that long, whichever
//! first), and weighted-round-robin wave assembly so one hot tenant
//! cannot starve the tail of the queue. Because every kernel is
//! row-local, WRR's reordering across waves never changes a request's
//! logits — fairness is free of the bitwise-equality contract.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::data::batcher::encode_into;
use crate::data::task_info;
use crate::model::ParamStore;
use crate::util::Rng;

use super::admit::AdmissionController;
use super::backend::{BatchAdapters, DeviceTensor, InferBatch, InferOut};
use super::bankstore::{BankReader, CompactSummary};
use super::engine::Engine;
use super::faultpoint;
use super::manifest::ModelInfo;

/// Everything task-specific the Hadamard method trains, in serve-ready
/// host form: the per-layer adapter vectors plus the task's stage-1
/// head (pooler + classifier). Tens of kilobytes per task, orders of
/// magnitude below the backbone — the paper's parameter efficiency is
/// exactly what makes this all a tenant needs to bring.
#[derive(Debug, Clone)]
pub struct TaskAdapter {
    /// Task name the adapter serves (the bank key).
    pub task: String,
    /// Active classes for this task's argmax (the global head is
    /// `classes_total` wide with a prefix class mask, exactly as in
    /// training's masked CE).
    pub classes: usize,
    /// Per-layer Hadamard weight vectors `W`, each `[hidden]`.
    pub had_w: Vec<Vec<f32>>,
    /// Per-layer Hadamard bias vectors `B`, each `[hidden]`.
    pub had_b: Vec<Vec<f32>>,
    /// Per-layer output-LayerNorm gains (`N` module), each `[hidden]`.
    pub norm_w: Vec<Vec<f32>>,
    /// Per-layer output-LayerNorm biases (`N` module), each `[hidden]`.
    pub norm_b: Vec<Vec<f32>>,
    /// Pooler weight, row-major `[hidden, hidden]` — stage 1 of the
    /// paper's pipeline trains the whole head group (pooler +
    /// classifier), and the classifier is fit against *its* pooler, so
    /// the pair travels together.
    pub pooler_w: Vec<f32>,
    /// Pooler bias, `[hidden]`.
    pub pooler_b: Vec<f32>,
    /// Classifier weight, row-major `[hidden, classes_total]`.
    pub cls_w: Vec<f32>,
    /// Classifier bias, `[classes_total]`.
    pub cls_b: Vec<f32>,
}

impl TaskAdapter {
    /// Distill a serve-ready adapter out of a (tuned or pristine)
    /// parameter store: clones exactly the vectors the Hadamard method
    /// trains. On an untuned backbone this yields a passthrough adapter
    /// (identity `W`/`B`, the backbone's LN and head).
    ///
    /// The serve path applies the **order-1** adapter (the paper's
    /// deployed form), so a store whose `hadamard.w2`/`w3` vectors were
    /// trained away from their zero init (the `hadamard^o2`/`o3`
    /// fitting-study methods) is rejected rather than silently served
    /// with the higher-order terms dropped.
    pub fn from_store(
        info: &ModelInfo,
        store: &ParamStore,
        task: &str,
        classes: usize,
    ) -> Result<TaskAdapter> {
        let mut had_w = Vec::with_capacity(info.layers);
        let mut had_b = Vec::with_capacity(info.layers);
        let mut norm_w = Vec::with_capacity(info.layers);
        let mut norm_b = Vec::with_capacity(info.layers);
        for i in 0..info.layers {
            let g = |suffix: &str| -> Result<Vec<f32>> {
                Ok(store.get(&format!("encoder.layer.{i}.{suffix}"))?.data.clone())
            };
            for fam in ["hadamard.w2", "hadamard.w3"] {
                let v = store.get(&format!("encoder.layer.{i}.{fam}"))?;
                if v.data.iter().any(|&x| x != 0.0) {
                    bail!(
                        "task '{task}': {fam} deviates from identity at layer {i} — \
                         the serve path applies the order-1 adapter only, so this \
                         checkpoint (an order-2/3 fitting-study tune?) cannot be \
                         distilled into a bank entry"
                    );
                }
            }
            had_w.push(g("hadamard.weight")?);
            had_b.push(g("hadamard.bias")?);
            norm_w.push(g("output.LayerNorm.weight")?);
            norm_b.push(g("output.LayerNorm.bias")?);
        }
        Ok(TaskAdapter {
            task: task.to_string(),
            classes,
            had_w,
            had_b,
            norm_w,
            norm_b,
            pooler_w: store.get("pooler.dense.weight")?.data.clone(),
            pooler_b: store.get("pooler.dense.bias")?.data.clone(),
            cls_w: store.get("classifier.weight")?.data.clone(),
            cls_b: store.get("classifier.bias")?.data.clone(),
        })
    }

    /// **Logical** scalars this adapter serves (the paper-comparable
    /// per-task parameter count — compare with the backbone's millions).
    /// This is what a tenant *means*, not what it costs to hold: in a
    /// tiered bank most of these scalars are shared centroid rows stored
    /// once for the whole fleet, so summing `scalars()` across tenants
    /// overstates storage. Use [`TaskAdapter::resident_bytes`] for
    /// memory accounting and `bankstore::BankSummary` for on-disk cost —
    /// keeping the two axes separate is what stops compression ratios
    /// from double-counting centroid storage per tenant.
    pub fn scalars(&self) -> usize {
        self.had_w.iter().map(Vec::len).sum::<usize>()
            + self.had_b.iter().map(Vec::len).sum::<usize>()
            + self.norm_w.iter().map(Vec::len).sum::<usize>()
            + self.norm_b.iter().map(Vec::len).sum::<usize>()
            + self.pooler_w.len()
            + self.pooler_b.len()
            + self.cls_w.len()
            + self.cls_b.len()
    }

    /// Bytes this adapter actually occupies fully materialized in memory
    /// (the hot-tier residency cost of one tenant).
    pub fn resident_bytes(&self) -> usize {
        self.scalars() * std::mem::size_of::<f32>()
    }
}

/// Why [`AdapterBank::resolve_pinned`] could not produce a slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResolveMiss {
    /// The task exists in neither tier.
    Unknown,
    /// Every hot slot is pinned by queued rows — no victim to evict.
    /// Transient: retry once the queue drains (the wire layer sheds
    /// with a 503, not a 404).
    Busy,
    /// The on-disk record vanished or failed its checksum mid-read.
    Torn,
}

/// Hot/cold tier counters of an [`AdapterBank`]. In flat (store-less)
/// banks every lookup is a hot hit; with a `bankstore` attached, a miss
/// on the resident set faults the tenant in from disk (one promotion,
/// plus one eviction once the hot set is full).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct BankStats {
    /// Lookups answered by the resident hot set.
    pub hot_hits: u64,
    /// Lookups that missed the hot set and paged a cold tenant in.
    pub cold_faults: u64,
    /// Tenants reconstructed (centroid + deltas) into the hot set.
    pub promotions: u64,
    /// Hot entries recycled to make room for a promotion.
    pub evictions: u64,
}

/// Named per-task adapters sharing one frozen backbone. Registration is
/// an upsert: replacing a task's adapter is the hot "deploy a new tuned
/// adapter" path and costs exactly the vector copies involved — it never
/// invalidates the backbone's packed panels.
///
/// Entries live in a dense `Vec` with a name index on the side. A task's
/// dense index ([`AdapterBank::index_of`]) is assigned at first
/// registration and **stable across hot swaps** (replacement happens in
/// place), which is what lets the wire path hold a `usize` per in-flight
/// request instead of an owned task name.
/// When a `bankstore` is attached ([`AdapterBank::attach_store`]), the
/// dense `Vec` becomes the **hot tier** of a two-tier bank: an LRU set
/// of fully materialized adapters over an on-disk fleet. A lookup that
/// misses the hot set faults the tenant in — reconstructed centroid +
/// delta into a recycled entry slot, in place, so the steady state over
/// a hot-resident working set stays allocation-free. Dense indices then
/// name *slots*, not tasks forever: an eviction reuses the slot for the
/// promoted tenant, which is why in-flight waves pin their slots (see
/// [`AdapterBank::resolve_pinned`]).
#[derive(Debug)]
pub struct AdapterBank {
    layers: usize,
    hidden: usize,
    classes: usize,
    entries: Vec<TaskAdapter>,
    index: HashMap<String, usize>,
    /// Cold tier: the on-disk bank, if attached.
    store: Option<BankReader>,
    /// Hot-tier capacity when a store is attached (0 = flat, unbounded).
    hot_cap: usize,
    /// Per-slot LRU stamps (parallel to `entries`).
    stamps: Vec<u64>,
    /// Monotonic LRU clock.
    clock: u64,
    stats: BankStats,
}

impl AdapterBank {
    /// An empty bank shaped for `info`'s geometry.
    pub fn for_model(info: &ModelInfo) -> Result<AdapterBank> {
        let classes = info.params[info.param_index("classifier.bias")?].shape[0];
        Ok(AdapterBank {
            layers: info.layers,
            hidden: info.hidden,
            classes,
            entries: Vec::new(),
            index: HashMap::new(),
            store: None,
            hot_cap: 0,
            stamps: Vec::new(),
            clock: 0,
            stats: BankStats::default(),
        })
    }

    /// Attach an on-disk bank as the cold tier, capping the resident hot
    /// set at `hot` entries. The store's geometry must match the bank's
    /// model; already-registered entries stay resident and count against
    /// the cap (so `hot` must cover them).
    pub fn attach_store(&mut self, store: BankReader, hot: usize) -> Result<()> {
        let g = store.geometry();
        if g.layers != self.layers || g.hidden != self.hidden || g.classes != self.classes {
            bail!(
                "bank file geometry (layers={}, hidden={}, classes={}) does not match \
                 the model (layers={}, hidden={}, classes={})",
                g.layers,
                g.hidden,
                g.classes,
                self.layers,
                self.hidden,
                self.classes
            );
        }
        if hot == 0 {
            bail!("the hot tier needs at least one slot");
        }
        if hot < self.entries.len() {
            bail!(
                "hot tier of {hot} cannot hold the {} already-registered entries",
                self.entries.len()
            );
        }
        self.store = Some(store);
        self.hot_cap = hot;
        Ok(())
    }

    /// The attached cold-tier store, if any — read-only access to its
    /// health surface (generation, damage, live fraction) for `/stats`
    /// and the CLI.
    pub fn store(&self) -> Option<&BankReader> {
        self.store.as_ref()
    }

    /// Compact the attached store in place: rewrite its log dropping
    /// shadowed and quarantined records into a generation-bumped image
    /// (see [`BankReader::compact`]), then keep serving from the new
    /// file. The hot tier is untouched — resident entries are fully
    /// materialized, so nothing they serve depends on old file offsets —
    /// and on any failure the previous generation keeps serving.
    pub fn compact_store(&mut self) -> Result<CompactSummary> {
        match self.store.as_mut() {
            Some(s) => s.compact(),
            None => bail!("no on-disk bank attached — nothing to compact"),
        }
    }

    /// Whether `task` is servable from either tier.
    pub fn available(&self, task: &str) -> bool {
        self.index.contains_key(task)
            || self.store.as_ref().is_some_and(|s| s.contains(task))
    }

    /// Resolve a task to its hot-tier slot, faulting it in from the cold
    /// tier if needed. `pinned` must return `true` for slots the queue
    /// already references — eviction skips those, because a queued row's
    /// index must keep naming the same tenant until its wave runs.
    ///
    /// Hot hits cost a map probe and a stamp write — no allocation;
    /// faults cost one offset read plus vector copies into the recycled
    /// slot (in place — no allocation at high-water). A miss is typed
    /// ([`ResolveMiss`]): the caller maps "no such tenant" to a 404-class
    /// reject and "every slot pinned" to a retryable shed, instead of
    /// conflating the two.
    pub fn resolve_pinned(
        &mut self,
        task: &str,
        pinned: impl Fn(usize) -> bool,
    ) -> Result<usize, ResolveMiss> {
        if let Some(&i) = self.index.get(task) {
            self.clock += 1;
            self.stamps[i] = self.clock;
            self.stats.hot_hits += 1;
            return Ok(i);
        }
        let store = self.store.as_mut().ok_or(ResolveMiss::Unknown)?;
        if !store.contains(task) {
            return Err(ResolveMiss::Unknown);
        }
        self.stats.cold_faults += 1;
        let slot = if self.entries.len() < self.hot_cap {
            // warm-up growth: materialize a fresh slot (allocates; the
            // steady state below never takes this branch)
            self.entries.push(store.blank_adapter());
            self.stamps.push(0);
            self.entries.len() - 1
        } else {
            // evict the least-recently-used unpinned slot (ties go to
            // the lowest index — deterministic across runs)
            let victim = (0..self.entries.len())
                .filter(|&i| !pinned(i))
                .min_by_key(|&i| self.stamps[i])
                .ok_or(ResolveMiss::Busy)?;
            self.index.remove(&self.entries[victim].task);
            self.stats.evictions += 1;
            victim
        };
        if store.read_into(task, &mut self.entries[slot]).is_err() {
            // the record vanished or failed to decode mid-serve; the
            // slot now holds a half-written tenant — drop it entirely
            // rather than serve it (its index entry was already removed
            // or never existed)
            self.entries[slot].task.clear();
            return Err(ResolveMiss::Torn);
        }
        self.stats.promotions += 1;
        self.clock += 1;
        self.stamps[slot] = self.clock;
        self.index.insert(self.entries[slot].task.clone(), slot);
        Ok(slot)
    }

    /// Register (or replace) a task's adapter after validating its
    /// geometry against the bank's model.
    pub fn register(&mut self, adapter: TaskAdapter) -> Result<()> {
        let (ly, h, c) = (self.layers, self.hidden, self.classes);
        for (what, set) in [
            ("hadamard.weight", &adapter.had_w),
            ("hadamard.bias", &adapter.had_b),
            ("output.LayerNorm.weight", &adapter.norm_w),
            ("output.LayerNorm.bias", &adapter.norm_b),
        ] {
            if set.len() != ly {
                bail!(
                    "task '{}': {what} covers {} layers, model has {ly}",
                    adapter.task,
                    set.len()
                );
            }
            for (i, v) in set.iter().enumerate() {
                if v.len() != h {
                    bail!(
                        "task '{}': {what} layer {i} has {} scalars, want {h}",
                        adapter.task,
                        v.len()
                    );
                }
            }
        }
        if adapter.pooler_w.len() != h * h || adapter.pooler_b.len() != h {
            bail!(
                "task '{}': pooler holds {}/{} scalars, want {}/{}",
                adapter.task,
                adapter.pooler_w.len(),
                adapter.pooler_b.len(),
                h * h,
                h
            );
        }
        if adapter.cls_w.len() != h * c || adapter.cls_b.len() != c {
            bail!(
                "task '{}': classifier holds {}/{} scalars, want {}/{}",
                adapter.task,
                adapter.cls_w.len(),
                adapter.cls_b.len(),
                h * c,
                c
            );
        }
        if adapter.classes == 0 || adapter.classes > c {
            bail!(
                "task '{}': {} active classes outside the {c}-wide head",
                adapter.task,
                adapter.classes
            );
        }
        self.clock += 1;
        match self.index.get(&adapter.task) {
            Some(&i) => {
                self.entries[i] = adapter;
                self.stamps[i] = self.clock;
            }
            None => {
                self.index.insert(adapter.task.clone(), self.entries.len());
                self.entries.push(adapter);
                self.stamps.push(self.clock);
            }
        }
        Ok(())
    }

    /// Look up a task's adapter.
    pub fn get(&self, task: &str) -> Option<&TaskAdapter> {
        self.index.get(task).map(|&i| &self.entries[i])
    }

    /// A task's dense index (stable across hot swaps).
    pub fn index_of(&self, task: &str) -> Option<usize> {
        self.index.get(task).copied()
    }

    /// The adapter at a dense index.
    pub fn by_index(&self, i: usize) -> Option<&TaskAdapter> {
        self.entries.get(i)
    }

    /// Whether a task is registered.
    pub fn contains(&self, task: &str) -> bool {
        self.index.contains_key(task)
    }

    /// Registered task count.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the bank is empty.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Registered task names, in first-registration order.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|a| a.task.as_str())
    }

    /// Hot/cold tier counters. In a flat bank every lookup counts as a
    /// hot hit and the fault/promotion/eviction counters stay zero.
    pub fn bank_stats(&self) -> BankStats {
        self.stats
    }

    /// Bytes resident in memory: the materialized hot entries plus (with
    /// a store attached) the shared centroid table. Cold tenants on disk
    /// cost nothing here — that is the point of the tiered bank.
    pub fn resident_bytes(&self) -> u64 {
        let hot: u64 = self.entries.iter().map(|a| a.resident_bytes() as u64).sum();
        let centroids: u64 = self
            .store
            .as_ref()
            .map(|s| s.centroids().iter().map(|c| c.resident_bytes() as u64).sum())
            .unwrap_or(0);
        hot + centroids
    }

    /// Distinct servable tenants across both tiers.
    pub fn tenant_count(&self) -> usize {
        let cold_only = self
            .store
            .as_ref()
            .map(|s| s.names().filter(|n| !self.index.contains_key(*n)).count())
            .unwrap_or(0);
        self.entries.len() + cold_only
    }
}

/// One classification request: raw token sequences plus the task tag that
/// selects the adapter rows. Encoding to the model's fixed geometry
/// happens inside the session (`data::batcher::encode_into`), directly
/// into the session's reused batch buffers.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    /// Which registered task's adapter serves this request.
    pub task: String,
    /// First sentence, as token ids (no specials).
    pub seq_a: Vec<i32>,
    /// Optional second sentence for pair tasks.
    pub seq_b: Option<Vec<i32>>,
}

/// One served request's result.
#[derive(Debug, Clone)]
pub struct ServeReply {
    /// The id [`ServeSession::submit`] returned for this request.
    pub id: u64,
    /// The request's task tag.
    pub task: String,
    /// Full-width logits row (mask applied at argmax, not here).
    pub logits: Vec<f32>,
    /// Argmax over the task's active classes.
    pub label: usize,
    /// Submit-to-reply latency in seconds (queue wait included).
    pub latency_s: f64,
}

/// Typed admission error for the borrowed submit path
/// ([`ServeSession::submit_borrowed`]). `Copy` on purpose: the wire
/// front door maps these to error responses on the zero-alloc hot path,
/// where the `String`-backed `anyhow` shim is off limits (the owned
/// [`ServeSession::submit`] keeps its rich allocating messages).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The task has no registered adapter in the bank.
    UnknownTask,
    /// A token id is negative or at/above the model's vocabulary size.
    TokenOutOfVocab,
    /// The bounded queue is at [`ServePolicy::queue_cap`] (or every hot
    /// slot is pinned by queued rows) — shed load, retry after a drain.
    QueueFull,
    /// The tenant's token bucket is empty; the payload is the
    /// milliseconds until one token refills (the wire layer's
    /// `Retry-After`).
    Throttled(u32),
}

/// One direct-wave result, borrowing the session's resident buffers —
/// the zero-copy sibling of [`ServeReply`], valid until the next wave
/// runs.
#[derive(Debug, Clone, Copy)]
pub struct DirectReply<'a> {
    /// The id [`ServeSession::submit_borrowed`] returned.
    pub id: u64,
    /// The request's task tag (borrowed from the bank).
    pub task: &'a str,
    /// Full-width logits row (borrowed from the session's output buffer).
    pub logits: &'a [f32],
    /// Argmax over the task's active classes.
    pub label: usize,
    /// Submit-to-reply latency in seconds.
    pub latency_s: f64,
    /// Which wave of the last drain served this row (0-based). Replies
    /// iterate in arrival order regardless; this exposes the
    /// weighted-round-robin wave assembly for tests and tracing.
    pub wave: u32,
    /// The connection tag the row was submitted under
    /// ([`ServeSession::submit_from`]; 0 for the in-process paths) —
    /// what the multi-connection wire server routes replies by.
    pub conn: u32,
}

/// A queued row: request metadata held without owning any request
/// payload (the payload went straight into the queue buffers at submit).
#[derive(Debug, Clone, Copy)]
struct DirectMeta {
    id: u64,
    task_idx: usize,
    enqueued: Instant,
    /// Connection-slot tag for reply routing (0 = in-process).
    conn: u32,
}

/// Serve-side counters (requests, batches and padding overhead).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ServeStats {
    /// Requests admitted into the queue.
    pub admitted: u64,
    /// Real requests served.
    pub requests: u64,
    /// Micro-batches executed.
    pub batches: u64,
    /// Padding rows executed (fixed-geometry batches repeat the last
    /// real request; padded rows never produce replies).
    pub padded_rows: u64,
    /// Waves that mixed rows from more than one connection tag — the
    /// multi-connection ingress actually batching across clients rather
    /// than serializing them.
    pub cross_conn_waves: u64,
}

/// The session's overload policy: queue bound, flush window and
/// per-tenant rate. The all-zero [`Default`] reproduces the legacy
/// behavior exactly — unbounded-feeling capacity (`2 * max_batch`),
/// flush-on-demand, no throttling.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ServePolicy {
    /// Bounded queue capacity in rows; `0` resolves to `2 * max_batch`.
    /// Submits past the cap get [`SubmitError::QueueFull`].
    pub queue_cap: usize,
    /// Flush window in µs: the wire loop flushes a short wave once the
    /// oldest queued row has waited this long ([`ServeSession::flush_deadline`]).
    /// `0` = flush as soon as the loop asks (legacy behavior).
    pub window_us: u64,
    /// Per-tenant admission rate in requests/second (token-bucket
    /// refill); `0` disables throttling.
    pub tenant_rps: u32,
    /// Token-bucket depth; `0` resolves to `max(tenant_rps, 1)`.
    pub tenant_burst: u32,
    /// Per-connection queued-row quota: one connection may hold at most
    /// this many rows in the queue at once, so a single pipelining
    /// client cannot fill the global queue and shed everyone else.
    /// `0` disables the quota (the global `queue_cap` still applies).
    /// Over-quota submits shed as [`SubmitError::QueueFull`].
    pub conn_queue_cap: usize,
}

/// A live multi-tenant serving session: one uploaded frozen backbone, an
/// adapter bank, **one bounded request queue** and the reused
/// batch/gather/output buffers that keep the steady-state serve loop
/// allocation-stable.
///
/// Batches always run at the fixed `[max_batch, seq]` geometry (short
/// waves pad by repeating the last real row), so after the first batch
/// the workspace arena stops missing and the worker pool stops spawning
/// — the same counters the training loop pins, now on the serve path.
pub struct ServeSession<'e> {
    engine: &'e Engine,
    model: String,
    seq: usize,
    max_batch: usize,
    classes: usize,
    vocab: usize,
    params: Vec<DeviceTensor>,
    bank: AdapterBank,
    next_id: u64,
    /// Overload policy as configured (zeros = legacy defaults).
    policy: ServePolicy,
    /// Resolved queue capacity in rows (`policy.queue_cap` or
    /// `2 * max_batch`).
    q_cap: usize,
    /// Per-tenant token buckets plus the WRR weights.
    admit: AdmissionController,
    /// Epoch for the buckets' monotonic µs timestamps.
    epoch: Instant,
    /// The bounded queue: row metadata in arrival order.
    q_meta: Vec<DirectMeta>,
    /// Queue-resident encoded rows, `[q_cap, seq]` each.
    q_tokens: Vec<i32>,
    q_type_ids: Vec<i32>,
    q_attn: Vec<f32>,
    /// Wave assignment per queued row (`u32::MAX` = unassigned).
    q_wave: Vec<u32>,
    /// Per-row logits of the last drain, `[q_cap, classes]`.
    q_logits: Vec<f32>,
    /// The last drained rows — what [`Self::direct_replies`] iterates
    /// (swapped with `q_meta` after a drain, buffers reused).
    served: Vec<DirectMeta>,
    /// Wave assignments of the last drained rows.
    served_wave: Vec<u32>,
    /// Queue indices of the wave being assembled (reused).
    wave_rows: Vec<usize>,
    /// WRR round clock with per-slot round/pick stamps: a slot's pick
    /// count is implicitly zero whenever its round stamp is stale, so
    /// wave assembly never clears per-slot state.
    wrr_round: u64,
    mark_round: Vec<u64>,
    mark_picks: Vec<u32>,
    /// Batch buffers at the fixed `[max_batch, seq]` geometry.
    tokens: Vec<i32>,
    type_ids: Vec<i32>,
    attn_mask: Vec<f32>,
    gather: BatchAdapters,
    /// Per-row active-class counts captured at gather time (reused).
    actives: Vec<usize>,
    out: InferOut,
    stats: ServeStats,
    /// Per-row argmax labels of the last drain (arrival-indexed).
    labels: Vec<usize>,
    /// Per-row latencies of the last drain (arrival-indexed).
    latencies: Vec<f64>,
}

impl<'e> ServeSession<'e> {
    /// Open a session: validates `store` against the model, uploads the
    /// backbone once (resident for the session's lifetime) and sizes the
    /// reused batch buffers for `[max_batch, seq_len]`. Starts under the
    /// legacy-exact [`ServePolicy::default`]; see [`Self::set_policy`].
    pub fn new(
        engine: &'e Engine,
        model: &str,
        store: &ParamStore,
        max_batch: usize,
    ) -> Result<ServeSession<'e>> {
        if max_batch == 0 {
            bail!("max_batch must be at least 1");
        }
        let info = engine.manifest().model(model)?;
        store.check_against(info)?;
        // The serve forward applies the order-1 adapter everywhere (bank
        // rows replace the model's hadamard vectors outright), so a
        // backbone carrying trained higher-order terms would be silently
        // truncated — reject it here, exactly as
        // [`TaskAdapter::from_store`] does for adapter checkpoints.
        for i in 0..info.layers {
            for fam in ["hadamard.w2", "hadamard.w3"] {
                let v = store.get(&format!("encoder.layer.{i}.{fam}"))?;
                if v.data.iter().any(|&x| x != 0.0) {
                    bail!(
                        "backbone '{model}': {fam} deviates from identity at layer {i} \
                         — the serve path applies the order-1 adapter only and would \
                         silently drop the higher-order terms"
                    );
                }
            }
        }
        let bank = AdapterBank::for_model(info)?;
        let (layers, hidden, classes) = (info.layers, info.hidden, bank.classes);
        let vocab = info.vocab;
        let params = store
            .tensors
            .iter()
            .map(|t| engine.upload(t))
            .collect::<Result<Vec<_>>>()?;
        let mut session = ServeSession {
            engine,
            model: model.to_string(),
            seq: engine.manifest().seq_len,
            max_batch,
            classes,
            vocab,
            params,
            bank,
            next_id: 0,
            policy: ServePolicy::default(),
            q_cap: 0,
            admit: AdmissionController::default(),
            epoch: Instant::now(),
            q_meta: Vec::new(),
            q_tokens: Vec::new(),
            q_type_ids: Vec::new(),
            q_attn: Vec::new(),
            q_wave: Vec::new(),
            q_logits: Vec::new(),
            served: Vec::new(),
            served_wave: Vec::new(),
            wave_rows: Vec::with_capacity(max_batch),
            wrr_round: 0,
            mark_round: Vec::new(),
            mark_picks: Vec::new(),
            tokens: Vec::new(),
            type_ids: Vec::new(),
            attn_mask: Vec::new(),
            gather: BatchAdapters::for_model(layers, hidden, classes),
            actives: Vec::new(),
            out: InferOut::default(),
            stats: ServeStats::default(),
            labels: Vec::new(),
            latencies: Vec::new(),
        };
        session.set_policy(ServePolicy::default())?;
        Ok(session)
    }

    /// Replace the session's overload policy. Only legal on an empty
    /// queue (queued rows were admitted under the old policy's cap and
    /// buckets — re-shaping the queue under them would tear the buffers).
    ///
    /// Sizes every queue buffer up front so the steady admitted path
    /// never grows a `Vec` — the zero-allocation contract the wire alloc
    /// test pins covers submits at any queue depth up to the cap.
    pub fn set_policy(&mut self, policy: ServePolicy) -> Result<()> {
        if !self.q_meta.is_empty() {
            bail!(
                "cannot replace the serve policy with {} row(s) queued — drain first",
                self.q_meta.len()
            );
        }
        self.policy = policy;
        self.q_cap = if policy.queue_cap == 0 {
            2 * self.max_batch
        } else {
            policy.queue_cap
        };
        let (b, l, c, cap) = (self.max_batch, self.seq, self.classes, self.q_cap);
        self.q_tokens.resize(cap * l, 0);
        self.q_type_ids.resize(cap * l, 0);
        self.q_attn.resize(cap * l, 0.0);
        self.q_logits.resize(cap * c, 0.0);
        self.q_meta.reserve(cap);
        self.q_wave.reserve(cap);
        self.served.reserve(cap);
        self.served_wave.reserve(cap);
        self.labels.reserve(cap);
        self.latencies.reserve(cap);
        self.tokens.resize(b * l, 0);
        self.type_ids.resize(b * l, 0);
        self.attn_mask.resize(b * l, 0.0);
        self.admit.configure(policy.tenant_rps, policy.tenant_burst);
        self.admit.ensure_slots(self.bank.len());
        self.admit.configure_conns(policy.conn_queue_cap);
        Ok(())
    }

    /// The session's active overload policy (as configured — zeros mean
    /// the documented defaults).
    pub fn policy(&self) -> ServePolicy {
        self.policy
    }

    /// The resolved queue capacity in rows.
    pub fn queue_cap(&self) -> usize {
        self.q_cap
    }

    /// Whether the next submit would shed with
    /// [`SubmitError::QueueFull`].
    pub fn queue_full(&self) -> bool {
        self.q_meta.len() >= self.q_cap
    }

    /// When the oldest queued row's flush window expires — the wire
    /// loop's read deadline. `None` when the queue is empty or the
    /// policy has no window (`window_us == 0`: flush whenever asked).
    pub fn flush_deadline(&self) -> Option<Instant> {
        if self.policy.window_us == 0 {
            return None;
        }
        self.q_meta
            .first()
            .map(|m| m.enqueued + Duration::from_micros(self.policy.window_us))
    }

    /// Register (or hot-replace) a task's adapter — the vector-copy-cheap
    /// "deploy" operation; never touches the backbone or its pack cache.
    pub fn register_task(&mut self, adapter: TaskAdapter) -> Result<()> {
        self.bank.register(adapter)
    }

    /// The session's adapter bank.
    pub fn bank(&self) -> &AdapterBank {
        &self.bank
    }

    /// Attach an on-disk bank ([`BankReader`]) as the cold tier, capping
    /// the resident hot set at `hot` fully materialized adapters. Both
    /// submit paths then fault cold tenants in transparently.
    ///
    /// `hot` must be at least `max_batch` so one full wave always fits
    /// the hot tier. Queued rows pin their slots (a row's index must
    /// keep naming the same tenant until its wave runs), so with many
    /// *distinct* tenants queued the tier can still fill up — that miss
    /// is typed ([`ResolveMiss::Busy`]) and surfaces as a retryable
    /// [`SubmitError::QueueFull`] shed, never a wrong-tenant reply.
    pub fn attach_store(&mut self, store: BankReader, hot: usize) -> Result<()> {
        if hot < self.max_batch {
            bail!(
                "hot tier of {hot} is smaller than the wave size {} — one wave \
                 could pin every slot and leave nothing to evict",
                self.max_batch
            );
        }
        self.bank.attach_store(store, hot)
    }

    /// Compact the attached on-disk bank between waves. Refused while
    /// rows are queued: open-wave rows pin hot slots by index, and the
    /// swap must happen at a wave boundary so admitted replies are
    /// bitwise identical across it (the wire server calls this only
    /// after draining its responses). The hot tier, its LRU stamps and
    /// all serve counters survive the swap untouched.
    pub fn compact_bank(&mut self) -> Result<CompactSummary> {
        if !self.q_meta.is_empty() {
            bail!(
                "refusing to compact with {} rows queued — run the wave first",
                self.q_meta.len()
            );
        }
        self.bank.compact_store()
    }

    /// Queue a request for the next micro-batch; returns its reply id.
    ///
    /// This is the owned-request twin of [`Self::submit_borrowed`] — one
    /// bounded queue, one admission pipeline (resolve, validate, cap,
    /// throttle, **encode**) run at submit time, so a doomed request is
    /// refused before it can occupy a slot or poison the wave it would
    /// have ridden in. The only difference is ergonomics: this path
    /// takes an owned [`ServeRequest`] and reports rejects as rich
    /// `anyhow` messages instead of the typed `Copy` [`SubmitError`].
    pub fn submit(&mut self, req: ServeRequest) -> Result<u64> {
        match self.submit_borrowed(&req.task, &req.seq_a, req.seq_b.as_deref()) {
            Ok(id) => Ok(id),
            Err(SubmitError::UnknownTask) => bail!(
                "task '{}' has no adapter in either tier (hot: {:?})",
                req.task,
                self.bank.names().collect::<Vec<_>>()
            ),
            Err(SubmitError::TokenOutOfVocab) => bail!(
                "request for task '{}' carries a token id outside the model's \
                 vocabulary (0..{})",
                req.task,
                self.vocab
            ),
            Err(SubmitError::QueueFull) => bail!(
                "the serve queue is full ({} of {} rows) — drain with run_pending() \
                 or raise the policy's queue_cap",
                self.q_meta.len(),
                self.q_cap
            ),
            Err(SubmitError::Throttled(ms)) => bail!(
                "tenant '{}' is over its admission rate; retry in {ms} ms",
                req.task
            ),
        }
    }

    /// Requests currently queued.
    pub fn pending(&self) -> usize {
        self.q_meta.len()
    }

    /// Serve counters accumulated so far.
    pub fn stats(&self) -> ServeStats {
        self.stats
    }

    /// The fixed micro-batch geometry `(max_batch, seq_len)`.
    pub fn geometry(&self) -> (usize, usize) {
        (self.max_batch, self.seq)
    }

    /// The engine this session serves on (for counter snapshots — the
    /// wire server's `/stats` reports arena/pool/pack counters alongside
    /// its own).
    pub fn engine(&self) -> &Engine {
        self.engine
    }

    /// Borrowed-slice admission for the wire path: runs the full
    /// admission pipeline and encodes the request **directly into the
    /// resident queue buffers** — no owned `String`/`Vec`, no heap
    /// traffic after warmup. Rows accumulate until [`Self::run_direct`];
    /// replies are read back with [`Self::direct_replies`].
    ///
    /// The pipeline, in order, each stage with its typed reject:
    ///
    /// 1. queue cap ([`SubmitError::QueueFull`] — load shed);
    /// 2. bank resolution, faulting cold tenants in while pinning every
    ///    queued row's slot ([`SubmitError::UnknownTask`], or `QueueFull`
    ///    when every hot slot is pinned);
    /// 3. token validation ([`SubmitError::TokenOutOfVocab`] — a
    ///    malformed request must not poison the wave it would ride in);
    /// 4. the tenant's token bucket ([`SubmitError::Throttled`] with a
    ///    deterministic retry-after).
    pub fn submit_borrowed(
        &mut self,
        task: &str,
        seq_a: &[i32],
        seq_b: Option<&[i32]>,
    ) -> Result<u64, SubmitError> {
        self.submit_from(0, task, seq_a, seq_b)
    }

    /// [`Self::submit_borrowed`] with an explicit connection tag: the
    /// multi-connection wire server stamps each row with its
    /// connection-slot index so [`Self::direct_replies`] can be routed
    /// back to the right socket ([`DirectReply::conn`]), and so the
    /// per-connection queue quota ([`ServePolicy::conn_queue_cap`]) has
    /// something to count. In-process callers use `submit_borrowed`
    /// (tag 0); the tag never influences *what* is computed, only where
    /// the reply is delivered and whether this connection may queue.
    pub fn submit_from(
        &mut self,
        conn: u32,
        task: &str,
        seq_a: &[i32],
        seq_b: Option<&[i32]>,
    ) -> Result<u64, SubmitError> {
        if faultpoint::fire("serve.queue-full") || self.q_meta.len() >= self.q_cap {
            return Err(SubmitError::QueueFull);
        }
        if !self.admit.conn_within_quota(conn) {
            return Err(SubmitError::QueueFull);
        }
        // resolve through the tiered bank, pinning every queued row's
        // slot so a fault's eviction can never recycle an index an
        // earlier queued row still holds
        let q_meta = &self.q_meta;
        let promotions = self.bank.bank_stats().promotions;
        let slot = self
            .bank
            .resolve_pinned(task, |i| q_meta.iter().any(|m| m.task_idx == i))
            .map_err(|miss| match miss {
                ResolveMiss::Unknown | ResolveMiss::Torn => SubmitError::UnknownTask,
                ResolveMiss::Busy => SubmitError::QueueFull,
            })?;
        if self.bank.bank_stats().promotions != promotions {
            // the slot was just recycled for a newly promoted tenant —
            // it must start with a full burst, not the evictee's debt
            self.admit.reset_slot(slot);
        }
        for &t in seq_a.iter().chain(seq_b.into_iter().flatten()) {
            if t < 0 || t as usize >= self.vocab {
                return Err(SubmitError::TokenOutOfVocab);
            }
        }
        if faultpoint::fire("admit.slow-tenant") {
            return Err(SubmitError::Throttled(1000));
        }
        let enqueued = Instant::now();
        let now_us = enqueued.duration_since(self.epoch).as_micros() as u64;
        self.admit.try_admit(slot, now_us).map_err(SubmitError::Throttled)?;
        let l = self.seq;
        let i = self.q_meta.len();
        encode_into(
            seq_a,
            seq_b,
            l,
            &mut self.q_tokens[i * l..(i + 1) * l],
            &mut self.q_type_ids[i * l..(i + 1) * l],
            &mut self.q_attn[i * l..(i + 1) * l],
        );
        let id = self.next_id;
        self.next_id += 1;
        self.q_meta.push(DirectMeta { id, task_idx: slot, enqueued, conn });
        self.admit.note_conn_enqueue(conn);
        self.stats.admitted += 1;
        Ok(id)
    }

    /// Drop every queued row without serving it — the wire server's
    /// post-admission failure path: if a drain errors (or panics under
    /// fault injection), the admitted rows must not leak into the next
    /// wave. Per-connection quota held by the dropped rows is released.
    pub fn abort_direct(&mut self) {
        for i in 0..self.q_meta.len() {
            let conn = self.q_meta[i].conn;
            self.admit.release_conn(conn);
        }
        self.q_meta.clear();
        self.q_wave.clear();
    }

    /// Queued rows not yet drained (alias of [`Self::pending`], kept for
    /// the wire server's vocabulary).
    pub fn direct_pending(&self) -> usize {
        self.q_meta.len()
    }

    /// Drain the queue: weighted-round-robin waves of up to `max_batch`
    /// rows (mixed tasks welcome — adapter rows are selected per
    /// example), each run as one padded fixed-geometry micro-batch.
    /// Returns the number of real requests served; results stay resident
    /// until the next drain and are read with [`Self::direct_replies`].
    pub fn run_direct(&mut self) -> Result<usize> {
        self.drain()
    }

    /// Drain the queue and materialize owned replies, in arrival order.
    pub fn run_pending(&mut self) -> Result<Vec<ServeReply>> {
        let n = self.drain()?;
        if n == 0 {
            return Ok(Vec::new());
        }
        let c = self.classes;
        let mut replies = Vec::with_capacity(n);
        for (i, meta) in self.served.iter().enumerate() {
            replies.push(ServeReply {
                id: meta.id,
                task: self
                    .bank
                    .by_index(meta.task_idx)
                    .map(|a| a.task.clone())
                    .unwrap_or_default(),
                logits: self.q_logits[i * c..(i + 1) * c].to_vec(),
                label: self.labels[i],
                latency_s: self.latencies[i],
            });
        }
        Ok(replies)
    }

    /// Iterate the last drain's replies in arrival order, borrowing the
    /// session's resident buffers (valid until the next drain).
    pub fn direct_replies(&self) -> impl Iterator<Item = DirectReply<'_>> {
        let c = self.classes;
        self.served.iter().enumerate().map(move |(i, meta)| DirectReply {
            id: meta.id,
            task: self
                .bank
                .by_index(meta.task_idx)
                .map(|a| a.task.as_str())
                .unwrap_or(""),
            logits: &self.q_logits[i * c..(i + 1) * c],
            label: self.labels[i],
            latency_s: self.latencies[i],
            wave: self.served_wave[i],
            conn: meta.conn,
        })
    }

    /// Serve every queued row: assemble weighted-round-robin waves, run
    /// each as one padded micro-batch, scatter results back to
    /// arrival-indexed buffers, then swap the queue into the served set.
    ///
    /// WRR assembly walks the queue in arrival order in repeated rounds;
    /// each round a tenant may place at most its weight
    /// ([`AdmissionController::weight`], default 1) of rows, so a
    /// backlog from one hot tenant cannot monopolize a wave while other
    /// tenants wait. Every kernel downstream is row-local, so this
    /// reordering across waves never changes a request's logits.
    fn drain(&mut self) -> Result<usize> {
        let n = self.q_meta.len();
        if n == 0 {
            return Ok(0);
        }
        if faultpoint::fire("serve.mid-wave-panic") {
            panic!("fault injected: serve.mid-wave-panic");
        }
        let (b, l, c) = (self.max_batch, self.seq, self.classes);
        if self.mark_round.len() < self.bank.len() {
            self.mark_round.resize(self.bank.len(), 0);
            self.mark_picks.resize(self.bank.len(), 0);
        }
        self.q_wave.clear();
        self.q_wave.resize(n, u32::MAX);
        self.labels.clear();
        self.labels.resize(n, 0);
        self.latencies.clear();
        self.latencies.resize(n, 0.0);
        let mut wave: u32 = 0;
        let mut done = 0usize;
        while done < n {
            // assemble one wave: arrival-order rounds, ≤ weight picks
            // per tenant per round; a round that picks nothing means no
            // unassigned rows remain (weights are ≥ 1, so any round over
            // a non-empty remainder picks at least its first row)
            self.wave_rows.clear();
            while self.wave_rows.len() < b {
                self.wrr_round += 1;
                let round = self.wrr_round;
                let picked_before = self.wave_rows.len();
                for qi in 0..n {
                    if self.wave_rows.len() >= b {
                        break;
                    }
                    if self.q_wave[qi] != u32::MAX {
                        continue;
                    }
                    let slot = self.q_meta[qi].task_idx;
                    if self.mark_round[slot] != round {
                        self.mark_round[slot] = round;
                        self.mark_picks[slot] = 0;
                    }
                    if self.mark_picks[slot] >= self.admit.weight(slot) {
                        continue;
                    }
                    self.mark_picks[slot] += 1;
                    self.q_wave[qi] = wave;
                    self.wave_rows.push(qi);
                }
                if self.wave_rows.len() == picked_before {
                    break;
                }
            }
            let w = self.wave_rows.len();
            debug_assert!(w > 0, "a wave over a non-empty queue picked no rows");
            let first_conn = self.q_meta[self.wave_rows[0]].conn;
            if self.wave_rows.iter().any(|&qi| self.q_meta[qi].conn != first_conn) {
                self.stats.cross_conn_waves += 1;
            }
            for (row, &qi) in self.wave_rows.iter().enumerate() {
                self.tokens[row * l..(row + 1) * l]
                    .copy_from_slice(&self.q_tokens[qi * l..(qi + 1) * l]);
                self.type_ids[row * l..(row + 1) * l]
                    .copy_from_slice(&self.q_type_ids[qi * l..(qi + 1) * l]);
                self.attn_mask[row * l..(row + 1) * l]
                    .copy_from_slice(&self.q_attn[qi * l..(qi + 1) * l]);
            }
            for row in w..b {
                repeat_row(&mut self.tokens, l, w - 1, row);
                repeat_row(&mut self.type_ids, l, w - 1, row);
                repeat_row(&mut self.attn_mask, l, w - 1, row);
            }
            self.gather.clear();
            self.actives.clear();
            for row in 0..b {
                let meta = self.q_meta[self.wave_rows[row.min(w - 1)]];
                let ad = self.bank.by_index(meta.task_idx).ok_or_else(|| {
                    anyhow!("task index {} vanished from the bank", meta.task_idx)
                })?;
                self.actives.push(ad.classes);
                gather_rows(&mut self.gather, ad);
            }
            self.engine.infer(
                &self.model,
                &self.params,
                InferBatch {
                    b,
                    l,
                    tokens: &self.tokens,
                    type_ids: &self.type_ids,
                    attn_mask: &self.attn_mask,
                },
                Some(&self.gather),
                &mut self.out,
            )?;
            for (row, &qi) in self.wave_rows.iter().enumerate() {
                self.q_logits[qi * c..(qi + 1) * c]
                    .copy_from_slice(&self.out.logits[row * c..(row + 1) * c]);
                let active = self.actives[row];
                let mut best = 0usize;
                let mut bestv = f32::MIN;
                for (j, &v) in self.out.logits[row * c..(row + 1) * c]
                    .iter()
                    .enumerate()
                    .take(active)
                {
                    if v > bestv {
                        bestv = v;
                        best = j;
                    }
                }
                self.labels[qi] = best;
                self.latencies[qi] = self.q_meta[qi].enqueued.elapsed().as_secs_f64();
            }
            self.stats.requests += w as u64;
            self.stats.batches += 1;
            self.stats.padded_rows += (b - w) as u64;
            done += w;
            wave += 1;
        }
        // served rows leave the queue: release their connections' quota
        for i in 0..self.q_meta.len() {
            let conn = self.q_meta[i].conn;
            self.admit.release_conn(conn);
        }
        std::mem::swap(&mut self.q_meta, &mut self.served);
        std::mem::swap(&mut self.q_wave, &mut self.served_wave);
        self.q_meta.clear();
        self.q_wave.clear();
        Ok(n)
    }
}

/// Copy row `src` over row `dst` in a `[rows, l]` buffer (`src < dst`) —
/// the padding primitive for short direct waves.
fn repeat_row<T: Copy>(buf: &mut [T], l: usize, src: usize, dst: usize) {
    debug_assert!(src < dst);
    let (head, tail) = buf.split_at_mut(dst * l);
    tail[..l].copy_from_slice(&head[src * l..(src + 1) * l]);
}

/// Build deterministic synthetic tenants: distill the store's identity
/// adapter once per task, then perturb the Hadamard vectors with a
/// task-seeded RNG so tenants genuinely disagree on identical input.
///
/// This is the shared synthetic-tenant path behind `serve-demo`,
/// `serve-http`, the wire tests and the ingress bench — same `(store,
/// tasks, seed)` always yields the same adapters, which is what lets the
/// wire-vs-in-process test compare logits bitwise across two sessions.
pub fn synthetic_adapters(
    info: &ModelInfo,
    store: &ParamStore,
    tasks: &[String],
    seed: u64,
) -> Result<Vec<TaskAdapter>> {
    let mut adapters = Vec::with_capacity(tasks.len());
    for (ti, task) in tasks.iter().enumerate() {
        let classes = task_info(task)
            .with_context(|| format!("unknown task '{task}'"))?
            .classes
            .max(1);
        let mut a = TaskAdapter::from_store(info, store, task, classes)?;
        let mut rng = Rng::new(seed.wrapping_add(7919 * (ti as u64 + 1)));
        for li in 0..a.had_w.len() {
            for v in a.had_w[li].iter_mut() {
                *v += 0.05 * rng.normal();
            }
            for v in a.had_b[li].iter_mut() {
                *v += 0.05 * rng.normal();
            }
        }
        adapters.push(a);
    }
    Ok(adapters)
}

/// Deterministically synthesize tenant `idx` of a Zipf-clustered fleet
/// over `bases` (the fleet's centroid adapters, e.g. from
/// [`synthetic_adapters`]).
///
/// Tenants `0..bases.len()` are the bases themselves, name verbatim —
/// so a bank built from this fleet serves the same task names as a flat
/// synthetic bank, which is what lets the wire smoke and fixture corpus
/// run unchanged against a bank-backed server. Tenants beyond that are
/// named `t{idx:06}` (predictable cold-tenant names for load drivers),
/// Zipf-assigned to a base (popular bases collect most tenants, like
/// production task popularity), and perturbed the way the paper says
/// real tuning runs differ: ~3/8 are exact duplicates of their base,
/// half deviate in a single layer's Hadamard rows, and the rest deviate
/// in every layer — so most per-layer rows dedupe against the centroid
/// and the redundant-layer finding becomes measurable compression.
///
/// Same `(bases, idx, seed)` always yields the same tenant bitwise.
pub fn synthetic_tenant(bases: &[TaskAdapter], idx: usize, seed: u64) -> TaskAdapter {
    assert!(!bases.is_empty(), "a fleet needs at least one base adapter");
    if idx < bases.len() {
        return bases[idx].clone();
    }
    let mut rng = Rng::new(seed ^ (idx as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
    // Zipf base pick: weight of base r is 1/(r+1), via inverse CDF
    let h: f64 = (0..bases.len()).map(|r| 1.0 / (r + 1) as f64).sum();
    let u = rng.next_f32() as f64 * h;
    let mut acc = 0.0;
    let mut base = bases.len() - 1;
    for r in 0..bases.len() {
        acc += 1.0 / (r + 1) as f64;
        if u <= acc {
            base = r;
            break;
        }
    }
    let mut t = bases[base].clone();
    t.task.clear();
    use std::fmt::Write as _;
    let _ = write!(t.task, "t{idx:06}");
    let mix = rng.next_f32();
    if mix < 0.375 {
        // exact duplicate of its base: every row dedupes to zero bytes
    } else if mix < 0.875 {
        // single-layer deviation (the common case the redundant-layer
        // finding predicts: most layers stay at their shared rows)
        let li = rng.below(t.had_w.len());
        for v in t.had_w[li].iter_mut() {
            *v += 0.02 * rng.normal();
        }
        for v in t.had_b[li].iter_mut() {
            *v += 0.02 * rng.normal();
        }
    } else {
        // fully independent tune: every Hadamard row deviates
        for li in 0..t.had_w.len() {
            for v in t.had_w[li].iter_mut() {
                *v += 0.02 * rng.normal();
            }
            for v in t.had_b[li].iter_mut() {
                *v += 0.02 * rng.normal();
            }
        }
    }
    t
}

/// Append one task's adapter vectors as the next example's rows.
fn gather_rows(g: &mut BatchAdapters, a: &TaskAdapter) {
    for li in 0..g.layers {
        g.had_w[li].extend_from_slice(&a.had_w[li]);
        g.had_b[li].extend_from_slice(&a.had_b[li]);
        g.norm_w[li].extend_from_slice(&a.norm_w[li]);
        g.norm_b[li].extend_from_slice(&a.norm_b[li]);
    }
    g.pooler_w.extend_from_slice(&a.pooler_w);
    g.pooler_b.extend_from_slice(&a.pooler_b);
    g.cls_w.extend_from_slice(&a.cls_w);
    g.cls_b.extend_from_slice(&a.cls_b);
    g.batch += 1;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Engine, ParamStore) {
        let engine = Engine::native().unwrap();
        let info = engine.manifest().model("tiny").unwrap();
        let store = ParamStore::init(info, 11);
        (engine, store)
    }

    #[test]
    fn from_store_extracts_the_trained_families() {
        let (engine, store) = setup();
        let info = engine.manifest().model("tiny").unwrap();
        let a = TaskAdapter::from_store(info, &store, "sst2", 2).unwrap();
        assert_eq!(a.had_w.len(), info.layers);
        assert_eq!(a.norm_b.len(), info.layers);
        assert_eq!(a.had_w[0].len(), info.hidden);
        assert_eq!(a.pooler_w.len(), info.hidden * info.hidden);
        assert_eq!(a.pooler_b.len(), info.hidden);
        assert_eq!(a.cls_b.len(), 3);
        assert_eq!(a.cls_w.len(), info.hidden * 3);
        // identity init: hadamard W is ones, B is zeros
        assert!(a.had_w[0].iter().all(|&v| v == 1.0));
        assert!(a.had_b[0].iter().all(|&v| v == 0.0));
        let per_task = a.scalars();
        assert!(
            per_task * 5 < info.total_params(),
            "a task adapter ({per_task} scalars) must be a sliver of the backbone"
        );

        // an order-2/3 fitting-study checkpoint cannot be distilled: the
        // serve path applies the order-1 adapter only
        let mut s2 = store.clone();
        s2.get_mut("encoder.layer.0.hadamard.w2").unwrap().data[1] = 0.3;
        let err = TaskAdapter::from_store(info, &s2, "sst2", 2).unwrap_err();
        assert!(err.to_string().contains("order-1"), "{err}");
    }

    #[test]
    fn bank_rejects_misshapen_adapters() {
        let (engine, store) = setup();
        let info = engine.manifest().model("tiny").unwrap();
        let mut bank = AdapterBank::for_model(info).unwrap();
        let good = TaskAdapter::from_store(info, &store, "sst2", 2).unwrap();
        bank.register(good.clone()).unwrap();
        assert!(bank.contains("sst2"));
        assert_eq!(bank.len(), 1);

        let mut wrong_h = good.clone();
        wrong_h.had_w[1] = vec![0.0; 3];
        assert!(bank.register(wrong_h).is_err());

        let mut wrong_layers = good.clone();
        wrong_layers.norm_w.pop();
        assert!(bank.register(wrong_layers).is_err());

        let mut wrong_head = good.clone();
        wrong_head.cls_b = vec![0.0; 2];
        assert!(bank.register(wrong_head).is_err());

        let mut wrong_pooler = good.clone();
        wrong_pooler.pooler_w.pop();
        assert!(bank.register(wrong_pooler).is_err());

        let mut wrong_classes = good.clone();
        wrong_classes.classes = 9;
        assert!(bank.register(wrong_classes).is_err());

        // re-registration (the hot adapter-swap path) is an upsert
        let mut swap = good;
        swap.had_b[0][0] = 0.25;
        bank.register(swap).unwrap();
        assert_eq!(bank.len(), 1);
        assert_eq!(bank.get("sst2").unwrap().had_b[0][0], 0.25);
    }

    #[test]
    fn session_rejects_higher_order_backbones() {
        let (engine, store) = setup();
        let mut s2 = store.clone();
        s2.get_mut("encoder.layer.1.hadamard.w3").unwrap().data[0] = 0.2;
        let err = ServeSession::new(&engine, "tiny", &s2, 2).unwrap_err();
        assert!(err.to_string().contains("order-1"), "{err}");
    }

    #[test]
    fn direct_wave_matches_owned_path_and_reuses_buffers() {
        let (engine, store) = setup();
        let info = engine.manifest().model("tiny").unwrap().clone();
        let tasks = vec!["sst2".to_string(), "rte".to_string()];
        let adapters = synthetic_adapters(&info, &store, &tasks, 33).unwrap();

        let mut owned = ServeSession::new(&engine, "tiny", &store, 3).unwrap();
        let mut direct = ServeSession::new(&engine, "tiny", &store, 3).unwrap();
        for a in adapters {
            owned.register_task(a.clone()).unwrap();
            direct.register_task(a).unwrap();
        }

        // typed admission errors on the borrowed path
        assert_eq!(
            direct.submit_borrowed("nope", &[5], None),
            Err(SubmitError::UnknownTask)
        );
        assert_eq!(
            direct.submit_borrowed("sst2", &[5, -1], None),
            Err(SubmitError::TokenOutOfVocab)
        );
        assert_eq!(
            direct.submit_borrowed("sst2", &[5], Some(&[100_000])),
            Err(SubmitError::TokenOutOfVocab)
        );
        assert_eq!(direct.direct_pending(), 0);

        // two waves (one short, one full) must match the owned queue path
        let reqs: Vec<(&str, Vec<i32>, Option<Vec<i32>>)> = vec![
            ("sst2", vec![7, 8, 9], None),
            ("rte", vec![10, 11], Some(vec![12, 13, 14])),
            ("sst2", vec![15], Some(vec![])),
            ("rte", vec![], None),
            ("sst2", (0..40).map(|i| 20 + i).collect(), Some(vec![4, 5])),
        ];
        let mut owned_replies = Vec::new();
        for (task, a, b) in &reqs {
            owned
                .submit(ServeRequest {
                    task: (*task).into(),
                    seq_a: a.clone(),
                    seq_b: b.clone(),
                })
                .unwrap();
        }
        owned_replies.extend(owned.run_pending().unwrap());

        let mut direct_out: Vec<(u64, String, Vec<f32>, usize)> = Vec::new();
        for chunk in reqs.chunks(3) {
            for (task, a, b) in chunk {
                direct.submit_borrowed(task, a, b.as_deref()).unwrap();
            }
            let n = direct.run_direct().unwrap();
            assert_eq!(n, chunk.len());
            direct_out.extend(
                direct
                    .direct_replies()
                    .map(|r| (r.id, r.task.to_string(), r.logits.to_vec(), r.label)),
            );
        }
        assert_eq!(direct_out.len(), owned_replies.len());
        for (o, d) in owned_replies.iter().zip(&direct_out) {
            assert_eq!(o.id, d.0);
            assert_eq!(o.task, d.1);
            assert_eq!(o.logits, d.2, "borrowed path must serve identical logits");
            assert_eq!(o.label, d.3);
        }

        // a full queue sheds further admissions with a typed error —
        // from both submit paths, which share the one bounded queue
        direct
            .set_policy(ServePolicy { queue_cap: 3, ..ServePolicy::default() })
            .unwrap();
        for _ in 0..3 {
            direct.submit_borrowed("sst2", &[5], None).unwrap();
        }
        assert!(direct.queue_full());
        assert_eq!(
            direct.submit_borrowed("sst2", &[6], None),
            Err(SubmitError::QueueFull)
        );
        let err = direct
            .submit(ServeRequest { task: "sst2".into(), seq_a: vec![5], seq_b: None })
            .unwrap_err();
        assert!(err.to_string().contains("queue is full"), "{err}");
        // policy changes are refused while rows are queued
        assert!(direct.set_policy(ServePolicy::default()).is_err());
        assert_eq!(direct.run_direct().unwrap(), 3);
        assert!(direct.run_pending().unwrap().is_empty());
        direct.set_policy(ServePolicy::default()).unwrap();
    }

    #[test]
    fn wrr_wave_assembly_interleaves_tenants() {
        let (engine, store) = setup();
        let info = engine.manifest().model("tiny").unwrap().clone();
        let tasks = vec!["sst2".to_string(), "rte".to_string()];
        let adapters = synthetic_adapters(&info, &store, &tasks, 5).unwrap();
        let mut s = ServeSession::new(&engine, "tiny", &store, 2).unwrap();
        for a in adapters {
            s.register_task(a).unwrap();
        }
        // three rows of one tenant then one of another, wave size 2:
        // round-robin gives the lone rte row the first wave's second
        // slot instead of parking it behind the sst2 backlog
        for (t, tok) in [("sst2", 5), ("sst2", 6), ("sst2", 7), ("rte", 8)] {
            s.submit_borrowed(t, &[tok], None).unwrap();
        }
        assert_eq!(s.run_direct().unwrap(), 4);
        let waves: Vec<u32> = s.direct_replies().map(|r| r.wave).collect();
        assert_eq!(waves, vec![0, 1, 1, 0], "rte jumps the backlog into wave 0");
        // replies still iterate in arrival order with ids intact
        let ids: Vec<u64> = s.direct_replies().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        assert_eq!(s.stats().batches, 2);
        assert_eq!(s.stats().admitted, 4);
    }

    #[test]
    fn token_buckets_throttle_per_tenant() {
        let (engine, store) = setup();
        let info = engine.manifest().model("tiny").unwrap().clone();
        let tasks = vec!["sst2".to_string(), "rte".to_string()];
        let adapters = synthetic_adapters(&info, &store, &tasks, 5).unwrap();
        let mut s = ServeSession::new(&engine, "tiny", &store, 2).unwrap();
        for a in adapters {
            s.register_task(a).unwrap();
        }
        s.set_policy(ServePolicy {
            queue_cap: 8,
            tenant_rps: 1,
            tenant_burst: 1,
            ..ServePolicy::default()
        })
        .unwrap();
        s.submit_borrowed("sst2", &[5], None).unwrap();
        match s.submit_borrowed("sst2", &[6], None) {
            Err(SubmitError::Throttled(ms)) => {
                assert!((1..=1000).contains(&ms), "retry hint {ms} ms out of range");
            }
            other => panic!("expected a throttle, got {other:?}"),
        }
        // a different tenant draws from its own bucket
        s.submit_borrowed("rte", &[7], None).unwrap();
        assert_eq!(s.run_direct().unwrap(), 2);
        assert_eq!(s.stats().admitted, 2);
    }

    #[test]
    fn session_serves_mixed_tasks_and_counts() {
        let (engine, store) = setup();
        let info = engine.manifest().model("tiny").unwrap().clone();
        let mut s = ServeSession::new(&engine, "tiny", &store, 4).unwrap();
        let mut a = TaskAdapter::from_store(&info, &store, "a", 2).unwrap();
        for v in a.had_b[0].iter_mut() {
            *v += 0.3;
        }
        let b = TaskAdapter::from_store(&info, &store, "b", 3).unwrap();
        s.register_task(a).unwrap();
        s.register_task(b).unwrap();

        assert!(
            s.submit(ServeRequest { task: "nope".into(), seq_a: vec![7, 8], seq_b: None })
                .is_err(),
            "unregistered tasks must be rejected at submit"
        );
        assert!(
            s.submit(ServeRequest { task: "a".into(), seq_a: vec![7, 100_000], seq_b: None })
                .is_err(),
            "out-of-vocab tokens must be rejected at submit, not poison a batch"
        );
        assert!(
            s.submit(ServeRequest {
                task: "a".into(),
                seq_a: vec![7],
                seq_b: Some(vec![-3]),
            })
            .is_err(),
            "negative ids in the pair sentence are rejected too"
        );
        assert_eq!(s.pending(), 0, "rejected requests never enter the queue");

        let mut ids = Vec::new();
        for i in 0..6 {
            let task = if i % 2 == 0 { "a" } else { "b" };
            ids.push(
                s.submit(ServeRequest {
                    task: task.into(),
                    seq_a: vec![10 + i as i32, 20, 30],
                    seq_b: if i % 3 == 0 { Some(vec![40, 41]) } else { None },
                })
                .unwrap(),
            );
        }
        assert_eq!(s.pending(), 6);
        let replies = s.run_pending().unwrap();
        assert_eq!(s.pending(), 0);
        assert_eq!(replies.len(), 6);
        for (r, id) in replies.iter().zip(&ids) {
            assert_eq!(r.id, *id, "replies come back in submit order");
            assert_eq!(r.logits.len(), 3);
            assert!(r.logits.iter().all(|v| v.is_finite()));
            assert!(r.latency_s >= 0.0);
            let active = if r.task == "a" { 2 } else { 3 };
            assert!(r.label < active, "label masked to the task's classes");
        }
        let st = s.stats();
        assert_eq!(st.requests, 6);
        assert_eq!(st.batches, 2, "6 requests at max_batch=4 -> 2 batches");
        assert_eq!(st.padded_rows, 2, "the second batch pads 2 rows");
    }
}
