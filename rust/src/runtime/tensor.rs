//! Host-side tensors (and, behind the `xla` feature, conversions to/from
//! PJRT literals/buffers).
//!
//! Everything on the Rust hot path is f32 or i32; the `Tensor` type is a
//! minimal dense array (shape + contiguous Vec) with just the operations
//! the coordinator needs. The heavy math lives in `runtime::kernels` for
//! the native backend, or in the HLO artifacts for the XLA backend.

use anyhow::{bail, Result};

#[cfg(feature = "xla")]
use xla::{ElementType, Literal, PjRtBuffer, PjRtClient};

/// Dense f32 tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Dimension sizes (row-major).
    pub shape: Vec<usize>,
    /// Flat row-major storage.
    pub data: Vec<f32>,
}

impl Tensor {
    /// A tensor from a shape and matching flat data.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(Tensor { shape, data })
    }

    /// A zero-filled tensor.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// A ones-filled tensor.
    pub fn ones(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![1.0; n] }
    }

    /// A rank-0 (single-element) tensor.
    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    /// Total scalars.
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// L2 norm (used by grad-clip and the analysis module).
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Convert to an XLA literal (zero intermediate copies beyond the one
    /// XLA makes internally).
    #[cfg(feature = "xla")]
    pub fn to_literal(&self) -> Result<Literal> {
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(
                self.data.as_ptr() as *const u8,
                self.data.len() * 4,
            )
        };
        Ok(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &self.shape,
            bytes,
        )?)
    }

    /// Upload directly host -> device.
    #[cfg(feature = "xla")]
    pub fn to_buffer(&self, client: &PjRtClient) -> Result<PjRtBuffer> {
        Ok(client.buffer_from_host_buffer::<f32>(&self.data, &self.shape, None)?)
    }

    #[cfg(feature = "xla")]
    /// Copy a device literal back into a host tensor (XLA path).
    pub fn from_literal(lit: &Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Tensor::new(dims, data)
    }
}

/// Dense i32 tensor (token ids, labels).
#[derive(Debug, Clone, PartialEq)]
pub struct IntTensor {
    /// Dimension sizes (row-major).
    pub shape: Vec<usize>,
    /// Flat row-major storage.
    pub data: Vec<i32>,
}

impl IntTensor {
    /// An integer tensor from a shape and matching flat data.
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(IntTensor { shape, data })
    }

    /// A zero-filled integer tensor.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        IntTensor { shape, data: vec![0; n] }
    }

    #[cfg(feature = "xla")]
    /// Convert to a device literal (XLA path).
    pub fn to_literal(&self) -> Result<Literal> {
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(
                self.data.as_ptr() as *const u8,
                self.data.len() * 4,
            )
        };
        Ok(Literal::create_from_shape_and_untyped_data(
            ElementType::S32,
            &self.shape,
            bytes,
        )?)
    }

    #[cfg(feature = "xla")]
    /// Upload to a PJRT device buffer (XLA path).
    pub fn to_buffer(&self, client: &PjRtClient) -> Result<PjRtBuffer> {
        Ok(client.buffer_from_host_buffer::<i32>(&self.data, &self.shape, None)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert_eq!(Tensor::zeros(vec![4, 4]).numel(), 16);
        assert_eq!(Tensor::ones(vec![3]).data, vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn norm() {
        let t = Tensor::new(vec![2], vec![3.0, 4.0]).unwrap();
        assert!((t.norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn int_tensor_shape_checks() {
        let t = IntTensor::new(vec![3], vec![7, -1, 2]).unwrap();
        assert_eq!(t.data, vec![7, -1, 2]);
        assert!(IntTensor::new(vec![2, 2], vec![1, 2, 3]).is_err());
        assert_eq!(IntTensor::zeros(vec![2, 2]).data, vec![0; 4]);
    }

    #[test]
    fn scalar_tensor() {
        let t = Tensor::scalar(2.5);
        assert_eq!(t.numel(), 1);
        assert!(t.shape.is_empty());
        assert_eq!(t.data[0], 2.5);
    }
}
