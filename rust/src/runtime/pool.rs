//! `runtime::pool`: a persistent fork-join worker pool for the native
//! kernels.
//!
//! The kernels in [`super::kernels`] are data-parallel over output rows
//! (matmul), batch×head blocks (attention) or elements (GELU). A [`Pool`]
//! carries the configured worker count (the `threads` config key; `0`
//! auto-detects one worker per core, overridable with the
//! `HADAPT_THREADS` env var) and provides fork-join over disjoint
//! row-chunks of the output buffers.
//!
//! # Persistent workers (PR 4)
//!
//! PR 2's pool spawned and joined OS threads via `std::thread::scope` on
//! every parallel kernel call — dozens of spawn/join cycles per train
//! step, which dominates dispatch cost at the small shapes the GLUE-style
//! tasks use. The pool now keeps `threads - 1` long-lived workers parked
//! on a condvar. A dispatch publishes a type-erased *borrowed* job (raw
//! chunk-partition descriptor + a pointer to the caller's closure), bumps
//! an epoch counter and wakes the workers; workers claim chunk indices
//! under the job-slot mutex, the caller runs the reserved last chunk
//! itself (then helps drain unclaimed chunks), and everyone meets at a
//! completion latch before the dispatch returns. Consequences:
//!
//! * **Zero steady-state spawns**: workers are spawned lazily on the
//!   first parallel dispatch and then reused until the last [`Pool`]
//!   clone drops (workers are joined on drop). [`PoolStats`] counts
//!   spawns / dispatches / wakeups so the property is testable.
//! * **Zero dispatch allocations**: the job descriptor lives on the
//!   caller's stack (PR 2 collected a `Vec` of `chunks_mut` slices per
//!   call), so the threaded path now satisfies the same counting-
//!   allocator test as the serial one (`tests/workspace_alloc.rs`).
//! * **Work below `grain` never wakes anyone** — tiny kernels (LoRA
//!   rank-4 GEMMs, head projections) run inline on the caller, exactly
//!   as before.
//! * **Worker panics propagate**: a panicking chunk poisons the job; the
//!   dispatching caller still drains the latch (no hang, no dangling
//!   borrows) and then panics itself.
//!
//! The chunk partition is unchanged from PR 2 — a pure function of
//! `(rows, threads)` — so results are deterministic for a fixed thread
//! count; across *different* thread counts only the order of float
//! reductions in activation rows can differ, at ~1e-7 relative, and
//! parameter-gradient reductions are serial (PR 3) and bit-identical for
//! every count. Set `threads=1` for bit-reproducibility across machines;
//! `threads<=1` pools never spawn anything.
//!
//! `map_rows` (chunk-ordered partial reductions) was removed in PR 4: no
//! kernel has used it since the PR 3 parameter reductions went serial,
//! and keeping it would have reintroduced a thread-count-dependent merge
//! order for any future caller.
//!
//! # Safety
//!
//! This module contains the runtime pool's only `unsafe` (the repo's
//! other `unsafe` blocks are byte-cast helpers in `runtime::tensor` and
//! `model::store`): handing a borrowed job to long-lived threads erases
//! lifetimes, so the two invariants are (1)
//! chunk indices partition the output buffers disjointly — the partition
//! arithmetic below mirrors `chunks_mut` exactly — and (2) the job
//! descriptor outlives every access, which the completion latch enforces:
//! a worker only dereferences the descriptor for a chunk it claimed from
//! the *current* job slot under the mutex, and the dispatching caller
//! cannot return (or unwind) until `pending` reaches zero, i.e. until
//! every claimed chunk has finished executing.

use std::fmt;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// Dispatch counters for a pool (and its clones, which share workers).
///
/// * `threads_spawned` — OS threads ever spawned; frozen after warmup
///   (the zero-spawn steady-state contract).
/// * `jobs_dispatched` — fork-join jobs published to the workers.
/// * `wakeups` — times a worker woke and observed a live job (a job can
///   wake more workers than it has chunks; the extras claim nothing and
///   park again).
/// * `inline_runs` — calls that stayed entirely on the caller (work
///   below `grain`, single-shard splits, or `threads <= 1`).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    /// Persistent workers ever spawned (freezes after warm-up).
    pub threads_spawned: u64,
    /// Fork-join jobs published to the workers.
    pub jobs_dispatched: u64,
    /// Worker wakeups across all jobs.
    pub wakeups: u64,
    /// Calls that ran entirely on the caller.
    pub inline_runs: u64,
}

/// Worker configuration handed to every parallel kernel. Cloning is
/// cheap and shares the same persistent workers and [`PoolStats`].
#[derive(Clone)]
pub struct Pool {
    threads: usize,
    scalar: bool,
    inner: Arc<Inner>,
}

impl fmt::Debug for Pool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Pool")
            .field("threads", &self.threads)
            .field("scalar", &self.scalar)
            .field("stats", &self.stats())
            .finish()
    }
}

impl Default for Pool {
    fn default() -> Self {
        Pool::auto()
    }
}

/// A published fork-join job: a monomorphized chunk runner plus a
/// type-erased pointer to the dispatch descriptor on the caller's stack.
/// Both fields are plain words so the slot stays `Send`.
#[derive(Clone, Copy)]
struct Job {
    /// Executes chunk `idx` of the job behind `data`.
    call: unsafe fn(usize, usize),
    /// `*const CtxN<F>` as usize; valid until the job's latch drains.
    data: usize,
}

/// The single job slot plus worker lifecycle flags, all guarded by one
/// mutex: every claim/completion transition happens under it, which is
/// what makes the borrowed-job lifetime argument airtight (chunks are at
/// least `grain` rows of kernel work, so the lock is uncontended noise
/// next to the work itself).
struct Slot {
    /// Bumped once per dispatch; parked workers wake on a change.
    epoch: u64,
    job: Option<Job>,
    /// Next chunk index a worker may claim (`0..claimable`).
    next: usize,
    /// Chunks available to workers; the final chunk (`claimable`) is
    /// reserved for the dispatching caller.
    claimable: usize,
    /// Chunks not yet finished executing — the completion latch.
    pending: usize,
    /// Set when any chunk panicked; the caller re-raises after the latch.
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    slot: Mutex<Slot>,
    /// Workers park here waiting for a new epoch.
    work_cv: Condvar,
    /// The dispatching caller parks here waiting for `pending == 0`.
    done_cv: Condvar,
    spawned: AtomicU64,
    dispatched: AtomicU64,
    wakeups: AtomicU64,
    inline_runs: AtomicU64,
}

/// Owns the worker handles; dropping the last `Pool` clone signals
/// shutdown and joins every worker.
struct Inner {
    shared: Arc<Shared>,
    workers: Mutex<Vec<thread::JoinHandle<()>>>,
    /// Fast-path flag so the steady-state dispatch skips the `workers`
    /// mutex entirely: set once (release) after the workers exist, read
    /// (acquire) on every dispatch. Spawning happens at most once.
    workers_ready: AtomicBool,
    /// Serializes concurrent dispatchers: the slot holds one job at a
    /// time, so a second thread calling `for_rows*` on the same pool (or
    /// a clone) queues here until the first job's latch drains. Held
    /// across the whole dispatch — which also means a job's closure must
    /// not dispatch on its own pool (no kernel does; nested fan-out
    /// would self-deadlock by design rather than corrupt the slot).
    dispatch_lock: Mutex<()>,
}

impl Drop for Inner {
    fn drop(&mut self) {
        {
            let mut slot = lock(&self.shared.slot);
            slot.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        let workers = match self.workers.get_mut() {
            Ok(w) => w,
            Err(p) => p.into_inner(),
        };
        for h in workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn lock(m: &Mutex<Slot>) -> std::sync::MutexGuard<'_, Slot> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

fn worker_loop(shared: Arc<Shared>) {
    let mut seen = 0u64;
    let mut guard = lock(&shared.slot);
    loop {
        if guard.shutdown {
            return;
        }
        if guard.epoch == seen {
            guard = shared.work_cv.wait(guard).unwrap_or_else(|p| p.into_inner());
            continue;
        }
        seen = guard.epoch;
        if guard.job.is_none() {
            // Slept through an entire job; nothing left to do for it.
            continue;
        }
        shared.wakeups.fetch_add(1, Ordering::Relaxed);
        while let Some(job) = guard.job {
            if guard.next >= guard.claimable {
                break;
            }
            let idx = guard.next;
            guard.next += 1;
            drop(guard);
            // SAFETY: `idx` was claimed from the live job under the slot
            // mutex; the caller is blocked on the latch until this chunk
            // completes, so `job.data` is valid and chunk `idx` is ours
            // exclusively.
            let run = || unsafe { (job.call)(job.data, idx) };
            let ok = panic::catch_unwind(AssertUnwindSafe(run)).is_ok();
            guard = lock(&shared.slot);
            if !ok {
                guard.panicked = true;
            }
            guard.pending -= 1;
            if guard.pending == 0 {
                shared.done_cv.notify_one();
            }
        }
    }
}

impl Pool {
    /// One worker per available core.
    pub fn auto() -> Pool {
        Pool::with_threads(0)
    }

    /// Fixed worker count; `0` auto-detects (the `HADAPT_THREADS` env
    /// var when set, else `available_parallelism` — identical to the
    /// PR 2 resolution when the env var is absent). Workers are spawned
    /// lazily on the first parallel dispatch, never at construction.
    pub fn with_threads(threads: usize) -> Pool {
        let t = if threads == 0 { auto_threads() } else { threads };
        Pool::build(t.max(1), false)
    }

    /// Single-threaded blocked kernels (no fan-out, fully deterministic).
    pub fn serial() -> Pool {
        Pool::with_threads(1)
    }

    /// Dispatch to the retained PR-1 scalar kernels, single-threaded — the
    /// baseline `cargo bench --bench bench_runtime` compares against.
    pub fn scalar_reference() -> Pool {
        Pool::build(1, true)
    }

    fn build(threads: usize, scalar: bool) -> Pool {
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot {
                epoch: 0,
                job: None,
                next: 0,
                claimable: 0,
                pending: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            spawned: AtomicU64::new(0),
            dispatched: AtomicU64::new(0),
            wakeups: AtomicU64::new(0),
            inline_runs: AtomicU64::new(0),
        });
        let inner = Inner {
            shared,
            workers: Mutex::new(Vec::new()),
            workers_ready: AtomicBool::new(false),
            dispatch_lock: Mutex::new(()),
        };
        Pool { threads, scalar, inner: Arc::new(inner) }
    }

    /// Configured worker count (including the caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// True when kernels should route to `kernels::scalar`.
    pub fn is_scalar(&self) -> bool {
        self.scalar
    }

    /// Snapshot of the dispatch counters (shared across clones).
    pub fn stats(&self) -> PoolStats {
        let s = &self.inner.shared;
        PoolStats {
            threads_spawned: s.spawned.load(Ordering::Relaxed),
            jobs_dispatched: s.dispatched.load(Ordering::Relaxed),
            wakeups: s.wakeups.load(Ordering::Relaxed),
            inline_runs: s.inline_runs.load(Ordering::Relaxed),
        }
    }

    /// Shard count for `items` work items with at least `grain` each.
    fn shards(&self, items: usize, grain: usize) -> usize {
        if items == 0 || self.threads <= 1 {
            return 1;
        }
        let g = grain.max(1);
        let cap = (items + g - 1) / g;
        self.threads.min(cap)
    }

    fn note_inline(&self) {
        self.inner.shared.inline_runs.fetch_add(1, Ordering::Relaxed);
    }

    /// Spawn the `threads - 1` persistent workers if they don't exist
    /// yet. Steady state takes only the relaxed-cost atomic fast path —
    /// the `workers` mutex is touched once per pool lifetime.
    fn ensure_workers(&self) {
        if self.threads <= 1 || self.inner.workers_ready.load(Ordering::Acquire) {
            return;
        }
        let mut ws = self.inner.workers.lock().unwrap_or_else(|p| p.into_inner());
        if !ws.is_empty() {
            return;
        }
        for i in 0..self.threads - 1 {
            let shared = Arc::clone(&self.inner.shared);
            let h = thread::Builder::new()
                .name(format!("hadapt-pool-{i}"))
                .spawn(move || worker_loop(shared))
                .expect("failed to spawn a pool worker");
            ws.push(h);
        }
        self.inner.shared.spawned.fetch_add((self.threads - 1) as u64, Ordering::Relaxed);
        self.inner.workers_ready.store(true, Ordering::Release);
    }

    /// Publish a job of `nch >= 2` chunks, run the reserved last chunk on
    /// the calling thread, help drain unclaimed chunks, and wait for the
    /// completion latch. Re-raises if any chunk panicked.
    fn dispatch(&self, nch: usize, call: unsafe fn(usize, usize), data: usize) {
        debug_assert!(nch >= 2);
        self.ensure_workers();
        let _serialized = self.inner.dispatch_lock.lock().unwrap_or_else(|p| p.into_inner());
        let shared = &self.inner.shared;
        {
            let mut slot = lock(&shared.slot);
            slot.epoch = slot.epoch.wrapping_add(1);
            slot.job = Some(Job { call, data });
            slot.next = 0;
            slot.claimable = nch - 1;
            slot.pending = nch;
            slot.panicked = false;
            shared.work_cv.notify_all();
        }
        shared.dispatched.fetch_add(1, Ordering::Relaxed);
        // SAFETY: chunk `nch - 1` is reserved for the caller (never
        // claimable), and `data` points into this stack frame.
        let last = || unsafe { call(data, nch - 1) };
        let mut poisoned = panic::catch_unwind(AssertUnwindSafe(last)).is_err();
        let mut slot = lock(&shared.slot);
        slot.pending -= 1;
        // Help drain chunks no worker has claimed yet (covers workers
        // that are still waking up, or a pool whose workers are busy).
        while slot.next < slot.claimable {
            let idx = slot.next;
            slot.next += 1;
            drop(slot);
            // SAFETY: same claim discipline as the workers.
            let run = || unsafe { call(data, idx) };
            if panic::catch_unwind(AssertUnwindSafe(run)).is_err() {
                poisoned = true;
            }
            slot = lock(&shared.slot);
            slot.pending -= 1;
        }
        while slot.pending > 0 {
            slot = shared.done_cv.wait(slot).unwrap_or_else(|p| p.into_inner());
        }
        poisoned |= slot.panicked;
        slot.job = None;
        drop(slot);
        if poisoned {
            panic!("pool worker panicked during a fork-join job");
        }
    }

    /// Run `f(first_row, chunk)` over disjoint row-chunks of `out`
    /// (`cols` floats per row). The final chunk runs on the caller, so a
    /// 2-shard split wakes exactly one worker — and work below `grain`
    /// wakes none.
    pub fn for_rows<F>(&self, out: &mut [f32], cols: usize, grain: usize, f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        let rows = if cols == 0 { 0 } else { out.len() / cols };
        let shards = self.shards(rows, grain);
        if shards <= 1 {
            self.note_inline();
            f(0, out);
            return;
        }
        let chunk = (rows + shards - 1) / shards;
        let nch = (rows + chunk - 1) / chunk;
        let ctx = Ctx1 { base: out.as_mut_ptr(), len: out.len(), cols, chunk, nch, f: &f };
        self.dispatch(nch, run_chunk1::<F>, &ctx as *const Ctx1<F> as usize);
    }

    /// Two parallel output buffers with per-item widths `acols` / `bcols`
    /// (attention: `out [L, D]` + `probs [L, L]` per batch×head block).
    /// Both widths must be non-zero.
    pub fn for_rows2<F>(
        &self,
        a: &mut [f32],
        acols: usize,
        b: &mut [f32],
        bcols: usize,
        grain: usize,
        f: F,
    ) where
        F: Fn(usize, &mut [f32], &mut [f32]) + Sync,
    {
        let items = if acols == 0 { 0 } else { a.len() / acols };
        debug_assert_eq!(items * bcols, b.len());
        let shards = self.shards(items, grain);
        if shards <= 1 {
            self.note_inline();
            f(0, a, b);
            return;
        }
        let chunk = (items + shards - 1) / shards;
        let nch = (items + chunk - 1) / chunk;
        let ctx = Ctx2 {
            a: Buf { base: a.as_mut_ptr(), len: a.len(), cols: acols },
            b: Buf { base: b.as_mut_ptr(), len: b.len(), cols: bcols },
            chunk,
            nch,
            f: &f,
        };
        self.dispatch(nch, run_chunk2::<F>, &ctx as *const Ctx2<F> as usize);
    }

    /// Three parallel output buffers (LayerNorm `y`/`xhat`/`inv`). All
    /// widths must be non-zero.
    #[allow(clippy::too_many_arguments)]
    pub fn for_rows3<F>(
        &self,
        a: &mut [f32],
        acols: usize,
        b: &mut [f32],
        bcols: usize,
        c: &mut [f32],
        ccols: usize,
        grain: usize,
        f: F,
    ) where
        F: Fn(usize, &mut [f32], &mut [f32], &mut [f32]) + Sync,
    {
        let items = if acols == 0 { 0 } else { a.len() / acols };
        debug_assert_eq!(items * bcols, b.len());
        debug_assert_eq!(items * ccols, c.len());
        let shards = self.shards(items, grain);
        if shards <= 1 {
            self.note_inline();
            f(0, a, b, c);
            return;
        }
        let chunk = (items + shards - 1) / shards;
        let nch = (items + chunk - 1) / chunk;
        let ctx = Ctx3 {
            a: Buf { base: a.as_mut_ptr(), len: a.len(), cols: acols },
            b: Buf { base: b.as_mut_ptr(), len: b.len(), cols: bcols },
            c: Buf { base: c.as_mut_ptr(), len: c.len(), cols: ccols },
            chunk,
            nch,
            f: &f,
        };
        self.dispatch(nch, run_chunk3::<F>, &ctx as *const Ctx3<F> as usize);
    }

    /// Four parallel output buffers (attention VJP `dq`/`dk`/`dv` plus its
    /// per-item `dprobs` scratch slab). All widths must be non-zero.
    #[allow(clippy::too_many_arguments)]
    pub fn for_rows4<F>(
        &self,
        a: &mut [f32],
        acols: usize,
        b: &mut [f32],
        bcols: usize,
        c: &mut [f32],
        ccols: usize,
        d: &mut [f32],
        dcols: usize,
        grain: usize,
        f: F,
    ) where
        F: Fn(usize, &mut [f32], &mut [f32], &mut [f32], &mut [f32]) + Sync,
    {
        let items = if acols == 0 { 0 } else { a.len() / acols };
        debug_assert_eq!(items * bcols, b.len());
        debug_assert_eq!(items * ccols, c.len());
        debug_assert_eq!(items * dcols, d.len());
        let shards = self.shards(items, grain);
        if shards <= 1 {
            self.note_inline();
            f(0, a, b, c, d);
            return;
        }
        let chunk = (items + shards - 1) / shards;
        let nch = (items + chunk - 1) / chunk;
        let ctx = Ctx4 {
            a: Buf { base: a.as_mut_ptr(), len: a.len(), cols: acols },
            b: Buf { base: b.as_mut_ptr(), len: b.len(), cols: bcols },
            c: Buf { base: c.as_mut_ptr(), len: c.len(), cols: ccols },
            d: Buf { base: d.as_mut_ptr(), len: d.len(), cols: dcols },
            chunk,
            nch,
            f: &f,
        };
        self.dispatch(nch, run_chunk4::<F>, &ctx as *const Ctx4<F> as usize);
    }
}

/// Resolve the auto worker count: `HADAPT_THREADS` (CI's serial test run
/// sets it to 1) when present and positive, else one per available core.
fn auto_threads() -> usize {
    let forced = std::env::var("HADAPT_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0);
    match forced {
        Some(n) => n,
        None => thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

// ------------------------------------------------ type-erased dispatch ctxs

/// One output buffer's partition geometry inside a job descriptor.
#[derive(Clone, Copy)]
struct Buf {
    base: *mut f32,
    len: usize,
    cols: usize,
}

/// Chunk `idx` of `nch` for a buffer — identical arithmetic to
/// `chunks_mut(chunk * cols)` on exact-multiple buffers (every kernel's
/// case), so the partition (and therefore every per-chunk float
/// reduction order) matches the PR 2 scoped pool exactly. The final
/// chunk absorbs any trailing partial row, so coverage is total even
/// for a length that is not a multiple of `cols`.
///
/// # Safety
/// Caller must hold a claimed chunk index of a live job whose buffers the
/// descriptor describes; disjointness follows from unique `idx` claims.
unsafe fn chunk_of<'s>(b: &Buf, chunk: usize, nch: usize, idx: usize) -> &'s mut [f32] {
    let start = (idx * chunk * b.cols).min(b.len);
    let end = if idx + 1 == nch { b.len } else { ((idx + 1) * chunk * b.cols).min(b.len) };
    std::slice::from_raw_parts_mut(b.base.add(start), end - start)
}

struct Ctx1<F> {
    base: *mut f32,
    len: usize,
    cols: usize,
    chunk: usize,
    nch: usize,
    f: *const F,
}

unsafe fn run_chunk1<F: Fn(usize, &mut [f32]) + Sync>(data: usize, idx: usize) {
    let ctx = &*(data as *const Ctx1<F>);
    let b = Buf { base: ctx.base, len: ctx.len, cols: ctx.cols };
    let f = &*ctx.f;
    f(idx * ctx.chunk, chunk_of(&b, ctx.chunk, ctx.nch, idx));
}

struct Ctx2<F> {
    a: Buf,
    b: Buf,
    chunk: usize,
    nch: usize,
    f: *const F,
}

unsafe fn run_chunk2<F: Fn(usize, &mut [f32], &mut [f32]) + Sync>(data: usize, idx: usize) {
    let ctx = &*(data as *const Ctx2<F>);
    let f = &*ctx.f;
    let row0 = idx * ctx.chunk;
    f(
        row0,
        chunk_of(&ctx.a, ctx.chunk, ctx.nch, idx),
        chunk_of(&ctx.b, ctx.chunk, ctx.nch, idx),
    );
}

struct Ctx3<F> {
    a: Buf,
    b: Buf,
    c: Buf,
    chunk: usize,
    nch: usize,
    f: *const F,
}

unsafe fn run_chunk3<F: Fn(usize, &mut [f32], &mut [f32], &mut [f32]) + Sync>(
    data: usize,
    idx: usize,
) {
    let ctx = &*(data as *const Ctx3<F>);
    let f = &*ctx.f;
    f(
        idx * ctx.chunk,
        chunk_of(&ctx.a, ctx.chunk, ctx.nch, idx),
        chunk_of(&ctx.b, ctx.chunk, ctx.nch, idx),
        chunk_of(&ctx.c, ctx.chunk, ctx.nch, idx),
    );
}

struct Ctx4<F> {
    a: Buf,
    b: Buf,
    c: Buf,
    d: Buf,
    chunk: usize,
    nch: usize,
    f: *const F,
}

unsafe fn run_chunk4<F: Fn(usize, &mut [f32], &mut [f32], &mut [f32], &mut [f32]) + Sync>(
    data: usize,
    idx: usize,
) {
    let ctx = &*(data as *const Ctx4<F>);
    let f = &*ctx.f;
    f(
        idx * ctx.chunk,
        chunk_of(&ctx.a, ctx.chunk, ctx.nch, idx),
        chunk_of(&ctx.b, ctx.chunk, ctx.nch, idx),
        chunk_of(&ctx.c, ctx.chunk, ctx.nch, idx),
        chunk_of(&ctx.d, ctx.chunk, ctx.nch, idx),
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn with_threads_resolves_auto() {
        assert!(Pool::auto().threads() >= 1);
        assert_eq!(Pool::with_threads(3).threads(), 3);
        assert_eq!(Pool::serial().threads(), 1);
        assert!(Pool::scalar_reference().is_scalar());
        assert!(!Pool::with_threads(4).is_scalar());
    }

    #[test]
    fn auto_detect_matches_pr2_resolution() {
        // `threads=0` resolves exactly as PR 2 did (available_parallelism)
        // unless the HADAPT_THREADS override is present — the CI serial
        // run sets it, so the expectation is computed the same way.
        let want = std::env::var("HADAPT_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
        assert_eq!(Pool::auto().threads(), want);
        assert_eq!(Pool::with_threads(0).threads(), want);
    }

    #[test]
    fn for_rows_covers_every_row_once() {
        for threads in [1, 2, 3, 7] {
            let pool = Pool::with_threads(threads);
            let cols = 3;
            let mut out = vec![0.0f32; 25 * cols];
            pool.for_rows(&mut out, cols, 1, |row0, chunk| {
                for (r, row) in chunk.chunks_exact_mut(cols).enumerate() {
                    for v in row.iter_mut() {
                        *v += (row0 + r) as f32 + 1.0;
                    }
                }
            });
            for (r, row) in out.chunks_exact(cols).enumerate() {
                for &v in row {
                    assert_eq!(v, r as f32 + 1.0, "threads={threads} row={r}");
                }
            }
        }
    }

    #[test]
    fn for_rows_respects_grain() {
        // 4 rows at grain 8 must stay on the caller (single chunk at 0)
        let pool = Pool::with_threads(8);
        let mut out = vec![0.0f32; 4];
        let seen = Mutex::new(Vec::new());
        pool.for_rows(&mut out, 1, 8, |row0, chunk| {
            seen.lock().unwrap().push((row0, chunk.len()));
        });
        assert_eq!(*seen.lock().unwrap(), vec![(0, 4)]);
        let st = pool.stats();
        assert_eq!(st.inline_runs, 1);
        assert_eq!(st.jobs_dispatched, 0, "below-grain work must not dispatch");
        assert_eq!(st.threads_spawned, 0, "below-grain work must not even spawn");
    }

    #[test]
    fn chunks_tile_rows_in_order() {
        let pool = Pool::with_threads(4);
        let mut out = vec![0.0f32; 100];
        let seen = Mutex::new(Vec::new());
        pool.for_rows(&mut out, 1, 1, |row0, chunk| {
            seen.lock().unwrap().push((row0, chunk.len()));
        });
        let mut parts = seen.into_inner().unwrap();
        parts.sort_unstable();
        let mut expect = 0usize;
        let mut total = 0usize;
        for (row0, len) in parts {
            assert_eq!(row0, expect);
            expect += len;
            total += len;
        }
        assert_eq!(total, 100);
    }

    #[test]
    fn for_rows2_and_3_split_consistently() {
        let pool = Pool::with_threads(3);
        let items = 10;
        let (wa, wb, wc) = (2, 5, 1);
        let mut a = vec![0.0f32; items * wa];
        let mut b = vec![0.0f32; items * wb];
        let mut c = vec![0.0f32; items * wc];
        pool.for_rows2(&mut a, wa, &mut b, wb, 1, |i0, ca, cb| {
            assert_eq!(ca.len() / wa, cb.len() / wb);
            for v in ca.iter_mut() {
                *v = i0 as f32;
            }
            for v in cb.iter_mut() {
                *v = 1.0;
            }
        });
        assert!(b.iter().all(|&v| v == 1.0));
        pool.for_rows3(&mut a, wa, &mut b, wb, &mut c, wc, 1, |_, ca, cb, cc| {
            assert_eq!(ca.len() / wa, cc.len() / wc);
            assert_eq!(cb.len() / wb, cc.len() / wc);
            for v in cc.iter_mut() {
                *v = 2.0;
            }
        });
        assert!(c.iter().all(|&v| v == 2.0));
    }

    #[test]
    fn for_rows4_covers_items_once() {
        for threads in [1, 3] {
            let pool = Pool::with_threads(threads);
            let items = 7;
            let (wa, wb, wc, wd) = (2, 3, 1, 4);
            let mut a = vec![0.0f32; items * wa];
            let mut b = vec![0.0f32; items * wb];
            let mut c = vec![0.0f32; items * wc];
            let mut d = vec![0.0f32; items * wd];
            pool.for_rows4(
                &mut a,
                wa,
                &mut b,
                wb,
                &mut c,
                wc,
                &mut d,
                wd,
                1,
                |i0, ca, cb, cc, cd| {
                    assert_eq!(ca.len() / wa, cb.len() / wb);
                    assert_eq!(cc.len() / wc, cd.len() / wd);
                    for (r, item) in cc.chunks_exact_mut(wc).enumerate() {
                        item[0] += (i0 + r) as f32 + 1.0;
                    }
                    for v in cd.iter_mut() {
                        *v += 1.0;
                    }
                },
            );
            for (r, item) in c.chunks_exact(wc).enumerate() {
                assert_eq!(item[0], r as f32 + 1.0, "threads={threads} item={r}");
            }
            assert!(d.iter().all(|&v| v == 1.0));
        }
    }

    #[test]
    fn empty_output_is_fine() {
        let pool = Pool::with_threads(4);
        let mut out: Vec<f32> = Vec::new();
        pool.for_rows(&mut out, 4, 1, |_, chunk| assert!(chunk.is_empty()));
        assert_eq!(pool.stats().jobs_dispatched, 0);
    }

    #[test]
    fn workers_spawn_once_and_are_reused() {
        let pool = Pool::with_threads(3);
        assert_eq!(pool.stats().threads_spawned, 0, "spawn is lazy");
        let mut out = vec![0.0f32; 64];
        for i in 0..10 {
            pool.for_rows(&mut out, 1, 1, |row0, chunk| {
                for (r, v) in chunk.iter_mut().enumerate() {
                    *v = (row0 + r) as f32 + i as f32;
                }
            });
        }
        let st = pool.stats();
        assert_eq!(st.threads_spawned, 2, "exactly threads-1 workers, once");
        assert_eq!(st.jobs_dispatched, 10);
        for (r, v) in out.iter().enumerate() {
            assert_eq!(*v, r as f32 + 9.0);
        }
    }

    #[test]
    fn clones_share_workers_and_stats() {
        let pool = Pool::with_threads(2);
        let clone = pool.clone();
        let mut out = vec![0.0f32; 32];
        pool.for_rows(&mut out, 1, 1, |_, c| c.fill(1.0));
        clone.for_rows(&mut out, 1, 1, |_, c| c.fill(2.0));
        let st = pool.stats();
        assert_eq!(st, clone.stats());
        assert_eq!(st.threads_spawned, 1, "clones must reuse the same worker");
        assert_eq!(st.jobs_dispatched, 2);
        assert!(out.iter().all(|&v| v == 2.0));
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = Pool::with_threads(2);
        let mut out = vec![0.0f32; 32];
        let caught = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.for_rows(&mut out, 1, 1, |_, _| panic!("boom"));
        }));
        assert!(caught.is_err(), "a panicking chunk must raise at the dispatch site");
        // the pool is intact afterwards: the latch drained, the job slot
        // is clear, and the same workers still serve jobs
        pool.for_rows(&mut out, 1, 1, |_, c| c.fill(7.0));
        assert!(out.iter().all(|&v| v == 7.0));
        assert_eq!(pool.stats().threads_spawned, 1, "no respawn after a panic");
    }

    #[test]
    fn drop_while_idle_joins_cleanly() {
        // would hang (and time the suite out) if shutdown or join broke
        let pool = Pool::with_threads(4);
        let mut out = vec![0.0f32; 64];
        pool.for_rows(&mut out, 1, 1, |_, c| c.fill(1.0));
        assert_eq!(pool.stats().threads_spawned, 3);
        drop(pool);
        // dropping a never-dispatched pool is also clean (no workers)
        drop(Pool::with_threads(4));
        drop(Pool::serial());
    }

    #[test]
    fn results_identical_for_fixed_thread_count() {
        let run = |pool: &Pool| {
            let mut out = vec![0.0f32; 97 * 3];
            pool.for_rows(&mut out, 3, 2, |row0, chunk| {
                for (r, row) in chunk.chunks_exact_mut(3).enumerate() {
                    let t = (row0 + r) as f32;
                    row[0] = t * 1.5;
                    row[1] = t - 0.25;
                    row[2] = t * t;
                }
            });
            out
        };
        let a = run(&Pool::with_threads(3));
        let b = run(&Pool::with_threads(3));
        let serial = run(&Pool::serial());
        assert_eq!(a, b, "same thread count, same partition, same bits");
        assert_eq!(a, serial, "row-independent work matches serial exactly");
    }
}
