//! `runtime::pool`: a tiny std-only fork-join helper for the native
//! kernels.
//!
//! The kernels in [`super::kernels`] are data-parallel over output rows
//! (matmul), batch×head blocks (attention) or elements (GELU). A [`Pool`]
//! carries the configured worker count (the `threads` config key; `0`
//! auto-detects one worker per core) and provides safe scoped fork-join
//! over disjoint row-chunks of the output buffers — `std::thread::scope`
//! plus `chunks_mut`, no unsafe, no dependencies, and no persistent
//! worker threads to keep `Engine` trivially droppable.
//!
//! Work below `grain` rows stays on the calling thread, so tiny kernels
//! (LoRA rank-4 GEMMs, head projections) never pay a spawn. The chunk
//! partition is a pure function of `(rows, threads)`, so results are
//! deterministic for a fixed thread count; across *different* thread
//! counts only the order of float reductions (e.g. the Hadamard VJP's
//! `dw` partials) can differ, at ~1e-7 relative. Set `threads=1` for
//! bit-reproducibility across machines.

use std::thread;

/// Worker configuration handed to every parallel kernel.
#[derive(Debug, Clone)]
pub struct Pool {
    threads: usize,
    scalar: bool,
}

impl Default for Pool {
    fn default() -> Self {
        Pool::auto()
    }
}

impl Pool {
    /// One worker per available core.
    pub fn auto() -> Pool {
        Pool::with_threads(0)
    }

    /// Fixed worker count; `0` auto-detects (`available_parallelism`).
    pub fn with_threads(threads: usize) -> Pool {
        let t = if threads == 0 {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        Pool { threads: t.max(1), scalar: false }
    }

    /// Single-threaded blocked kernels (no fan-out, fully deterministic).
    pub fn serial() -> Pool {
        Pool::with_threads(1)
    }

    /// Dispatch to the retained PR-1 scalar kernels, single-threaded — the
    /// baseline `cargo bench --bench bench_runtime` compares against.
    pub fn scalar_reference() -> Pool {
        Pool { threads: 1, scalar: true }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// True when kernels should route to `kernels::scalar`.
    pub fn is_scalar(&self) -> bool {
        self.scalar
    }

    /// Shard count for `items` work items with at least `grain` each.
    fn shards(&self, items: usize, grain: usize) -> usize {
        if items == 0 || self.threads <= 1 {
            return 1;
        }
        let g = grain.max(1);
        let cap = (items + g - 1) / g;
        self.threads.min(cap)
    }

    /// Run `f(first_row, chunk)` over disjoint row-chunks of `out`
    /// (`cols` floats per row). The final chunk runs on the caller, so a
    /// 2-shard split costs exactly one spawn.
    pub fn for_rows<F>(&self, out: &mut [f32], cols: usize, grain: usize, f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        let rows = if cols == 0 { 0 } else { out.len() / cols };
        let shards = self.shards(rows, grain);
        if shards <= 1 {
            f(0, out);
            return;
        }
        let chunk = (rows + shards - 1) / shards;
        let fref = &f;
        thread::scope(move |s| {
            let chunks: Vec<&mut [f32]> = out.chunks_mut(chunk * cols).collect();
            let nch = chunks.len();
            for (idx, ch) in chunks.into_iter().enumerate() {
                let row0 = idx * chunk;
                if idx + 1 == nch {
                    fref(row0, ch);
                } else {
                    s.spawn(move || fref(row0, ch));
                }
            }
        });
    }

    /// Like [`Pool::for_rows`], but each shard also returns a value
    /// (partial reductions); results come back in chunk order. As of PR 3
    /// no kernel uses this — parameter reductions went serial for
    /// thread-count-independent results — but it remains part of the pool
    /// API for callers that want chunk-ordered partials.
    pub fn map_rows<T, F>(&self, out: &mut [f32], cols: usize, grain: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, &mut [f32]) -> T + Sync,
    {
        let rows = if cols == 0 { 0 } else { out.len() / cols };
        let shards = self.shards(rows, grain);
        if shards <= 1 {
            return vec![f(0, out)];
        }
        let chunk = (rows + shards - 1) / shards;
        let fref = &f;
        thread::scope(move |s| {
            let chunks: Vec<&mut [f32]> = out.chunks_mut(chunk * cols).collect();
            let nch = chunks.len();
            let mut handles = Vec::with_capacity(nch);
            let mut last = None;
            for (idx, ch) in chunks.into_iter().enumerate() {
                let row0 = idx * chunk;
                if idx + 1 == nch {
                    last = Some(fref(row0, ch));
                } else {
                    handles.push(s.spawn(move || fref(row0, ch)));
                }
            }
            let mut partials: Vec<T> = handles
                .into_iter()
                .map(|h| h.join().expect("pool worker panicked"))
                .collect();
            if let Some(v) = last {
                partials.push(v);
            }
            partials
        })
    }

    /// Two parallel output buffers with per-item widths `acols` / `bcols`
    /// (attention: `out [L, D]` + `probs [L, L]` per batch×head block).
    /// Both widths must be non-zero.
    pub fn for_rows2<F>(
        &self,
        a: &mut [f32],
        acols: usize,
        b: &mut [f32],
        bcols: usize,
        grain: usize,
        f: F,
    ) where
        F: Fn(usize, &mut [f32], &mut [f32]) + Sync,
    {
        let items = if acols == 0 { 0 } else { a.len() / acols };
        debug_assert_eq!(items * bcols, b.len());
        let shards = self.shards(items, grain);
        if shards <= 1 {
            f(0, a, b);
            return;
        }
        let chunk = (items + shards - 1) / shards;
        let fref = &f;
        thread::scope(move |s| {
            let ca: Vec<&mut [f32]> = a.chunks_mut(chunk * acols).collect();
            let cb: Vec<&mut [f32]> = b.chunks_mut(chunk * bcols).collect();
            let nch = ca.len();
            debug_assert_eq!(nch, cb.len());
            for (idx, (ha, hb)) in ca.into_iter().zip(cb).enumerate() {
                let i0 = idx * chunk;
                if idx + 1 == nch {
                    fref(i0, ha, hb);
                } else {
                    s.spawn(move || fref(i0, ha, hb));
                }
            }
        });
    }

    /// Three parallel output buffers (LayerNorm `y`/`xhat`/`inv`, attention
    /// VJP `dq`/`dk`/`dv`). All widths must be non-zero.
    #[allow(clippy::too_many_arguments)]
    pub fn for_rows3<F>(
        &self,
        a: &mut [f32],
        acols: usize,
        b: &mut [f32],
        bcols: usize,
        c: &mut [f32],
        ccols: usize,
        grain: usize,
        f: F,
    ) where
        F: Fn(usize, &mut [f32], &mut [f32], &mut [f32]) + Sync,
    {
        let items = if acols == 0 { 0 } else { a.len() / acols };
        debug_assert_eq!(items * bcols, b.len());
        debug_assert_eq!(items * ccols, c.len());
        let shards = self.shards(items, grain);
        if shards <= 1 {
            f(0, a, b, c);
            return;
        }
        let chunk = (items + shards - 1) / shards;
        let fref = &f;
        thread::scope(move |s| {
            let ca: Vec<&mut [f32]> = a.chunks_mut(chunk * acols).collect();
            let cb: Vec<&mut [f32]> = b.chunks_mut(chunk * bcols).collect();
            let cc: Vec<&mut [f32]> = c.chunks_mut(chunk * ccols).collect();
            let nch = ca.len();
            debug_assert_eq!(nch, cb.len());
            debug_assert_eq!(nch, cc.len());
            for (idx, ((ha, hb), hc)) in ca.into_iter().zip(cb).zip(cc).enumerate() {
                let i0 = idx * chunk;
                if idx + 1 == nch {
                    fref(i0, ha, hb, hc);
                } else {
                    s.spawn(move || fref(i0, ha, hb, hc));
                }
            }
        });
    }
    /// Four parallel output buffers (attention VJP `dq`/`dk`/`dv` plus its
    /// per-item `dprobs` scratch slab). All widths must be non-zero.
    #[allow(clippy::too_many_arguments)]
    pub fn for_rows4<F>(
        &self,
        a: &mut [f32],
        acols: usize,
        b: &mut [f32],
        bcols: usize,
        c: &mut [f32],
        ccols: usize,
        d: &mut [f32],
        dcols: usize,
        grain: usize,
        f: F,
    ) where
        F: Fn(usize, &mut [f32], &mut [f32], &mut [f32], &mut [f32]) + Sync,
    {
        let items = if acols == 0 { 0 } else { a.len() / acols };
        debug_assert_eq!(items * bcols, b.len());
        debug_assert_eq!(items * ccols, c.len());
        debug_assert_eq!(items * dcols, d.len());
        let shards = self.shards(items, grain);
        if shards <= 1 {
            f(0, a, b, c, d);
            return;
        }
        let chunk = (items + shards - 1) / shards;
        let fref = &f;
        thread::scope(move |s| {
            let ca: Vec<&mut [f32]> = a.chunks_mut(chunk * acols).collect();
            let cb: Vec<&mut [f32]> = b.chunks_mut(chunk * bcols).collect();
            let cc: Vec<&mut [f32]> = c.chunks_mut(chunk * ccols).collect();
            let cd: Vec<&mut [f32]> = d.chunks_mut(chunk * dcols).collect();
            let nch = ca.len();
            debug_assert_eq!(nch, cb.len());
            debug_assert_eq!(nch, cc.len());
            debug_assert_eq!(nch, cd.len());
            for (idx, (((ha, hb), hc), hd)) in
                ca.into_iter().zip(cb).zip(cc).zip(cd).enumerate()
            {
                let i0 = idx * chunk;
                if idx + 1 == nch {
                    fref(i0, ha, hb, hc, hd);
                } else {
                    s.spawn(move || fref(i0, ha, hb, hc, hd));
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn with_threads_resolves_auto() {
        assert!(Pool::auto().threads() >= 1);
        assert_eq!(Pool::with_threads(3).threads(), 3);
        assert_eq!(Pool::serial().threads(), 1);
        assert!(Pool::scalar_reference().is_scalar());
        assert!(!Pool::with_threads(4).is_scalar());
    }

    #[test]
    fn for_rows_covers_every_row_once() {
        for threads in [1, 2, 3, 7] {
            let pool = Pool::with_threads(threads);
            let cols = 3;
            let mut out = vec![0.0f32; 25 * cols];
            pool.for_rows(&mut out, cols, 1, |row0, chunk| {
                for (r, row) in chunk.chunks_exact_mut(cols).enumerate() {
                    for v in row.iter_mut() {
                        *v += (row0 + r) as f32 + 1.0;
                    }
                }
            });
            for (r, row) in out.chunks_exact(cols).enumerate() {
                for &v in row {
                    assert_eq!(v, r as f32 + 1.0, "threads={threads} row={r}");
                }
            }
        }
    }

    #[test]
    fn for_rows_respects_grain() {
        // 4 rows at grain 8 must stay on the caller (single chunk at 0)
        let pool = Pool::with_threads(8);
        let mut out = vec![0.0f32; 4];
        let starts = pool.map_rows(&mut out, 1, 8, |row0, chunk| (row0, chunk.len()));
        assert_eq!(starts, vec![(0, 4)]);
    }

    #[test]
    fn map_rows_partials_in_chunk_order() {
        let pool = Pool::with_threads(4);
        let mut out = vec![0.0f32; 100];
        let parts = pool.map_rows(&mut out, 1, 1, |row0, chunk| (row0, chunk.len()));
        // chunks tile [0, 100) in order and cover it exactly
        let mut expect = 0usize;
        let mut total = 0usize;
        for (row0, len) in parts {
            assert_eq!(row0, expect);
            expect += len;
            total += len;
        }
        assert_eq!(total, 100);
    }

    #[test]
    fn for_rows2_and_3_split_consistently() {
        let pool = Pool::with_threads(3);
        let items = 10;
        let (wa, wb, wc) = (2, 5, 1);
        let mut a = vec![0.0f32; items * wa];
        let mut b = vec![0.0f32; items * wb];
        let mut c = vec![0.0f32; items * wc];
        pool.for_rows2(&mut a, wa, &mut b, wb, 1, |i0, ca, cb| {
            assert_eq!(ca.len() / wa, cb.len() / wb);
            for v in ca.iter_mut() {
                *v = i0 as f32;
            }
            for v in cb.iter_mut() {
                *v = 1.0;
            }
        });
        assert!(b.iter().all(|&v| v == 1.0));
        pool.for_rows3(&mut a, wa, &mut b, wb, &mut c, wc, 1, |_, ca, cb, cc| {
            assert_eq!(ca.len() / wa, cc.len() / wc);
            assert_eq!(cb.len() / wb, cc.len() / wc);
            for v in cc.iter_mut() {
                *v = 2.0;
            }
        });
        assert!(c.iter().all(|&v| v == 2.0));
    }

    #[test]
    fn for_rows4_covers_items_once() {
        for threads in [1, 3] {
            let pool = Pool::with_threads(threads);
            let items = 7;
            let (wa, wb, wc, wd) = (2, 3, 1, 4);
            let mut a = vec![0.0f32; items * wa];
            let mut b = vec![0.0f32; items * wb];
            let mut c = vec![0.0f32; items * wc];
            let mut d = vec![0.0f32; items * wd];
            pool.for_rows4(
                &mut a,
                wa,
                &mut b,
                wb,
                &mut c,
                wc,
                &mut d,
                wd,
                1,
                |i0, ca, cb, cc, cd| {
                    assert_eq!(ca.len() / wa, cb.len() / wb);
                    assert_eq!(cc.len() / wc, cd.len() / wd);
                    for (r, item) in cc.chunks_exact_mut(wc).enumerate() {
                        item[0] += (i0 + r) as f32 + 1.0;
                    }
                    for v in cd.iter_mut() {
                        *v += 1.0;
                    }
                },
            );
            for (r, item) in c.chunks_exact(wc).enumerate() {
                assert_eq!(item[0], r as f32 + 1.0, "threads={threads} item={r}");
            }
            assert!(d.iter().all(|&v| v == 1.0));
        }
    }

    #[test]
    fn empty_output_is_fine() {
        let pool = Pool::with_threads(4);
        let mut out: Vec<f32> = Vec::new();
        pool.for_rows(&mut out, 4, 1, |_, chunk| assert!(chunk.is_empty()));
        let parts = pool.map_rows(&mut out, 4, 1, |_, chunk| chunk.len());
        assert_eq!(parts, vec![0]);
    }
}
