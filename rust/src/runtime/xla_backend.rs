//! `XlaBackend`: the PJRT execution path (behind the `xla` cargo feature).
//!
//! HLO *text* is the interchange format (see DESIGN.md §4.1):
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`. One compiled executable per artifact,
//! compiled on first use and cached for the life of the backend.
//!
//! Note: the in-tree `vendor/xla` crate is a stub that errors at runtime;
//! swap it for the published `xla` crate to actually run this path.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};
use xla::{PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::backend::{Backend, DeviceTensor};
use super::manifest::{ArtifactInfo, Manifest};
use super::tensor::{IntTensor, Tensor};

/// PJRT CPU backend with a per-artifact executable cache.
pub struct XlaBackend {
    client: PjRtClient,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    compiles: RefCell<(usize, f64)>,
}

impl XlaBackend {
    /// A backend over a fresh PJRT CPU client.
    pub fn new() -> Result<XlaBackend> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(XlaBackend {
            client,
            cache: RefCell::new(HashMap::new()),
            compiles: RefCell::new((0, 0.0)),
        })
    }

    /// Fetch (compiling on first use) the executable for an artifact.
    fn executable(&self, info: &ArtifactInfo) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&info.name) {
            return Ok(exe.clone());
        }
        let t0 = Instant::now();
        let path = info
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path {:?}", info.file))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("loading HLO text {:?}", info.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{}'", info.name))?,
        );
        {
            let mut c = self.compiles.borrow_mut();
            c.0 += 1;
            c.1 += t0.elapsed().as_secs_f64();
        }
        self.cache.borrow_mut().insert(info.name.clone(), exe.clone());
        Ok(exe)
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn upload(&self, t: &Tensor) -> Result<DeviceTensor> {
        Ok(DeviceTensor::Pjrt(t.to_buffer(&self.client)?))
    }

    fn upload_int(&self, t: &IntTensor) -> Result<DeviceTensor> {
        Ok(DeviceTensor::Pjrt(t.to_buffer(&self.client)?))
    }

    fn warmup(&self, _manifest: &Manifest, info: &ArtifactInfo) -> Result<()> {
        self.executable(info).map(|_| ())
    }

    fn compile_stats(&self) -> (usize, f64) {
        *self.compiles.borrow()
    }

    fn execute(
        &self,
        _manifest: &Manifest,
        info: &ArtifactInfo,
        inputs: &[&DeviceTensor],
    ) -> Result<Vec<Tensor>> {
        let exe = self.executable(info)?;
        // Stage any host-resident tensors onto the device; device-resident
        // buffers (the session hot path) pass through untouched.
        let mut staged: Vec<Option<PjRtBuffer>> = Vec::with_capacity(inputs.len());
        for dt in inputs {
            match dt {
                DeviceTensor::F32(t) => staged.push(Some(t.to_buffer(&self.client)?)),
                DeviceTensor::I32(t) => staged.push(Some(t.to_buffer(&self.client)?)),
                DeviceTensor::Pjrt(_) => staged.push(None),
            }
        }
        let mut refs: Vec<&PjRtBuffer> = Vec::with_capacity(inputs.len());
        for (dt, st) in inputs.iter().zip(&staged) {
            match (dt, st) {
                (DeviceTensor::Pjrt(b), _) => refs.push(b),
                (_, Some(b)) => refs.push(b),
                _ => bail!("input staging failed"),
            }
        }
        let result = exe.execute_b::<&PjRtBuffer>(&refs)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        outs.iter().map(Tensor::from_literal).collect()
    }
}
