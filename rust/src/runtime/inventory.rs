//! Builtin model/artifact inventory for the native backend.
//!
//! Mirrors `python/compile/configs.py` + `model.param_specs` exactly: the
//! same three model sizes, the same canonical parameter order, the same
//! gradient-group predicates, and the same artifact naming scheme the AOT
//! pipeline records in `manifest.json`. This is what lets the whole
//! experiment harness run with no Python, no artifacts directory and no
//! network: `Manifest::builtin()` is byte-equivalent in structure to a
//! parsed `manifest.json` (the `file` paths simply point at artifacts that
//! need not exist for the native backend).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use super::manifest::{
    ArtifactInfo, ArtifactKind, InitKind, Manifest, ModelInfo, ParamSpec,
};

/// Batch geometry baked into the artifacts (`configs.BATCH` / `configs.SEQ`).
pub const BATCH: usize = 16;
/// Sequence length baked into the artifacts.
pub const SEQ: usize = 32;
/// Global classifier-head width (class mask selects per task).
pub const NUM_CLASSES: usize = 3;

/// One model-size configuration (`configs.ModelConfig`).
struct SizeCfg {
    name: &'static str,
    layers: usize,
    hidden: usize,
    heads: usize,
    ffn: usize,
}

const VOCAB: usize = 512;
const MAX_LEN: usize = 32;
const TYPE_VOCAB: usize = 2;
const LORA_RANK: usize = 4;
const LORA_ALPHA: f32 = 8.0;
const HOULSBY_BOTTLENECK: usize = 16;

const SIZES: [SizeCfg; 3] = [
    SizeCfg { name: "tiny", layers: 2, hidden: 64, heads: 2, ffn: 128 },
    SizeCfg { name: "base", layers: 4, hidden: 128, heads: 4, ffn: 512 },
    SizeCfg { name: "large", layers: 8, hidden: 192, heads: 6, ffn: 768 },
];

// ------------------------------------------------------- group predicates

fn is_head(n: &str) -> bool {
    n.starts_with("pooler.") || n.starts_with("classifier.") || n.starts_with("regressor.")
}

fn is_peft(n: &str) -> bool {
    n.contains(".hadamard.")
        || n.contains(".lora.")
        || n.contains(".houlsby.")
        || n.contains(".ia3.")
}

fn is_hadamard_group(n: &str) -> bool {
    is_head(n)
        || n.contains(".hadamard.")
        || n.contains(".attention.output.LayerNorm.")
        || (n.contains(".output.LayerNorm.") && !n.contains(".attention."))
}

fn is_bitfit(n: &str) -> bool {
    // Backbone bias terms only (adapter-internal biases are not BitFit's).
    is_head(n) || (n.ends_with(".bias") && !is_peft(n))
}

fn is_lora(n: &str) -> bool {
    is_head(n) || n.contains(".lora.")
}

fn is_houlsby(n: &str) -> bool {
    is_head(n)
        || n.contains(".houlsby.")
        || n.contains(".attention.output.LayerNorm.")
        || (n.contains(".output.LayerNorm.") && !n.contains(".attention."))
}

fn is_ia3(n: &str) -> bool {
    is_head(n) || n.contains(".ia3.")
}

fn is_backbone(n: &str) -> bool {
    !is_peft(n) && !is_head(n)
}

fn is_full(n: &str) -> bool {
    !is_peft(n)
}

/// Gradient groups in the AOT pipeline's iteration order.
const GROUPS: [(&str, fn(&str) -> bool); 7] = [
    ("head", is_head),
    ("hadamard", is_hadamard_group),
    ("bitfit", is_bitfit),
    ("lora", is_lora),
    ("houlsby", is_houlsby),
    ("ia3", is_ia3),
    ("full", is_full),
];

// ------------------------------------------------------------ param specs

fn push(v: &mut Vec<ParamSpec>, name: String, shape: Vec<usize>, init: InitKind) {
    v.push(ParamSpec { name, shape, init });
}

/// Canonical parameter inventory, mirroring `model.param_specs`.
fn param_specs(c: &SizeCfg) -> Vec<ParamSpec> {
    use InitKind::{Normal, Ones, Zeros};
    let (h, f, v) = (c.hidden, c.ffn, VOCAB);
    let (r, bn) = (LORA_RANK, HOULSBY_BOTTLENECK);
    let mut s = Vec::new();
    push(&mut s, "embeddings.word_embeddings.weight".into(), vec![v, h], Normal);
    push(&mut s, "embeddings.position_embeddings.weight".into(), vec![MAX_LEN, h], Normal);
    push(&mut s, "embeddings.token_type_embeddings.weight".into(), vec![TYPE_VOCAB, h], Normal);
    push(&mut s, "embeddings.LayerNorm.weight".into(), vec![h], Ones);
    push(&mut s, "embeddings.LayerNorm.bias".into(), vec![h], Zeros);
    for i in 0..c.layers {
        let p = format!("encoder.layer.{i}");
        push(&mut s, format!("{p}.attention.self.query.weight"), vec![h, h], Normal);
        push(&mut s, format!("{p}.attention.self.query.bias"), vec![h], Zeros);
        push(&mut s, format!("{p}.attention.self.key.weight"), vec![h, h], Normal);
        push(&mut s, format!("{p}.attention.self.key.bias"), vec![h], Zeros);
        push(&mut s, format!("{p}.attention.self.value.weight"), vec![h, h], Normal);
        push(&mut s, format!("{p}.attention.self.value.bias"), vec![h], Zeros);
        // The paper's adapter right after the concatenated self-attention
        // output (Eq. 6-7); w2/w3 are the Sec. 2.2 fitting-order terms.
        push(&mut s, format!("{p}.hadamard.weight"), vec![h], Ones);
        push(&mut s, format!("{p}.hadamard.bias"), vec![h], Zeros);
        push(&mut s, format!("{p}.hadamard.w2"), vec![h], Zeros);
        push(&mut s, format!("{p}.hadamard.w3"), vec![h], Zeros);
        push(&mut s, format!("{p}.attention.output.dense.weight"), vec![h, h], Normal);
        push(&mut s, format!("{p}.attention.output.dense.bias"), vec![h], Zeros);
        push(&mut s, format!("{p}.attention.output.LayerNorm.weight"), vec![h], Ones);
        push(&mut s, format!("{p}.attention.output.LayerNorm.bias"), vec![h], Zeros);
        // LoRA on Q and V (B zero-init => identity).
        push(&mut s, format!("{p}.lora.query.a"), vec![h, r], Normal);
        push(&mut s, format!("{p}.lora.query.b"), vec![r, h], Zeros);
        push(&mut s, format!("{p}.lora.value.a"), vec![h, r], Normal);
        push(&mut s, format!("{p}.lora.value.b"), vec![r, h], Zeros);
        // IA3 rescaling vectors (ones => identity).
        push(&mut s, format!("{p}.ia3.l_k"), vec![h], Ones);
        push(&mut s, format!("{p}.ia3.l_v"), vec![h], Ones);
        push(&mut s, format!("{p}.ia3.l_ff"), vec![f], Ones);
        // Houlsby bottleneck adapters (up zero-init => identity).
        push(&mut s, format!("{p}.houlsby.attn.down.weight"), vec![h, bn], Normal);
        push(&mut s, format!("{p}.houlsby.attn.down.bias"), vec![bn], Zeros);
        push(&mut s, format!("{p}.houlsby.attn.up.weight"), vec![bn, h], Zeros);
        push(&mut s, format!("{p}.houlsby.attn.up.bias"), vec![h], Zeros);
        push(&mut s, format!("{p}.houlsby.ffn.down.weight"), vec![h, bn], Normal);
        push(&mut s, format!("{p}.houlsby.ffn.down.bias"), vec![bn], Zeros);
        push(&mut s, format!("{p}.houlsby.ffn.up.weight"), vec![bn, h], Zeros);
        push(&mut s, format!("{p}.houlsby.ffn.up.bias"), vec![h], Zeros);
        push(&mut s, format!("{p}.intermediate.dense.weight"), vec![h, f], Normal);
        push(&mut s, format!("{p}.intermediate.dense.bias"), vec![f], Zeros);
        push(&mut s, format!("{p}.output.dense.weight"), vec![f, h], Normal);
        push(&mut s, format!("{p}.output.dense.bias"), vec![h], Zeros);
        push(&mut s, format!("{p}.output.LayerNorm.weight"), vec![h], Ones);
        push(&mut s, format!("{p}.output.LayerNorm.bias"), vec![h], Zeros);
    }
    push(&mut s, "pooler.dense.weight".into(), vec![h, h], Normal);
    push(&mut s, "pooler.dense.bias".into(), vec![h], Zeros);
    push(&mut s, "classifier.weight".into(), vec![h, NUM_CLASSES], Normal);
    push(&mut s, "classifier.bias".into(), vec![NUM_CLASSES], Zeros);
    push(&mut s, "regressor.weight".into(), vec![h, 1], Normal);
    push(&mut s, "regressor.bias".into(), vec![1], Zeros);
    push(&mut s, "mlm.dense.weight".into(), vec![h, h], Normal);
    push(&mut s, "mlm.dense.bias".into(), vec![h], Zeros);
    push(&mut s, "mlm.LayerNorm.weight".into(), vec![h], Ones);
    push(&mut s, "mlm.LayerNorm.bias".into(), vec![h], Zeros);
    push(&mut s, "mlm.decoder.bias".into(), vec![v], Zeros);
    s
}

fn build_model(c: &SizeCfg) -> ModelInfo {
    let params = param_specs(c);
    let index: HashMap<String, usize> = params
        .iter()
        .enumerate()
        .map(|(i, p)| (p.name.clone(), i))
        .collect();
    let mut groups = HashMap::new();
    for (gname, pred) in GROUPS {
        groups.insert(
            gname.to_string(),
            params.iter().filter(|p| pred(&p.name)).map(|p| p.name.clone()).collect(),
        );
    }
    let mlm_group = params
        .iter()
        .filter(|p| is_backbone(&p.name))
        .map(|p| p.name.clone())
        .collect();
    ModelInfo {
        name: c.name.to_string(),
        layers: c.layers,
        hidden: c.hidden,
        heads: c.heads,
        ffn: c.ffn,
        vocab: VOCAB,
        max_len: MAX_LEN,
        lora_alpha: LORA_ALPHA,
        params,
        index,
        groups,
        mlm_group,
    }
}

fn grad_outputs(members: &[String]) -> Vec<String> {
    let mut out = vec!["loss".to_string()];
    out.extend(members.iter().map(|n| format!("grad:{n}")));
    out
}

fn strings(xs: &[&str]) -> Vec<String> {
    xs.iter().map(|s| s.to_string()).collect()
}

impl Manifest {
    /// The builtin inventory (all three model sizes, every artifact the AOT
    /// pipeline would emit). `dir` is only used to form nominal artifact
    /// file paths; the native backend never reads them.
    pub fn builtin(dir: impl AsRef<Path>) -> Manifest {
        let dir: PathBuf = dir.as_ref().to_path_buf();
        let mut models = HashMap::new();
        let mut artifacts = HashMap::new();
        for cfg in &SIZES {
            let info = build_model(cfg);
            let size = cfg.name;

            let fwd = Manifest::fwd_name(size);
            artifacts.insert(
                fwd.clone(),
                ArtifactInfo {
                    name: fwd.clone(),
                    file: dir.join(format!("{fwd}.hlo.txt")),
                    model: size.to_string(),
                    kind: ArtifactKind::Forward,
                    loss: None,
                    group: None,
                    batch_inputs: strings(&["tokens", "type_ids", "attn_mask"]),
                    outputs: strings(&["logits", "regression", "attn_norms", "attn_means"]),
                },
            );

            for lk in ["cls", "reg"] {
                for (gname, _) in GROUPS {
                    let name = Manifest::train_name(lk, gname, size);
                    let batch_inputs = if lk == "cls" {
                        strings(&["tokens", "type_ids", "attn_mask", "labels_onehot", "class_mask"])
                    } else {
                        strings(&["tokens", "type_ids", "attn_mask", "labels"])
                    };
                    artifacts.insert(
                        name.clone(),
                        ArtifactInfo {
                            name: name.clone(),
                            file: dir.join(format!("{name}.hlo.txt")),
                            model: size.to_string(),
                            kind: ArtifactKind::Train,
                            loss: Some(lk.to_string()),
                            group: Some(gname.to_string()),
                            batch_inputs,
                            outputs: grad_outputs(&info.groups[gname]),
                        },
                    );
                }
            }

            let mlm = Manifest::mlm_name(size);
            artifacts.insert(
                mlm.clone(),
                ArtifactInfo {
                    name: mlm.clone(),
                    file: dir.join(format!("{mlm}.hlo.txt")),
                    model: size.to_string(),
                    kind: ArtifactKind::Mlm,
                    loss: None,
                    group: None,
                    batch_inputs: strings(&[
                        "tokens", "type_ids", "attn_mask", "mlm_labels", "loss_mask",
                    ]),
                    outputs: grad_outputs(&info.mlm_group),
                },
            );

            models.insert(size.to_string(), info);
        }
        Manifest {
            batch: BATCH,
            seq_len: SEQ,
            num_classes: NUM_CLASSES,
            models,
            artifacts,
            dir,
        }
    }

    /// Load `manifest.json` from `dir` when present (an AOT artifacts
    /// directory), else fall back to the builtin inventory. The native
    /// backend works with either; the XLA backend requires the real thing.
    pub fn load_or_builtin(dir: impl AsRef<Path>) -> Result<Manifest, anyhow::Error> {
        if dir.as_ref().join("manifest.json").exists() {
            Manifest::load(&dir)
        } else {
            Ok(Manifest::builtin(dir))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_models_and_artifacts_consistent() {
        let m = Manifest::builtin("artifacts");
        assert_eq!(m.batch, 16);
        assert_eq!(m.seq_len, 32);
        assert_eq!(m.models.len(), 3);
        // 1 fwd + 2 losses x 7 groups + 1 mlm = 16 per model
        assert_eq!(m.artifacts.len(), 3 * 16);
        let tiny = m.model("tiny").unwrap();
        assert_eq!(tiny.layers, 2);
        assert_eq!(tiny.hidden, 64);
        // every artifact's grad params exist in its model
        for a in m.artifacts.values() {
            let info = m.model(&a.model).unwrap();
            for g in a.grad_params() {
                assert!(info.param_index(g).is_ok(), "{g} missing in {}", a.name);
            }
        }
    }

    #[test]
    fn group_predicates_match_python_semantics() {
        let m = Manifest::builtin("artifacts");
        let tiny = m.model("tiny").unwrap();
        let full = tiny.group("full").unwrap();
        assert!(full.iter().all(|n| !n.contains(".hadamard.")));
        assert!(full.iter().any(|n| n.starts_with("classifier.")));
        let had = tiny.group("hadamard").unwrap();
        assert!(had.iter().any(|n| n.ends_with(".hadamard.weight")));
        assert!(had.iter().any(|n| n.contains(".attention.output.LayerNorm.")));
        // embeddings LN is NOT in the hadamard group
        assert!(!had.iter().any(|n| n.starts_with("embeddings.")));
        let bitfit = tiny.group("bitfit").unwrap();
        assert!(bitfit.iter().all(|n| n.ends_with(".bias") || is_head(n)));
        assert!(!bitfit.iter().any(|n| n.contains(".houlsby.")));
        // mlm group: no PEFT, no task heads, but includes the MLM head
        assert!(tiny.mlm_group.iter().all(|n| !is_peft(n) && !is_head(n)));
        assert!(tiny.mlm_group.iter().any(|n| n.starts_with("mlm.")));
    }

    #[test]
    fn canonical_order_stable() {
        let m = Manifest::builtin("artifacts");
        let tiny = m.model("tiny").unwrap();
        assert_eq!(tiny.params[0].name, "embeddings.word_embeddings.weight");
        assert_eq!(tiny.params[5].name, "encoder.layer.0.attention.self.query.weight");
        let last = tiny.params.last().unwrap();
        assert_eq!(last.name, "mlm.decoder.bias");
        assert_eq!(last.shape, vec![512]);
        // tiny parameter count: 5 embeddings + 35/layer x 2 + 11 head/mlm
        assert_eq!(tiny.params.len(), 5 + 35 * 2 + 11);
    }

    #[test]
    fn load_or_builtin_falls_back() {
        let m = Manifest::load_or_builtin("/nonexistent/dir").unwrap();
        assert!(m.model("base").is_ok());
    }
}
