//! `runtime::workspace`: a size-keyed buffer arena for the native hot path.
//!
//! The train/eval step runs the same artifact with the same geometry every
//! step, so every intermediate buffer the kernels need has a stable length.
//! A [`Workspace`] recycles those buffers across steps: `take(len)` pops a
//! previously-returned buffer of exactly that length (zero-filled, so
//! accumulating kernels can rely on a clean slate) or allocates a fresh one
//! on a miss; `give(buf)` returns a buffer to its length bucket when the
//! caller is done with it.
//!
//! Steady state (step >= 2 of a fixed-geometry loop) is allocation-free in
//! kernel code: every `take` is a hit, and the hit/miss counters make that
//! property testable (`tests/workspace_alloc.rs` additionally pins it with
//! a counting global allocator). Buckets are keyed by the buffer's length —
//! `vec![0.0; len]` allocates exactly `len`, and the native backend never
//! resizes a workspace buffer, so the round trip is stable.
//!
//! The arena is deliberately not thread-safe: only the orchestrating thread
//! takes and gives buffers; pool workers receive pre-partitioned `&mut`
//! chunks of them. `NativeBackend` owns one behind its state mutex.

use std::collections::HashMap;

/// Per-size cap on retained buffers: steady-state flows balance take/give,
/// so anything beyond a small backlog is a leak we'd rather return to the
/// allocator than hoard.
const MAX_PER_BUCKET: usize = 32;

/// A size-keyed free list of `Vec<f32>` buffers with hit/miss accounting.
#[derive(Debug, Default)]
pub struct Workspace {
    buckets: HashMap<usize, Vec<Vec<f32>>>,
    hits: u64,
    misses: u64,
    held_bytes: usize,
}

/// Snapshot of the arena's accounting, the allocation-side half of the
/// steady-state story (the dispatch-side half is
/// [`crate::runtime::PoolStats`]): a fixed-geometry loop stops accruing
/// `misses` after step 1, exactly as the pool stops accruing
/// `threads_spawned`.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct WorkspaceStats {
    /// `take` calls served from the free list.
    pub hits: u64,
    /// `take` calls that had to allocate.
    pub misses: u64,
    /// Bytes currently retained in the free list.
    pub held_bytes: usize,
    /// Distinct buffer lengths currently retained.
    pub buckets: usize,
}

impl Workspace {
    /// An empty arena.
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// A zero-filled buffer of exactly `len` floats — recycled when a
    /// same-length buffer was previously [`Workspace::give`]n back.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.take_dirty(len);
        v.fill(0.0);
        v
    }

    /// Like [`Workspace::take`] but without the zero fill: a recycled
    /// buffer keeps its stale contents. Only for consumers that fully
    /// overwrite every element (GEMM outputs, split/merge copies,
    /// attention probs/scratch slabs) — accumulating consumers must use
    /// [`Workspace::take`]. Skipping the memset matters on the large
    /// `[T, F]` / `[B, NH, L, L]` hot-path buffers, which would otherwise
    /// be swept twice per step.
    pub fn take_dirty(&mut self, len: usize) -> Vec<f32> {
        if len == 0 {
            return Vec::new();
        }
        if let Some(bucket) = self.buckets.get_mut(&len) {
            if let Some(mut v) = bucket.pop() {
                self.hits += 1;
                self.held_bytes -= v.capacity() * 4;
                debug_assert_eq!(v.len(), len);
                v.resize(len, 0.0);
                return v;
            }
        }
        self.misses += 1;
        vec![0.0f32; len]
    }

    /// Return a buffer for reuse. Buffers keep their length bucket; a full
    /// bucket drops the buffer back to the allocator.
    pub fn give(&mut self, v: Vec<f32>) {
        let len = v.len();
        if len == 0 {
            return;
        }
        let bucket = self.buckets.entry(len).or_default();
        if bucket.len() >= MAX_PER_BUCKET {
            return;
        }
        self.held_bytes += v.capacity() * 4;
        bucket.push(v);
    }

    /// Number of `take` calls served from the free list.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Number of `take` calls that had to allocate.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Bytes currently resident in the free list.
    pub fn held_bytes(&self) -> usize {
        self.held_bytes
    }

    /// One-call snapshot of all counters. `buckets` counts only sizes
    /// that currently retain at least one buffer (a drained bucket keeps
    /// its map entry but holds nothing).
    pub fn stats(&self) -> WorkspaceStats {
        WorkspaceStats {
            hits: self.hits,
            misses: self.misses,
            held_bytes: self.held_bytes,
            buckets: self.buckets.values().filter(|b| !b.is_empty()).count(),
        }
    }

    /// Drop every retained buffer (checkpoint boundaries, tests).
    pub fn clear(&mut self) {
        self.buckets.clear();
        self.held_bytes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_give_roundtrip_reuses_allocation() {
        let mut ws = Workspace::new();
        let a = ws.take(128);
        assert_eq!(ws.misses(), 1);
        assert_eq!(a.len(), 128);
        let ptr = a.as_ptr() as usize;
        ws.give(a);
        assert_eq!(ws.held_bytes(), 128 * 4);
        let b = ws.take(128);
        assert_eq!(ws.hits(), 1, "second take of the same size must be a hit");
        assert_eq!(b.as_ptr() as usize, ptr, "the very same allocation comes back");
        assert!(b.iter().all(|&x| x == 0.0), "recycled buffers are zeroed");
    }

    #[test]
    fn distinct_sizes_use_distinct_buckets() {
        let mut ws = Workspace::new();
        ws.give(vec![1.0; 8]);
        ws.give(vec![2.0; 16]);
        let a = ws.take(16);
        assert_eq!(a.len(), 16);
        assert_eq!(ws.hits(), 1);
        let b = ws.take(9);
        assert_eq!(b.len(), 9);
        assert_eq!(ws.misses(), 1, "no 9-float buffer was ever given");
    }

    #[test]
    fn dirty_buffers_come_back_zeroed() {
        let mut ws = Workspace::new();
        let mut a = ws.take(4);
        a.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        ws.give(a);
        assert_eq!(ws.take(4), vec![0.0; 4]);
    }

    #[test]
    fn take_dirty_skips_the_memset() {
        let mut ws = Workspace::new();
        let mut a = ws.take_dirty(4);
        a.copy_from_slice(&[5.0, 6.0, 7.0, 8.0]);
        let ptr = a.as_ptr() as usize;
        ws.give(a);
        let b = ws.take_dirty(4);
        assert_eq!(b.as_ptr() as usize, ptr);
        assert_eq!(b, vec![5.0, 6.0, 7.0, 8.0], "dirty take keeps stale contents");
        ws.give(b);
        assert_eq!(ws.take(4), vec![0.0; 4], "zeroing take still zeroes");
    }

    #[test]
    fn zero_len_is_a_noop() {
        let mut ws = Workspace::new();
        let v = ws.take(0);
        assert!(v.is_empty());
        ws.give(v);
        assert_eq!(ws.hits() + ws.misses(), 0);
        assert_eq!(ws.held_bytes(), 0);
    }

    #[test]
    fn bucket_cap_bounds_retention() {
        let mut ws = Workspace::new();
        for _ in 0..MAX_PER_BUCKET + 5 {
            ws.give(vec![0.0; 8]);
        }
        assert_eq!(ws.held_bytes(), MAX_PER_BUCKET * 8 * 4);
        ws.clear();
        assert_eq!(ws.held_bytes(), 0);
    }

    #[test]
    fn stats_snapshot_matches_counters() {
        let mut ws = Workspace::new();
        let a = ws.take(16);
        ws.give(a);
        let _ = ws.take(16);
        let _ = ws.take(32);
        ws.give(vec![0.0; 8]);
        let s = ws.stats();
        assert_eq!((s.hits, s.misses), (ws.hits(), ws.misses()));
        assert_eq!(s.held_bytes, ws.held_bytes());
        assert_eq!(s.buckets, 1, "only the 8-float bucket holds a buffer");
    }
}
