//! Pure-Rust compute kernels for the native backend.
//!
//! Each kernel mirrors its oracle in `python/compile/kernels/ref.py`
//! (hadamard adapter, row-wise LayerNorm, masked scaled-dot-product
//! attention) plus the backward passes the gradient groups need. The
//! golden-fixture tests in `rust/tests/native_kernels.rs` pin forward and
//! VJP outputs against values generated once from the JAX oracles.
//!
//! Layout conventions: activations are dense row-major f32, `[T, H]` for
//! token-major matrices and `[B, NH, L, D]` for per-head attention blocks.

/// Error function via Abramowitz & Stegun 7.1.26 (max abs error 1.5e-7,
/// well inside the 1e-5 kernel-parity budget). Computed in f64.
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let ax = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * ax);
    let poly = t
        * (0.254829592
            + t * (-0.284496736
                + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    sign * (1.0 - poly * (-ax * ax).exp())
}

/// Exact (erf-based) GELU, matching `jax.nn.gelu(x, approximate=False)`.
pub fn gelu(x: f32) -> f32 {
    let x = x as f64;
    (0.5 * x * (1.0 + erf(x * std::f64::consts::FRAC_1_SQRT_2))) as f32
}

/// d/dx of exact GELU: Phi(x) + x * phi(x).
pub fn dgelu(x: f32) -> f32 {
    let x = x as f64;
    let phi = (-0.5 * x * x).exp() / (2.0 * std::f64::consts::PI).sqrt();
    let cdf = 0.5 * (1.0 + erf(x * std::f64::consts::FRAC_1_SQRT_2));
    (cdf + x * phi) as f32
}

/// Apply `gelu` elementwise into a new buffer.
pub fn gelu_vec(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| gelu(v)).collect()
}

// ------------------------------------------------------------------ matmul

/// `c = a @ b` for `a: [m, k]`, `b: [k, n]` (row-major, ikj loop order).
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// `out += a^T @ b` for `a: [k, m]`, `b: [k, n]`, `out: [m, n]` — the
/// parameter-gradient shape (`dW = x^T @ dy`).
pub fn matmul_tn_acc(a: &[f32], b: &[f32], out: &mut [f32], k: usize, m: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
}

/// `c = a @ b^T` for `a: [m, k]`, `b: [n, k]` — the input-gradient shape
/// (`dx = dy @ W^T`). Both rows are contiguous, so this is a dot-product
/// loop.
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            c[i * n + j] = acc;
        }
    }
    c
}

/// Add a `[n]` bias to each row of `x: [rows, n]`.
pub fn add_bias(x: &mut [f32], bias: &[f32]) {
    let n = bias.len();
    for row in x.chunks_exact_mut(n) {
        for (v, b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// `out += column sums of x: [rows, n]` — the bias-gradient shape.
pub fn col_sum_acc(x: &[f32], out: &mut [f32]) {
    let n = out.len();
    for row in x.chunks_exact(n) {
        for (o, v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
}

/// `out += column sums of a ⊙ b` for `a, b: [rows, n]` — the gradient shape
/// of a broadcast elementwise scale (LayerNorm gain, IA3 vectors, Hadamard
/// weight).
pub fn mul_col_sum_acc(a: &[f32], b: &[f32], out: &mut [f32]) {
    let n = out.len();
    for (arow, brow) in a.chunks_exact(n).zip(b.chunks_exact(n)) {
        for j in 0..n {
            out[j] += arow[j] * brow[j];
        }
    }
}

// ---------------------------------------------------------------- hadamard

/// Hadamard adapter forward (paper Eq. 5, ref: `hadamard_ref`):
/// `y[t, h] = w[h] * x[t, h] + b[h] (+ w2[h] x^2 + w3[h] x^3)`.
pub fn hadamard_fwd(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    w2: Option<&[f32]>,
    w3: Option<&[f32]>,
) -> Vec<f32> {
    let h = w.len();
    let mut y = vec![0.0f32; x.len()];
    for (t, row) in x.chunks_exact(h).enumerate() {
        let yrow = &mut y[t * h..(t + 1) * h];
        for j in 0..h {
            let xv = row[j];
            let mut v = w[j] * xv + b[j];
            if let Some(w2) = w2 {
                v += w2[j] * xv * xv;
            }
            if let Some(w3) = w3 {
                v += w3[j] * xv * xv * xv;
            }
            yrow[j] = v;
        }
    }
    y
}

/// Gradients of the Hadamard adapter.
pub struct HadamardGrads {
    pub dx: Vec<f32>,
    pub dw: Vec<f32>,
    pub db: Vec<f32>,
    /// present iff `w2` participated in the forward.
    pub dw2: Option<Vec<f32>>,
    pub dw3: Option<Vec<f32>>,
}

/// VJP of [`hadamard_fwd`] at `(x, w, b, w2, w3)` for upstream `dy`.
pub fn hadamard_vjp(
    x: &[f32],
    w: &[f32],
    w2: Option<&[f32]>,
    w3: Option<&[f32]>,
    dy: &[f32],
) -> HadamardGrads {
    let h = w.len();
    let mut dx = vec![0.0f32; x.len()];
    let mut dw = vec![0.0f32; h];
    let mut db = vec![0.0f32; h];
    let mut dw2 = w2.map(|_| vec![0.0f32; h]);
    let mut dw3 = w3.map(|_| vec![0.0f32; h]);
    for (t, (row, dyrow)) in x.chunks_exact(h).zip(dy.chunks_exact(h)).enumerate() {
        for j in 0..h {
            let xv = row[j];
            let g = dyrow[j];
            dw[j] += g * xv;
            db[j] += g;
            let mut deriv = w[j];
            if let Some(w2) = w2 {
                deriv += 2.0 * w2[j] * xv;
                dw2.as_mut().unwrap()[j] += g * xv * xv;
            }
            if let Some(w3) = w3 {
                deriv += 3.0 * w3[j] * xv * xv;
                dw3.as_mut().unwrap()[j] += g * xv * xv * xv;
            }
            dx[t * h + j] = g * deriv;
        }
    }
    HadamardGrads { dx, dw, db, dw2, dw3 }
}

// --------------------------------------------------------------- layernorm

/// Per-row cache for the LayerNorm backward.
pub struct LnCache {
    /// normalized activations `(x - mu) * inv`, `[T, H]`.
    pub xhat: Vec<f32>,
    /// `1 / sqrt(var + eps)` per row, `[T]`.
    pub inv: Vec<f32>,
}

pub const LN_EPS: f64 = 1e-5;

/// Row-wise LayerNorm with affine output (ref: `layernorm_ref`).
/// `x: [T, H]`, `g, b: [H]`.
pub fn layernorm_fwd(x: &[f32], g: &[f32], b: &[f32]) -> (Vec<f32>, LnCache) {
    let h = g.len();
    let rows = x.len() / h;
    let mut y = vec![0.0f32; x.len()];
    let mut xhat = vec![0.0f32; x.len()];
    let mut inv = vec![0.0f32; rows];
    for t in 0..rows {
        let row = &x[t * h..(t + 1) * h];
        let mut mean = 0.0f64;
        for &v in row {
            mean += v as f64;
        }
        mean /= h as f64;
        let mut var = 0.0f64;
        for &v in row {
            let d = v as f64 - mean;
            var += d * d;
        }
        var /= h as f64;
        let iv = 1.0 / (var + LN_EPS).sqrt();
        inv[t] = iv as f32;
        for j in 0..h {
            let xh = ((row[j] as f64 - mean) * iv) as f32;
            xhat[t * h + j] = xh;
            y[t * h + j] = xh * g[j] + b[j];
        }
    }
    (y, LnCache { xhat, inv })
}

/// VJP of [`layernorm_fwd`]: returns `(dx, dg, db)`; `dg`/`db` are
/// *accumulated into* the provided buffers so layer loops can reuse slots.
pub fn layernorm_vjp(
    dy: &[f32],
    g: &[f32],
    cache: &LnCache,
    dg: Option<&mut [f32]>,
    db: Option<&mut [f32]>,
) -> Vec<f32> {
    let h = g.len();
    let rows = dy.len() / h;
    let mut dx = vec![0.0f32; dy.len()];
    if let Some(dg) = dg {
        for t in 0..rows {
            for j in 0..h {
                dg[j] += dy[t * h + j] * cache.xhat[t * h + j];
            }
        }
    }
    if let Some(db) = db {
        col_sum_acc(dy, db);
    }
    for t in 0..rows {
        let dyrow = &dy[t * h..(t + 1) * h];
        let xhrow = &cache.xhat[t * h..(t + 1) * h];
        let mut m1 = 0.0f64;
        let mut m2 = 0.0f64;
        for j in 0..h {
            let dxh = (dyrow[j] * g[j]) as f64;
            m1 += dxh;
            m2 += dxh * xhrow[j] as f64;
        }
        m1 /= h as f64;
        m2 /= h as f64;
        let iv = cache.inv[t] as f64;
        for j in 0..h {
            let dxh = (dyrow[j] * g[j]) as f64;
            dx[t * h + j] = (iv * (dxh - m1 - xhrow[j] as f64 * m2)) as f32;
        }
    }
    dx
}

// --------------------------------------------------------------- attention

/// Numerically-stable softmax over the last axis of `[rows, n]`, in place.
pub fn softmax_rows(x: &mut [f32], n: usize) {
    for row in x.chunks_exact_mut(n) {
        let mut max = f32::MIN;
        for &v in row.iter() {
            if v > max {
                max = v;
            }
        }
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// Masked scaled-dot-product attention forward (ref: `attention_ref`).
///
/// `q, k, v: [B, NH, L, D]`; `mask_add: [B, L]` additive (0 keep, -1e9
/// drop). Returns `(out [B, NH, L, D], probs [B, NH, L, L])`.
#[allow(clippy::too_many_arguments)]
pub fn attention_fwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    mask_add: &[f32],
    b: usize,
    nh: usize,
    l: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>) {
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = vec![0.0f32; b * nh * l * d];
    let mut probs = vec![0.0f32; b * nh * l * l];
    for bi in 0..b {
        let mrow = &mask_add[bi * l..(bi + 1) * l];
        for hi in 0..nh {
            let base = (bi * nh + hi) * l * d;
            let qs = &q[base..base + l * d];
            let ks = &k[base..base + l * d];
            let vs = &v[base..base + l * d];
            let pbase = (bi * nh + hi) * l * l;
            let scores = &mut probs[pbase..pbase + l * l];
            for i in 0..l {
                for j in 0..l {
                    let mut acc = 0.0f32;
                    for p in 0..d {
                        acc += qs[i * d + p] * ks[j * d + p];
                    }
                    scores[i * l + j] = acc * scale + mrow[j];
                }
            }
            softmax_rows(scores, l);
            for i in 0..l {
                let orow = &mut out[base + i * d..base + (i + 1) * d];
                for j in 0..l {
                    let pv = scores[i * l + j];
                    if pv == 0.0 {
                        continue;
                    }
                    let vrow = &vs[j * d..(j + 1) * d];
                    for p in 0..d {
                        orow[p] += pv * vrow[p];
                    }
                }
            }
        }
    }
    (out, probs)
}

/// VJP of [`attention_fwd`]: given upstream `dout [B, NH, L, D]` and the
/// forward's `probs`, returns `(dq, dk, dv)` (mask gets no gradient).
#[allow(clippy::too_many_arguments)]
pub fn attention_vjp(
    dout: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    probs: &[f32],
    b: usize,
    nh: usize,
    l: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let scale = 1.0 / (d as f32).sqrt();
    let mut dq = vec![0.0f32; q.len()];
    let mut dk = vec![0.0f32; k.len()];
    let mut dv = vec![0.0f32; v.len()];
    let mut dprobs = vec![0.0f32; l * l];
    let mut dscores = vec![0.0f32; l * l];
    for bi in 0..b {
        for hi in 0..nh {
            let base = (bi * nh + hi) * l * d;
            let pbase = (bi * nh + hi) * l * l;
            let pr = &probs[pbase..pbase + l * l];
            let dat = &dout[base..base + l * d];
            let vs = &v[base..base + l * d];
            // dprobs = dout @ v^T ; dv = probs^T @ dout
            for i in 0..l {
                for j in 0..l {
                    let mut acc = 0.0f32;
                    for p in 0..d {
                        acc += dat[i * d + p] * vs[j * d + p];
                    }
                    dprobs[i * l + j] = acc;
                }
            }
            {
                let dvs = &mut dv[base..base + l * d];
                for j in 0..l {
                    for i in 0..l {
                        let pv = pr[i * l + j];
                        if pv == 0.0 {
                            continue;
                        }
                        for p in 0..d {
                            dvs[j * d + p] += pv * dat[i * d + p];
                        }
                    }
                }
            }
            // softmax backward: ds = p * (dp - sum_j dp * p)
            for i in 0..l {
                let mut dot = 0.0f32;
                for j in 0..l {
                    dot += dprobs[i * l + j] * pr[i * l + j];
                }
                for j in 0..l {
                    dscores[i * l + j] = pr[i * l + j] * (dprobs[i * l + j] - dot);
                }
            }
            // dq = ds @ k * scale ; dk = ds^T @ q * scale
            let qs = &q[base..base + l * d];
            let ks = &k[base..base + l * d];
            {
                let dqs = &mut dq[base..base + l * d];
                let dks = &mut dk[base..base + l * d];
                for i in 0..l {
                    for j in 0..l {
                        let sv = dscores[i * l + j] * scale;
                        if sv == 0.0 {
                            continue;
                        }
                        for p in 0..d {
                            dqs[i * d + p] += sv * ks[j * d + p];
                            dks[j * d + p] += sv * qs[i * d + p];
                        }
                    }
                }
            }
        }
    }
    (dq, dk, dv)
}

// ------------------------------------------------------------------ probes

/// Per-example spectral norm of `a: [B, L, H]` via 8-step power iteration
/// on `A^T A` — mirrors `_spectral_norm` in `python/compile/model.py`
/// (the Fig. 1 statistic).
pub fn spectral_norm(a: &[f32], b: usize, l: usize, h: usize) -> Vec<f32> {
    let iters = 8;
    let mut out = vec![1.0f32; b];
    for bi in 0..b {
        let ab = &a[bi * l * h..(bi + 1) * l * h];
        let mut v = vec![1.0f32 / (h as f32).sqrt(); h];
        let mut u = vec![0.0f32; l];
        let mut nrm = 1.0f32;
        for _ in 0..iters {
            for (i, uv) in u.iter_mut().enumerate() {
                let row = &ab[i * h..(i + 1) * h];
                let mut acc = 0.0f32;
                for j in 0..h {
                    acc += row[j] * v[j];
                }
                *uv = acc;
            }
            let un: f32 = u.iter().map(|x| x * x).sum::<f32>().sqrt();
            for uv in u.iter_mut() {
                *uv /= un + 1e-9;
            }
            for vv in v.iter_mut() {
                *vv = 0.0;
            }
            for i in 0..l {
                let row = &ab[i * h..(i + 1) * h];
                let uv = u[i];
                for j in 0..h {
                    v[j] += row[j] * uv;
                }
            }
            nrm = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            for vv in v.iter_mut() {
                *vv /= nrm + 1e-9;
            }
        }
        out[bi] = nrm;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_reference_points() {
        assert!((erf(0.0)).abs() < 1e-12);
        assert!((erf(1.0) - 0.8427007929497149).abs() < 2e-7);
        assert!((erf(-1.0) + 0.8427007929497149).abs() < 2e-7);
        assert!((erf(3.0) - 0.9999779095030014).abs() < 2e-7);
    }

    #[test]
    fn gelu_known_values() {
        // gelu(0)=0, gelu is odd-ish: gelu(x) + gelu(-x) = x - x = ... check
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(1.0) - 0.841345).abs() < 1e-5);
        assert!((gelu(-1.0) + 0.158655).abs() < 1e-5);
        // derivative at 0 is 0.5
        assert!((dgelu(0.0) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn matmul_small() {
        // [2,3] x [3,2]
        let a = [1., 2., 3., 4., 5., 6.];
        let b = [7., 8., 9., 10., 11., 12.];
        let c = matmul(&a, &b, 2, 3, 2);
        assert_eq!(c, vec![58., 64., 139., 154.]);
        // a^T @ a : [3,3], diag = col norms
        let mut out = vec![0.0; 9];
        matmul_tn_acc(&a, &a, &mut out, 2, 3, 3);
        assert_eq!(out[0], 17.0); // 1*1 + 4*4
        // a @ a^T : [2,2]
        let c = matmul_nt(&a, &a, 2, 3, 2);
        assert_eq!(c, vec![14., 32., 32., 77.]);
    }

    #[test]
    fn hadamard_identity_is_noop() {
        let x = vec![0.5, -1.25, 3.0, 0.0, 2.5, -0.125];
        let w = vec![1.0, 1.0, 1.0];
        let b = vec![0.0, 0.0, 0.0];
        let z = vec![0.0, 0.0, 0.0];
        let y = hadamard_fwd(&x, &w, &b, Some(&z), Some(&z));
        assert_eq!(y, x, "identity-init adapter must be bit-exact no-op");
    }

    #[test]
    fn hadamard_grads_finite_difference() {
        let x = vec![0.3, -0.7, 1.1, 0.9, -0.2, 0.4];
        let w = vec![1.2, 0.8, -0.5];
        let b = vec![0.1, -0.1, 0.2];
        let w2 = vec![0.05, -0.02, 0.03];
        let w3 = vec![0.01, 0.02, -0.01];
        let dy = vec![1.0; 6];
        let g = hadamard_vjp(&x, &w, Some(&w2), Some(&w3), &dy);
        let f = |x: &[f32]| -> f32 {
            hadamard_fwd(x, &w, &b, Some(&w2), Some(&w3)).iter().sum()
        };
        let eps = 1e-3;
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let num = (f(&xp) - f(&xm)) / (2.0 * eps);
            assert!((num - g.dx[i]).abs() < 1e-2, "dx[{i}] {num} vs {}", g.dx[i]);
        }
    }

    #[test]
    fn layernorm_rows_normalized() {
        let x = vec![1.0, 2.0, 3.0, 4.0, -2.0, 0.0, 2.0, 4.0];
        let g = vec![1.0; 4];
        let b = vec![0.0; 4];
        let (y, cache) = layernorm_fwd(&x, &g, &b);
        for row in y.chunks_exact(4) {
            let mean: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-6);
            assert!((var - 1.0).abs() < 1e-3);
        }
        assert_eq!(cache.inv.len(), 2);
    }

    #[test]
    fn layernorm_vjp_finite_difference() {
        let x = vec![0.5, -1.0, 2.0, 0.25, 1.5, -0.5, 0.0, 1.0];
        let g = vec![1.1, 0.9, 1.2, 0.8];
        let b = vec![0.1, 0.0, -0.1, 0.2];
        let (_, cache) = layernorm_fwd(&x, &g, &b);
        let dy = vec![0.3, -0.2, 0.5, 0.1, -0.4, 0.2, 0.6, -0.1];
        let dx = layernorm_vjp(&dy, &g, &cache, None, None);
        let f = |x: &[f32]| -> f32 {
            let (y, _) = layernorm_fwd(x, &g, &b);
            y.iter().zip(&dy).map(|(a, b)| a * b).sum()
        };
        let eps = 1e-2;
        for i in 0..x.len() {
            let mut xp = x.to_vec();
            xp[i] += eps;
            let mut xm = x.to_vec();
            xm[i] -= eps;
            let num = (f(&xp) - f(&xm)) / (2.0 * eps);
            assert!((num - dx[i]).abs() < 2e-2, "dx[{i}] {num} vs {}", dx[i]);
        }
    }

    #[test]
    fn softmax_rows_sum_to_one_and_respect_mask() {
        let mut x = vec![1.0, 2.0, -1e9, 0.5];
        softmax_rows(&mut x, 4);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(x[2] < 1e-12);
    }

    #[test]
    fn attention_uniform_when_qk_zero() {
        let (b, nh, l, d) = (1, 1, 3, 2);
        let q = vec![0.0; l * d];
        let k = vec![0.0; l * d];
        let v: Vec<f32> = (0..l * d).map(|i| i as f32).collect();
        let mask = vec![0.0; l];
        let (out, probs) = attention_fwd(&q, &k, &v, &mask, b, nh, l, d);
        for p in &probs {
            assert!((p - 1.0 / 3.0).abs() < 1e-6);
        }
        // out rows are the mean of v rows
        for i in 0..l {
            assert!((out[i * d] - 2.0).abs() < 1e-5);
            assert!((out[i * d + 1] - 3.0).abs() < 1e-5);
        }
    }

    #[test]
    fn spectral_norm_of_known_matrix() {
        // rank-1 matrix: norm = |u| * |v|
        let l = 3;
        let h = 4;
        let u = [1.0f32, 2.0, 2.0];
        let v = [0.5f32, 0.5, 0.5, 0.5];
        let mut a = vec![0.0f32; l * h];
        for i in 0..l {
            for j in 0..h {
                a[i * h + j] = u[i] * v[j];
            }
        }
        let un: f32 = u.iter().map(|x| x * x).sum::<f32>().sqrt();
        let vn: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        let got = spectral_norm(&a, 1, l, h);
        assert!((got[0] - un * vn).abs() < 1e-4, "{} vs {}", got[0], un * vn);
    }
}
